//! Sparsity telemetry: per-context-length fired-fraction histograms
//! that check the engine's empirical sparsity against the paper's
//! `n^{4/5}` decode envelope, plus the shared zero-denominator ratio
//! helper every metrics rate goes through.

use crate::util::json::Json;

/// `num / den`, or `default` when the denominator is zero — the one
/// shared guard for every metrics ratio (`prefix_skip_rate`,
/// `attended_fraction`, hit rates), so an empty-engine snapshot never
/// divides by zero or emits NaN.
#[inline]
pub fn ratio_or(num: f64, den: f64, default: f64) -> f64 {
    if den == 0.0 {
        default
    } else {
        num / den
    }
}

/// Context lengths are bucketed by `log2`: bucket `i` covers
/// `[2^i, 2^(i+1))` tokens, up to 2^20 (1M) and beyond in the last.
pub const CTX_BUCKETS: usize = 21;

/// Per-bucket accumulator. Totals are integers so merging is exactly
/// associative and commutative (the property the multi-worker stats
/// aggregation depends on); min/max track the per-observation fraction.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
struct Bucket {
    /// Observations (decode rows) in this context-length bucket.
    count: u64,
    /// Total attention entries actually computed.
    fired: u64,
    /// Total dense-equivalent entries (context length per row summed).
    dense: u64,
    /// Smallest single-observation fired fraction (0 when empty).
    min_frac: f64,
    /// Largest single-observation fired fraction.
    max_frac: f64,
}

/// Histogram of empirical fired-entry fractions keyed by context
/// length, reported against the paper's `n^{4/5}` envelope (a fired
/// *fraction* of `n^{-1/5}`).
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityHist {
    buckets: Vec<Bucket>,
}

impl Default for SparsityHist {
    fn default() -> Self {
        SparsityHist { buckets: vec![Bucket::default(); CTX_BUCKETS] }
    }
}

/// Bucket index for a context length (log2, clamped to the table).
fn bucket_of(ctx_len: usize) -> usize {
    (usize::BITS - 1 - ctx_len.max(1).leading_zeros()) as usize
}

impl SparsityHist {
    /// The paper's fired-fraction envelope at context length `n`:
    /// decode touches `O(n^{4/5})` entries, a fraction of `n^{-1/5}`.
    pub fn envelope(ctx_len: usize) -> f64 {
        if ctx_len == 0 {
            return 1.0;
        }
        (ctx_len as f64).powf(-0.2)
    }

    /// Record one observation: a decode row over `ctx_len` cached
    /// tokens fired `fired` of `dense` dense-equivalent entries.
    pub fn record(&mut self, ctx_len: usize, fired: u64, dense: u64) {
        if dense == 0 {
            return;
        }
        let b = &mut self.buckets[bucket_of(ctx_len).min(CTX_BUCKETS - 1)];
        let frac = fired as f64 / dense as f64;
        if b.count == 0 {
            b.min_frac = frac;
            b.max_frac = frac;
        } else {
            b.min_frac = b.min_frac.min(frac);
            b.max_frac = b.max_frac.max(frac);
        }
        b.count += 1;
        b.fired += fired;
        b.dense += dense;
    }

    /// Merge another histogram (exactly associative and commutative:
    /// integer sums plus min/max).
    pub fn merge(&mut self, other: &SparsityHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            if b.count == 0 {
                continue;
            }
            if a.count == 0 {
                a.min_frac = b.min_frac;
                a.max_frac = b.max_frac;
            } else {
                a.min_frac = a.min_frac.min(b.min_frac);
                a.max_frac = a.max_frac.max(b.max_frac);
            }
            a.count += b.count;
            a.fired += b.fired;
            a.dense += b.dense;
        }
    }

    /// Total observations across buckets.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.count).sum()
    }

    /// Mean fired fraction across everything recorded (1.0 when empty:
    /// an engine that never pruned is dense).
    pub fn overall_fraction(&self) -> f64 {
        let fired: u64 = self.buckets.iter().map(|b| b.fired).sum();
        let dense: u64 = self.buckets.iter().map(|b| b.dense).sum();
        ratio_or(fired as f64, dense as f64, 1.0)
    }

    /// JSON summary: one entry per non-empty bucket with the mean /
    /// min / max fired fraction and the paper envelope `n^{-1/5}` at
    /// the bucket's lower edge.
    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| b.count > 0)
            .map(|(i, b)| {
                let lo = 1usize << i;
                let mut o = Json::obj();
                o.set("ctx_log2", i.into())
                    .set("ctx_lo", lo.into())
                    .set("count", b.count.into())
                    .set("fired", b.fired.into())
                    .set("dense", b.dense.into())
                    .set(
                        "mean_fraction",
                        ratio_or(b.fired as f64, b.dense as f64, 1.0).into(),
                    )
                    .set("min_fraction", b.min_frac.into())
                    .set("max_fraction", b.max_frac.into())
                    .set("envelope", Self::envelope(lo).into());
                o
            })
            .collect();
        Json::Arr(arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_or_guards_zero_denominators() {
        assert_eq!(ratio_or(3.0, 0.0, 0.0), 0.0);
        assert_eq!(ratio_or(3.0, 0.0, 1.0), 1.0);
        assert!((ratio_or(1.0, 4.0, 0.0) - 0.25).abs() < 1e-12);
        assert!(ratio_or(0.0, 0.0, 0.5).is_finite());
    }

    #[test]
    fn buckets_by_log2_context() {
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(0), 0, "degenerate context clamps to 0");
    }

    #[test]
    fn record_and_summarize() {
        let mut h = SparsityHist::default();
        h.record(1000, 100, 1000); // 10% fired at ctx ~1k
        h.record(1000, 300, 1000);
        h.record(8, 8, 8); // dense tiny context
        assert_eq!(h.count(), 3);
        let js = h.to_json();
        let arr = js.as_arr().unwrap();
        assert_eq!(arr.len(), 2, "two non-empty buckets");
        let big = arr.iter().find(|o| o.req_usize("ctx_log2").unwrap() == 9).unwrap();
        assert_eq!(big.req_usize("count").unwrap(), 2);
        assert!((big.req_f64("mean_fraction").unwrap() - 0.2).abs() < 1e-12);
        assert!((big.req_f64("min_fraction").unwrap() - 0.1).abs() < 1e-12);
        assert!((big.req_f64("max_fraction").unwrap() - 0.3).abs() < 1e-12);
        // Envelope is n^{-1/5} of the bucket's lower edge.
        let env = big.req_f64("envelope").unwrap();
        assert!((env - (512f64).powf(-0.2)).abs() < 1e-12);
        // Empty histogram is "dense" by convention.
        assert_eq!(SparsityHist::default().overall_fraction(), 1.0);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mk = |obs: &[(usize, u64, u64)]| {
            let mut h = SparsityHist::default();
            for &(c, f, d) in obs {
                h.record(c, f, d);
            }
            h
        };
        let a = mk(&[(100, 10, 100), (5000, 40, 5000)]);
        let b = mk(&[(100, 90, 100)]);
        let c = mk(&[(64, 64, 64), (5000, 10, 5000)]);
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "associative");
        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba, "commutative");
    }
}
