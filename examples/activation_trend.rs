//! Figure 1 reproduction: exp(x) vs ReLU^α(x − b) activation trends.
//!
//! Emits the series the paper plots (b = 1.5, α ∈ {1,2,3}, x ∈ [−4, 4])
//! as an aligned table plus a crude ASCII plot.
//!
//! Run: cargo run --release --example activation_trend

use hsr_attn::attention::relu::relu_pow;

fn main() {
    let b = 1.5f32;
    println!("Figure 1: Softmax activation exp(x) vs ReLU^a(x - {b})");
    println!(
        "{:>6} | {:>10} {:>10} {:>10} {:>10}",
        "x", "exp(x)", "ReLU^1", "ReLU^2", "ReLU^3"
    );
    println!("{}", "-".repeat(54));
    let mut rows = Vec::new();
    let steps = 33;
    for i in 0..steps {
        let x = -4.0 + 8.0 * i as f32 / (steps - 1) as f32;
        let e = x.exp();
        let r1 = relu_pow(x - b, 1);
        let r2 = relu_pow(x - b, 2);
        let r3 = relu_pow(x - b, 3);
        println!("{x:>6.2} | {e:>10.4} {r1:>10.4} {r2:>10.4} {r3:>10.4}");
        rows.push((x, e, r1, r2, r3));
    }
    // ASCII sketch of the crossing behaviour on [0, 4].
    println!("\nASCII sketch (x in [0,4], y clipped at 16): e=exp  1/2/3=ReLU^a");
    let height = 12;
    let width = 60;
    let mut grid = vec![vec![' '; width]; height];
    for col in 0..width {
        let x = 4.0 * col as f32 / (width - 1) as f32;
        let mut put = |y: f32, c: char| {
            if y >= 0.0 {
                let row = ((y.min(16.0) / 16.0) * (height - 1) as f32).round() as usize;
                let r = height - 1 - row;
                if grid[r][col] == ' ' {
                    grid[r][col] = c;
                }
            }
        };
        put(x.exp(), 'e');
        put(relu_pow(x - b, 1), '1');
        put(relu_pow(x - b, 2), '2');
        put(relu_pow(x - b, 3), '3');
    }
    for row in grid {
        println!("|{}", row.into_iter().collect::<String>());
    }
    println!("+{}", "-".repeat(width));
    println!("takeaway: past the threshold b the ReLU^a activations grow");
    println!("polynomially while exp grows exponentially — both concentrate");
    println!("mass on high-score entries, which is what HSR reporting exploits.");
}
