//! hsr-attn CLI: serve the trained model over TCP, generate one-shot,
//! or print reproduction tables.
//!
//!   hsr-attn serve   --model small --addr 127.0.0.1:7070 --workers 2
//!                    --policy sparse|dense --backend balltree
//!   hsr-attn generate --model small --prompt "text" --gen 48
//!   hsr-attn table1  [--max-n 1048576]
//!   hsr-attn info

use anyhow::{Context, Result};
use hsr_attn::attention::{AttentionConfig, AttentionKind};
use hsr_attn::engine::{EngineConfig, GenerationParams, Router, RouterConfig};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::kvstore::{PrefixCacheMode, SpillConfig, SpillPolicy};
use hsr_attn::model::tokenizer::ByteTokenizer;
use hsr_attn::model::transformer::AttentionPolicy;
use hsr_attn::model::Model;
use hsr_attn::server::{Server, ServerConfig};
use hsr_attn::util::cli::Args;
use std::path::PathBuf;
use std::sync::Arc;

const USAGE: &str = "usage: hsr-attn <serve|generate|table1|info> [--flags]\n\
  --backend <brute|balltree|layers2d|projected|none>   per-head HSR index\n\
  --policy  <dense|sparse|topr=R>                      attention policy\n\
  --decode-threads <N>                                 batched decode sweep (0 = auto)\n\
  --prefix-cache <on|off|tokens>                       shared-prefix KV cache\n\
                                                       (tokens = min match to adopt)\n\
  --spill <off|mem|directory>                          cold tier for evicted prefix\n\
                                                       segments (compressed spill store)\n\
  --spill-policy <rebuild|serialize>                   cold-segment HSR handling:\n\
                                                       rebuild at refault, or serialize\n\
  --hot-blocks <N>                                     hot-tier cap in blocks\n\
                                                       (0 = use --cache-tokens)\n\
  --request-log <on|off>                               one reqlog line per terminal\n\
                                                       outcome (serve; default on)\n\
  --max-queue <N> --max-in-flight <N>                  admission-control caps (serve)\n\
  --max-connections <N>                                live-connection cap (serve)\n\
  --affinity <on|off>                                  prefix-affinity routing (serve);\n\
                                                       degrades to least-loaded when the\n\
                                                       preferred worker is dead/saturated\n\
  --send-buffer <N>                                    per-stream token buffer (serve);\n\
                                                       a consumer this far behind is shed\n\
  --trace <on|off>                                     flight-recorder span tracing\n\
                                                       (default on; rings dump on panic)\n\
  --trace-dir <dir>                                    also write per-request JSONL\n\
                                                       timelines and panic dumps here\n\
  --metrics-interval <secs>                            periodic stderr metrics line\n\
                                                       (serve; 0 = off)\n\
  --deadline-ms <N>                                    request deadline (generate)";

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or(
        "artifacts",
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    ))
}

/// CLI → unified [`AttentionConfig`] → [`EngineConfig`]: one config
/// source for the serving engine's sparse-attention knobs. An invalid
/// --backend exits with `HsrBackend::parse`'s valid-name list.
fn engine_config(args: &Args) -> EngineConfig {
    let hsr_backend = match args.str_or("backend", "balltree") {
        // Explicit "none"/"scan": no per-head index — brute scans inside
        // the sparse policy (ablation mode).
        "none" | "scan" => None,
        _ => Some(args.parse_or_exit("backend", "balltree", USAGE, HsrBackend::parse)),
    };
    let mut att = AttentionConfig::new(
        AttentionKind::Softmax,
        hsr_backend.unwrap_or(HsrBackend::Brute),
    )
    .with_threads(args.usize_or("decode-threads", 0));
    // Single parse of --policy: fixed-r goes through the unified config,
    // dense overrides the sparse policy from_attention produces.
    let mut dense = false;
    match args.str_or("policy", "sparse") {
        "dense" => dense = true,
        "sparse" => {}
        other => {
            if let Some(r) = other.strip_prefix("topr=").and_then(|s| s.parse().ok()) {
                att = att.with_top_r(r);
            } else {
                eprintln!("unknown --policy '{other}', using sparse");
            }
        }
    }
    let mut cfg = EngineConfig::from_attention(att);
    if dense {
        cfg.policy = AttentionPolicy::Dense;
    }
    cfg.hsr_backend = hsr_backend;
    cfg.cache_capacity_tokens = args.usize_or("cache-tokens", 1 << 20);
    cfg.block_tokens = args.usize_or("block-tokens", 64);
    // Same Result-returning parse path as --backend: an invalid value
    // exits with the valid-form list from `PrefixCacheMode::parse`.
    cfg.prefix_cache =
        args.parse_or_exit("prefix-cache", "on", USAGE, PrefixCacheMode::parse);
    cfg.spill = args.parse_or_exit("spill", "off", USAGE, SpillConfig::parse);
    cfg.spill_policy =
        args.parse_or_exit("spill-policy", "rebuild", USAGE, SpillPolicy::parse);
    // --hot-blocks caps the *hot* tier in block units (the natural unit
    // once a cold tier exists); 0 keeps the --cache-tokens sizing.
    let hot_blocks = args.usize_or("hot-blocks", 0);
    if hot_blocks > 0 {
        cfg.cache_capacity_tokens = hot_blocks * cfg.block_tokens;
    }
    cfg.trace.enabled = match args.str_or("trace", "on") {
        "off" => false,
        "on" => true,
        other => {
            eprintln!("invalid --trace '{other}' (want on|off)");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    let trace_dir = args.str_or("trace-dir", "");
    if !trace_dir.is_empty() {
        cfg.trace.trace_dir = Some(PathBuf::from(trace_dir));
    }
    cfg
}

fn load_model(args: &Args) -> Result<Arc<Model>> {
    let dir = artifacts_dir(args);
    let name = args.str_or("model", "small");
    Ok(Arc::new(Model::load_named(&dir, name).with_context(
        || format!("loading model '{name}' from {} — run `make artifacts`?", dir.display()),
    )?))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let workers = args.usize_or("workers", 2);
    let addr = args.str_or("addr", "127.0.0.1:7070");
    let affinity = match args.str_or("affinity", "on") {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("invalid --affinity '{other}' (want on|off)");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    let request_log = match args.str_or("request-log", "on") {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("invalid --request-log '{other}' (want on|off)");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    let rcfg = RouterConfig {
        max_queue_per_worker: args.usize_or("max-queue", 64),
        max_in_flight: args.usize_or("max-in-flight", 512),
        affinity,
        stream_buffer: args.usize_or("send-buffer", 256),
        request_log,
        ..Default::default()
    };
    let scfg = ServerConfig {
        max_connections: args.usize_or("max-connections", 64),
        ..Default::default()
    };
    let router =
        Arc::new(Router::with_config(model, engine_config(args), workers, rcfg));
    let metrics_interval = args.usize_or("metrics-interval", 0);
    if metrics_interval > 0 {
        // Periodic stderr reporter: one compact delta line per interval
        // off the same live snapshot the {"cmd":"stats"} frame serves.
        // Detached on purpose — it dies with the process.
        let router = Arc::clone(&router);
        std::thread::Builder::new()
            .name("metrics-reporter".to_string())
            .spawn(move || {
                let mut prev: Option<hsr_attn::obs::Snapshot> = None;
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(
                        metrics_interval as u64,
                    ));
                    let snap = hsr_attn::obs::Snapshot::of(&router.stats_snapshot());
                    eprintln!("{}", snap.delta_line(prev.as_ref()));
                    prev = Some(snap);
                }
            })
            .expect("spawn metrics reporter");
    }
    let server = Server::bind_with(router, addr, scfg)?;
    println!("hsr-attn serving on {} ({} workers)", server.local_addr()?, workers);
    println!("protocol: one JSON object per line, e.g.");
    println!("  {{\"prompt\":\"the merchant carries \",\"max_new_tokens\":32,\"deadline_ms\":2000}}");
    println!("  add \"stream\":true for per-token frames (one terminal frame per stream)");
    server.serve()
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let prompt_text = args.str_or("prompt", "the merchant carries ");
    let tokenizer = ByteTokenizer;
    let router = Router::new(model, engine_config(args), 1);
    let deadline_ms = args.usize_or("deadline-ms", 0);
    router
        .submit(
            tokenizer.encode(prompt_text),
            GenerationParams {
                max_new_tokens: args.usize_or("gen", 48),
                temperature: args.f64_or("temperature", 0.0) as f32,
                stop_token: None,
                deadline: (deadline_ms > 0).then(|| {
                    std::time::Instant::now()
                        + std::time::Duration::from_millis(deadline_ms as u64)
                }),
                ..Default::default()
            },
        )
        .map_err(|e| anyhow::anyhow!("submit rejected: {e}"))?;
    router.wait_idle();
    let resp = router.take_responses().pop().context("no response")?;
    println!("prompt: {prompt_text}");
    println!("output: {}", tokenizer.decode(&resp.tokens));
    println!("({} tokens, {:.1} ms, ttft {:.1} ms)", resp.tokens.len(), resp.latency_ms, resp.ttft_ms);
    let m = router.shutdown();
    println!("{}", m.summary());
    Ok(())
}

fn cmd_table1(args: &Args) {
    let max_n = args.usize_or("max-n", 1 << 20);
    let ns: Vec<usize> = (10..=20).map(|p| 1usize << p).filter(|&n| n <= max_n).collect();
    println!("{:>10} {:>14} {:>10}", "n", "activated", "sparsity");
    for row in hsr_attn::attention::threshold::sparsity_table(&ns) {
        println!("{:>10} {:>14.0} {:>9.2}%", row.n, row.activated, row.sparsity * 100.0);
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    println!("hsr-attn {}", hsr_attn::version());
    println!("artifacts dir: {}", dir.display());
    if dir.join("manifest.json").exists() {
        let rt = hsr_attn::runtime::Runtime::new(&dir)?;
        println!("PJRT platform: {}", rt.platform());
        println!("models: {:?}", rt.manifest.models.keys().collect::<Vec<_>>());
        println!("hlo artifacts: {:?}", rt.manifest.hlo.keys().collect::<Vec<_>>());
    } else {
        println!("artifacts not built — run `make artifacts`");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("table1") => {
            cmd_table1(&args);
            Ok(())
        }
        Some("info") | None => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}
