"""L1 Pallas kernels for HSR-sparse attention.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the HSR report set
is ragged and data-dependent — hostile to systolic-array tiling — so the
kernels take a *padded gathered layout*: the L3 coordinator gathers the
reported K/V rows into fixed-size [r_max, d] tiles and passes a valid-row
count; masking replaces control flow inside the kernel. BlockSpec streams
key tiles through VMEM; accumulation runs in fp32.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path, and real-TPU efficiency is *estimated* from the block shapes
(EXPERIMENTS.md §Perf). Kernels deliberately use only TPU-friendly
primitives (matmul on [block, d] tiles, elementwise, masked reductions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Key-tile size: 128 rows keeps q-block x k-tile MXU-shaped and bounds the
# VMEM working set at (block_q + 2*BLOCK_K) * d * 4 bytes.
BLOCK_K = 128


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Masked softmax attention over a padded gathered block (Definition B.2).
# ---------------------------------------------------------------------------

def _masked_softmax_kernel(q_ref, kg_ref, vg_ref, count_ref, o_ref, *, r_max):
    """One query row per program. Streaming (flash-style) softmax over
    BLOCK_K-sized tiles of the gathered keys."""
    q = q_ref[...]  # [d]
    count = count_ref[...]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    num_tiles = r_max // BLOCK_K

    def body(t, carry):
        m_prev, l_prev, acc = carry
        kg = kg_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]  # [BLOCK_K, d]
        vg = vg_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]
        s = kg @ q * scale  # [BLOCK_K]
        idx = t * BLOCK_K + jnp.arange(BLOCK_K)
        valid = idx < count
        s = jnp.where(valid, s, -jnp.inf)
        m_new = jnp.maximum(m_prev, s.max())
        # Guard the all-invalid tile: keep the old maximum.
        m_new = jnp.where(jnp.isfinite(m_new), m_new, m_prev)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # [BLOCK_K]
        l_new = l_prev * corr + p.sum()
        acc = acc * corr + p @ vg  # [d]
        return m_new, l_new, acc

    # m starts at a large negative *finite* value so exp(m_prev - m_new)
    # is well-defined before the first valid tile.
    init = (jnp.float32(-1e30), jnp.float32(0.0), jnp.zeros((d,), jnp.float32))
    m_fin, l_fin, acc = jax.lax.fori_loop(0, num_tiles, body, init)
    safe = jnp.where(l_fin > 0.0, l_fin, 1.0)
    o_ref[...] = jnp.where(l_fin > 0.0, acc / safe, 0.0)


def masked_softmax_attention(q, kg, vg, count, *, interpret: bool = True):
    """Pallas masked softmax attention.

    q: [m, d]; kg, vg: [m, r_max, d]; count: [m] int32 -> [m, d].
    r_max is padded up to a BLOCK_K multiple internally.
    """
    m, d = q.shape
    r_max = kg.shape[1]
    r_pad = _ceil_to(max(r_max, BLOCK_K), BLOCK_K)
    if r_pad != r_max:
        pad = [(0, 0), (0, r_pad - r_max), (0, 0)]
        kg = jnp.pad(kg, pad)
        vg = jnp.pad(vg, pad)
    kernel = functools.partial(_masked_softmax_kernel, r_max=r_pad)
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, d), lambda i: (i, 0)),
            pl.BlockSpec((None, r_pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, r_pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(q, kg, vg, count)


# ---------------------------------------------------------------------------
# Masked ReLU^alpha attention over a padded gathered block (Definition 1.2
# restricted to the HSR report set — exact, no approximation error).
# ---------------------------------------------------------------------------

def _masked_relu_kernel(q_ref, kg_ref, vg_ref, count_ref, o_ref, *, r_max, alpha, bias):
    q = q_ref[...]
    count = count_ref[...]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    num_tiles = r_max // BLOCK_K

    def body(t, carry):
        denom, acc = carry
        kg = kg_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]
        vg = vg_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]
        s = kg @ q * scale - bias
        idx = t * BLOCK_K + jnp.arange(BLOCK_K)
        valid = idx < count
        a = jnp.where(valid, jnp.maximum(s, 0.0) ** alpha, 0.0)
        return denom + a.sum(), acc + a @ vg

    denom, acc = jax.lax.fori_loop(
        0, num_tiles, body, (jnp.float32(0.0), jnp.zeros((d,), jnp.float32))
    )
    safe = jnp.where(denom > 0.0, denom, 1.0)
    o_ref[...] = jnp.where(denom > 0.0, acc / safe, 0.0)


def masked_relu_attention(q, kg, vg, count, bias, alpha: int = 1, *, interpret: bool = True):
    """Pallas masked ReLU^alpha attention (same layout as softmax)."""
    m, d = q.shape
    r_max = kg.shape[1]
    r_pad = _ceil_to(max(r_max, BLOCK_K), BLOCK_K)
    if r_pad != r_max:
        pad = [(0, 0), (0, r_pad - r_max), (0, 0)]
        kg = jnp.pad(kg, pad)
        vg = jnp.pad(vg, pad)
    kernel = functools.partial(
        _masked_relu_kernel, r_max=r_pad, alpha=alpha, bias=float(bias)
    )
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, d), lambda i: (i, 0)),
            pl.BlockSpec((None, r_pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, r_pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(q, kg, vg, count)


# ---------------------------------------------------------------------------
# Dense attention kernels (naive-baseline shape): full K/V, flash-style
# streaming over key tiles. Used for the dense decode-step artifact and as
# the L1 comparator in kernel tests.
# ---------------------------------------------------------------------------

def _dense_softmax_kernel(q_ref, k_ref, v_ref, o_ref, *, n):
    q = q_ref[...]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    num_tiles = n // BLOCK_K

    def body(t, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]
        v = v_ref[pl.dslice(t * BLOCK_K, BLOCK_K), :]
        s = k @ q * scale
        m_new = jnp.maximum(m_prev, s.max())
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        return m_new, l_prev * corr + p.sum(), acc * corr + p @ v

    init = (jnp.float32(-1e30), jnp.float32(0.0), jnp.zeros((d,), jnp.float32))
    _, l_fin, acc = jax.lax.fori_loop(0, num_tiles, body, init)
    o_ref[...] = acc / l_fin


def dense_softmax_attention(q, k, v, *, interpret: bool = True):
    """Pallas dense softmax attention. q: [m,d]; k,v: [n,d] (n must be a
    BLOCK_K multiple — the AOT exporter pads caches to this)."""
    m, d = q.shape
    n = k.shape[0]
    assert n % BLOCK_K == 0, f"n={n} must be a multiple of {BLOCK_K}"
    kernel = functools.partial(_dense_softmax_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(r_max: int, d: int, block_q: int = 1) -> int:
    """Estimated VMEM working set of the masked kernels: the q block, one
    K tile, one V tile, and the accumulator (fp32)."""
    return 4 * (block_q * d + 2 * BLOCK_K * d + block_q * d)


def mxu_utilization_estimate(r_max: int, d: int) -> float:
    """Fraction of MXU-shaped work in the masked kernel: the [BLOCK_K, d]
    x [d] matvecs dominate; utilization is bounded by d/128 lane fill for
    d < 128 (8x128x128 MXU tiles)."""
    lane_fill = min(d, 128) / 128.0
    sublane_fill = min(BLOCK_K, 128) / 128.0
    return lane_fill * sublane_fill
