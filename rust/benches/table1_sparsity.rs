//! Bench/reproduction: **Table 1** — activated entries & sparsity ratio
//! across sequence lengths, analytic (n^{4/5}, the paper's construction)
//! and measured on the Gaussian workload; plus the wall time of counting
//! activations via HSR vs naive scan.

use hsr_attn::attention::relu::count_activated;
use hsr_attn::attention::threshold::{sparsity_table, ThresholdParams};
use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::engine::GenerationDecoding;
use hsr_attn::hsr::HsrBackend;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::fmt_ns;

fn main() {
    banner("table1_sparsity", "paper Table 1 (sparsity level vs n)");
    let d = 64usize;
    let m = 4usize;
    let analytic_ns: Vec<usize> = (10..=20).map(|p| 1usize << p).collect();
    println!("analytic (the paper's own Table 1 is this computation):");
    println!("{:>10} {:>12} {:>10}   paper row", "n", "activated", "sparsity");
    let paper_rows = [
        (1 << 10, 251),
        (1 << 11, 437),
        (1 << 12, 761),
        (1 << 13, 1325),
        (1 << 14, 2308),
        (1 << 15, 4019),
        (1 << 16, 6997),
        (1 << 17, 12183),
        (1 << 18, 21212),
        (1 << 19, 36933),
        (1 << 20, 64304),
    ];
    for (row, (pn, pact)) in sparsity_table(&analytic_ns).iter().zip(paper_rows) {
        assert_eq!(row.n, pn);
        let ratio = row.activated / pact as f64;
        println!(
            "{:>10} {:>12.0} {:>9.2}%   paper: {:>6} ({:+.1}%)",
            row.n,
            row.activated,
            row.sparsity * 100.0,
            pact,
            (ratio - 1.0) * 100.0
        );
    }

    println!("\nmeasured on Gaussian Q/K at the practical Lemma 6.1 threshold (d={d}):");
    println!(
        "{:>8} {:>10} {:>12} | {:>12} {:>12}",
        "n", "avg fired", "bound 2n^.8", "naive count", "hsr fire+attn"
    );
    let bench = Bencher::quick();
    let mut rng = Rng::new(3);
    for n in [1usize << 10, 1 << 12, 1 << 14, 1 << 16] {
        let params = ThresholdParams::standard(d, m);
        let bias = params.practical_bias(n) as f32;
        let q = rng.gaussian_vec_f32(m * d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let counts = count_activated(&q, &k, d, bias);
        let avg = counts.iter().sum::<usize>() / m;
        let naive = bench.run(&format!("naive_count/n={n}"), || {
            black_box(count_activated(&q, &k, d, bias));
        });
        let mut gd = GenerationDecoding::init_gaussian(
            &k,
            &v,
            d,
            m,
            hsr_attn::attention::AttentionKind::Relu { alpha: 1, bias },
            HsrBackend::Projected,
        );
        let mut out = vec![0f32; d];
        let hsr = bench.run(&format!("hsr_fire/n={n}"), || {
            for i in 0..m {
                let qq: Vec<f32> = q[i * d..(i + 1) * d].to_vec();
                black_box(gd.inference_row(&qq, &mut out));
            }
        });
        println!(
            "{:>8} {:>10} {:>12.0} | {:>12} {:>12}",
            n,
            avg,
            params.row_bound(n),
            fmt_ns(naive.median_ns),
            fmt_ns(hsr.median_ns),
        );
    }
    println!("\nOK: analytic column reproduces the paper's Table 1 within rounding");
    println!("(the paper tabulates ~n^0.8; small % offsets come from their rounding).");
}
