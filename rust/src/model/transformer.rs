//! Native transformer forward + HSR-sparse decode — the serving hot path.
//!
//! Mirrors `python/compile/model.py` op-for-op (RMSNorm/RoPE/SwiGLU,
//! fp32); parity is asserted against golden vectors exported by aot.py.
//! The attention inner loop is pluggable via [`AttentionPolicy`]:
//!
//! * `Dense` — the naive O(n) softmax over the whole KV cache
//!   (Definition 1.1; the baseline of Theorems 4.2/5.2).
//! * `TopR` — Algorithm 1's inference loop: HSR query for the candidate
//!   half-space, then exact top-r restriction (Definition B.2). The
//!   threshold b is auto-calibrated per (layer, head) from observed score
//!   quantiles ("choose b such that R = NN(r, q, K)" — Theorem 4.2) and
//!   adapts as the distribution drifts during generation. Because the HSR
//!   query is exact, candidates ⊇ top-r whenever |candidates| ≥ r, so the
//!   selected index set equals the true NN(r, q, K).

use super::kv::{HeadKv, KvState};
use super::Model;
use crate::attention::plan::AttentionPlan;
use crate::attention::session;
use crate::attention::softmax::log_sum_exp;
use crate::hsr::{HalfSpaceReport, QueryStats};
use crate::kvstore::shared::SharedKvMut;
use crate::util::tensor_io::Tensor;

/// How many candidates (relative to r) the calibrator aims to report:
/// a 2x superset absorbs distribution drift between steps.
const CALIBRATION_SLACK: f32 = 2.0;

/// Attention policy for cached attention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttentionPolicy {
    /// Full softmax attention over the cache.
    Dense,
    /// Softmax attention restricted to the top-r indices, r = spec(n).
    TopR(RSpec),
}

/// How r scales with the cache length n.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RSpec {
    /// Constant r.
    Fixed(usize),
    /// r = ceil(n^p) — the paper's n^{4/5} with p = 0.8.
    Pow(f64),
}

impl RSpec {
    /// The paper's r = n^{4/5}.
    pub fn paper() -> RSpec {
        RSpec::Pow(0.8)
    }

    pub fn r_for(&self, n: usize) -> usize {
        match *self {
            RSpec::Fixed(r) => r.max(1),
            RSpec::Pow(p) => (n as f64).powf(p).ceil().max(1.0) as usize,
        }
    }
}

/// Per-step instrumentation aggregated across layers/heads.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    /// HSR work counters summed over heads.
    pub hsr: QueryStats,
    /// Total attended (selected) entries.
    pub attended: usize,
    /// Total cache entries that a dense pass would have attended.
    pub dense_equivalent: usize,
    /// Number of calibration fallbacks (full re-scans).
    pub fallbacks: usize,
}

impl StepStats {
    /// Merge another worker's counters (all sums — order-independent).
    pub fn add(&mut self, other: &StepStats) {
        self.hsr.add(&other.hsr);
        self.attended += other.attended;
        self.dense_equivalent += other.dense_equivalent;
        self.fallbacks += other.fallbacks;
    }
}

/// Reusable scratch buffers for a forward step (no allocation on the
/// token hot path). The per-head attention worker state is an
/// [`AttentionPlan`] — the same plan arena the session API uses, so one
/// plan per thread serves every (layer, head) it sweeps.
pub struct Workspace {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ffn_a: Vec<f32>,
    ffn_b: Vec<f32>,
    attn: AttentionPlan,
    logits: Vec<f32>,
}

impl Workspace {
    pub fn new(model: &Model) -> Workspace {
        let c = &model.cfg;
        Workspace {
            x: vec![0.0; c.d_model],
            h: vec![0.0; c.d_model],
            q: vec![0.0; c.d_model],
            k: vec![0.0; c.d_model],
            v: vec![0.0; c.d_model],
            att: vec![0.0; c.d_model],
            proj: vec![0.0; c.d_model],
            ffn_a: vec![0.0; c.d_ffn],
            ffn_b: vec![0.0; c.d_ffn],
            attn: AttentionPlan::new(),
            logits: vec![0.0; c.vocab],
        }
    }
}

/// Reusable state for one **batched** decode step: flat [B, d_model]
/// activations plus per-thread [`AttentionPlan`] shards for the parallel
/// per-(layer, head) attention sweep. Buffers grow to the largest batch
/// seen and are reused across steps (no steady-state allocation).
pub struct BatchWorkspace {
    /// Residual stream per sequence, [B, d_model].
    x: Vec<f32>,
    /// Post-RoPE queries per sequence, [B, d_model] (per layer).
    q: Vec<f32>,
    /// Attention outputs per sequence, [B, d_model] (per layer).
    att: Vec<f32>,
    /// Serial-phase temporaries (norms, K/V projections, FFN, logits).
    tmp: Workspace,
    /// Per-thread attention plan shards.
    shards: Vec<AttentionPlan>,
    /// Worker threads for the (sequence × head) attention grid:
    /// 0 → one per available core, 1 → serial.
    pub threads: usize,
}

impl BatchWorkspace {
    pub fn new(model: &Model) -> BatchWorkspace {
        BatchWorkspace {
            x: Vec::new(),
            q: Vec::new(),
            att: Vec::new(),
            tmp: Workspace::new(model),
            shards: Vec::new(),
            threads: 0,
        }
    }
}

/// out = x @ W for row-major W [d_in, d_out].
fn matvec(x: &[f32], w: &Tensor, out: &mut [f32]) {
    let d_in = w.shape[0];
    let d_out = w.shape[1];
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(out.len(), d_out);
    out.fill(0.0);
    for i in 0..d_in {
        let xi = x[i];
        let row = &w.data[i * d_out..(i + 1) * d_out];
        crate::kernel::simd::axpy(out, row, xi);
    }
}

/// RMSNorm: x * rsqrt(mean(x^2) + eps) * w.
fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let scale = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * scale * wv;
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// In-place RoPE on one head vector (consecutive-pair layout, matching
/// model.py's apply_rope).
pub fn apply_rope(x: &mut [f32], pos: usize, theta: f64) {
    let d_head = x.len();
    let half = d_head / 2;
    for i in 0..half {
        let freq = theta.powf(-((2 * i) as f64) / d_head as f64);
        let ang = pos as f64 * freq;
        let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
        let e = x[2 * i];
        let o = x[2 * i + 1];
        x[2 * i] = e * cos - o * sin;
        x[2 * i + 1] = e * sin + o * cos;
    }
}

impl Model {
    /// One autoregressive step: appends this token's K/V to the cache and
    /// returns the next-token logits. `pos` must equal `kv.len()`.
    /// Unshared shim over [`Model::decode_step_shared`] (an empty prefix
    /// view follows the exact pre-kvstore code path).
    pub fn decode_step(
        &self,
        token: u32,
        kv: &mut KvState,
        policy: AttentionPolicy,
        ws: &mut Workspace,
        stats: &mut StepStats,
    ) -> Vec<f32> {
        let mut skv = SharedKvMut::unshared(kv);
        self.decode_step_shared(token, &mut skv, policy, ws, stats)
    }

    /// One autoregressive step over a **shared-prefix** KV view: the
    /// current token's K/V rows are appended to the private tail (the
    /// shared chain is immutable), attention positions run over
    /// `prefix + tail`, and the sparse attend queries each chain
    /// segment's shared HSR index plus the tail. With an empty prefix
    /// this is byte-for-byte the historical `decode_step`.
    pub fn decode_step_shared(
        &self,
        token: u32,
        skv: &mut SharedKvMut<'_, '_>,
        policy: AttentionPolicy,
        ws: &mut Workspace,
        stats: &mut StepStats,
    ) -> Vec<f32> {
        let c = &self.cfg;
        let pos = skv.len();
        // Embedding.
        let emb = self.tensor("tok_emb");
        ws.x.copy_from_slice(emb.row(token as usize));

        // One reusable buffer for the per-(layer, head) chain slices —
        // refilled per head, allocated once per token at most.
        let mut pheads: Vec<(&HeadKv, usize)> =
            Vec::with_capacity(skv.prefix.segments.len());

        for layer in 0..c.n_layers {
            // --- attention block ---
            rms_norm(&ws.x, &self.layer_tensor("attn_norm", layer).data, c.rms_eps, &mut ws.h);
            matvec(&ws.h, self.layer_tensor("wq", layer), &mut ws.q);
            matvec(&ws.h, self.layer_tensor("wk", layer), &mut ws.k);
            matvec(&ws.h, self.layer_tensor("wv", layer), &mut ws.v);
            for head in 0..c.n_heads {
                let s = head * c.d_head;
                let e = s + c.d_head;
                apply_rope(&mut ws.q[s..e], pos, c.rope_theta);
                apply_rope(&mut ws.k[s..e], pos, c.rope_theta);
                // Append current token so it participates in attention.
                let hk = skv.tail.head_mut(layer, head);
                hk.append(&ws.k[s..e], &ws.v[s..e]);
                if skv.prefix.is_empty() {
                    attend_head(
                        hk,
                        &ws.q[s..e],
                        c.d_head,
                        policy,
                        &mut ws.attn,
                        &mut ws.att[s..e],
                        stats,
                    );
                } else {
                    pheads.clear();
                    for &(kv, start) in skv.prefix.segments.iter() {
                        pheads.push((kv.head(layer, head), start));
                    }
                    let mut row = [(hk, &ws.q[s..e], &mut ws.att[s..e])];
                    attend_group(
                        &pheads,
                        skv.prefix.len,
                        &mut row,
                        c.d_head,
                        policy,
                        &mut ws.attn,
                        stats,
                    );
                }
            }
            matvec(&ws.att, self.layer_tensor("wo", layer), &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
            // --- MLP block (SwiGLU) ---
            rms_norm(&ws.x, &self.layer_tensor("mlp_norm", layer).data, c.rms_eps, &mut ws.h);
            matvec(&ws.h, self.layer_tensor("w1", layer), &mut ws.ffn_a);
            matvec(&ws.h, self.layer_tensor("w3", layer), &mut ws.ffn_b);
            for (a, &b) in ws.ffn_a.iter_mut().zip(&ws.ffn_b) {
                *a = silu(*a) * b;
            }
            matvec(&ws.ffn_a, self.layer_tensor("w2", layer), &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
        }
        rms_norm(&ws.x, &self.tensor("final_norm").data, c.rms_eps, &mut ws.h);
        matvec(&ws.h, self.tensor("w_out"), &mut ws.logits);
        ws.logits.clone()
    }

    /// One autoregressive step for a **batch** of independent sequences:
    /// appends each sequence's token to its own KV cache and returns the
    /// per-sequence next-token logits. Equivalent to calling
    /// [`Model::decode_step`] once per sequence — bit-identically so —
    /// but the per-(layer, head) attention loop runs over the whole
    /// (sequence × head) grid at once, sharded across scoped worker
    /// threads with per-thread [`AttentionPlan`] shards and deterministic
    /// shard-order stat merging.
    pub fn decode_step_batch(
        &self,
        tokens: &[u32],
        kvs: &mut [&mut KvState],
        policy: AttentionPolicy,
        bws: &mut BatchWorkspace,
        stats: &mut StepStats,
    ) -> Vec<Vec<f32>> {
        let mut views: Vec<SharedKvMut> = kvs
            .iter_mut()
            .map(|kv| SharedKvMut::unshared(&mut **kv))
            .collect();
        let groups: Vec<Vec<usize>> = (0..views.len()).map(|i| vec![i]).collect();
        self.decode_step_batch_shared(tokens, &mut views, &groups, policy, bws, stats)
    }

    /// [`Model::decode_step_batch`] over shared-prefix KV views, with the
    /// batch partitioned into **groups**: members of one group share an
    /// identical segment chain and their decode rows are answered as one
    /// multi-query HSR traversal per chain segment per head (the
    /// cross-sequence amortization of PR 3's query fan-out, now on the
    /// serving path). Groups must partition `0..seqs.len()`; singleton
    /// groups with empty prefixes follow the exact per-sequence code
    /// path, so this is bit-identical to per-sequence `decode_step` for
    /// every grouping and thread count.
    pub fn decode_step_batch_shared(
        &self,
        tokens: &[u32],
        seqs: &mut [SharedKvMut<'_, '_>],
        groups: &[Vec<usize>],
        policy: AttentionPolicy,
        bws: &mut BatchWorkspace,
        stats: &mut StepStats,
    ) -> Vec<Vec<f32>> {
        let c = &self.cfg;
        let b = tokens.len();
        assert_eq!(seqs.len(), b);
        if b == 0 {
            return Vec::new();
        }
        debug_assert_eq!(
            groups.iter().map(|g| g.len()).sum::<usize>(),
            b,
            "groups must partition the batch"
        );
        let positions: Vec<usize> = seqs.iter().map(|s| s.len()).collect();
        bws.x.resize(b * c.d_model, 0.0);
        bws.q.resize(b * c.d_model, 0.0);
        bws.att.resize(b * c.d_model, 0.0);
        let jobs = groups.len() * c.n_heads;
        // In auto mode (threads = 0), parallelize only when the grid
        // carries enough attention work to amortize the per-layer thread
        // spawns; total cached tokens across the batch's heads is the
        // per-layer cost proxy. Short contexts stay serial (outputs are
        // bit-identical either way); an explicit thread count is honored
        // as given so tests can pin the parallel path.
        let grid_work: usize = positions.iter().map(|&p| (p + 1) * c.n_heads).sum();
        let workers = if bws.threads == 0 && grid_work < 4096 {
            1
        } else {
            crate::kernel::effective_threads(bws.threads, jobs)
        };
        while bws.shards.len() < workers {
            bws.shards.push(AttentionPlan::new());
        }

        // Embedding.
        let emb = self.tensor("tok_emb");
        for (s, &tok) in tokens.iter().enumerate() {
            bws.x[s * c.d_model..(s + 1) * c.d_model]
                .copy_from_slice(emb.row(tok as usize));
        }

        // Per-sequence chain views, copied once per step (the refs carry
        // the pool lifetime, not the `seqs` borrow, so the per-layer
        // sweep below can still take the tails mutably).
        let prefix_of: Vec<(Vec<(&KvState, usize)>, usize)> = seqs
            .iter()
            .map(|s| (s.prefix.segments.clone(), s.prefix.len))
            .collect();

        for layer in 0..c.n_layers {
            // --- attention block: projections + RoPE + cache append ---
            // (serial per sequence; the matvecs reuse one temp workspace)
            for s in 0..b {
                let xs = &bws.x[s * c.d_model..(s + 1) * c.d_model];
                let qs = &mut bws.q[s * c.d_model..(s + 1) * c.d_model];
                let tmp = &mut bws.tmp;
                rms_norm(xs, &self.layer_tensor("attn_norm", layer).data, c.rms_eps, &mut tmp.h);
                matvec(&tmp.h, self.layer_tensor("wq", layer), qs);
                matvec(&tmp.h, self.layer_tensor("wk", layer), &mut tmp.k);
                matvec(&tmp.h, self.layer_tensor("wv", layer), &mut tmp.v);
                for head in 0..c.n_heads {
                    let (hs, he) = (head * c.d_head, (head + 1) * c.d_head);
                    apply_rope(&mut qs[hs..he], positions[s], c.rope_theta);
                    apply_rope(&mut tmp.k[hs..he], positions[s], c.rope_theta);
                    seqs[s]
                        .tail
                        .head_mut(layer, head)
                        .append(&tmp.k[hs..he], &tmp.v[hs..he]);
                }
            }
            // --- attention sweep: the (group × head) grid, sharded ---
            {
                // Per-(sequence, head) row items, regrouped into one job
                // per (group, head): members' rows answer through one
                // shared traversal of each chain segment.
                let mut row_of: Vec<Vec<Option<RowJob>>> = Vec::with_capacity(b);
                for ((skv, q_row), att_row) in seqs
                    .iter_mut()
                    .zip(bws.q.chunks(c.d_model))
                    .zip(bws.att.chunks_mut(c.d_model))
                {
                    let mut rows = Vec::with_capacity(c.n_heads);
                    for ((hk, qh), oh) in skv
                        .tail
                        .layer_heads_mut(layer)
                        .iter_mut()
                        .zip(q_row.chunks(c.d_head))
                        .zip(att_row.chunks_mut(c.d_head))
                    {
                        rows.push(Some((hk, qh, oh)));
                    }
                    row_of.push(rows);
                }
                let mut grid: Vec<GroupJob> = Vec::with_capacity(jobs);
                for members in groups {
                    let (segs, plen) = &prefix_of[members[0]];
                    for h in 0..c.n_heads {
                        let mut rows = Vec::with_capacity(members.len());
                        for &m in members {
                            rows.push(
                                row_of[m][h]
                                    .take()
                                    .expect("each (sequence, head) is in exactly one group"),
                            );
                        }
                        let prefix: Vec<(&HeadKv, usize)> = segs
                            .iter()
                            .map(|&(kv, start)| (kv.head(layer, h), start))
                            .collect();
                        grid.push(GroupJob { prefix, prefix_len: *plen, rows });
                    }
                }
                if workers <= 1 {
                    let scratch = &mut bws.shards[0];
                    for job in grid.iter_mut() {
                        attend_group(
                            &job.prefix,
                            job.prefix_len,
                            &mut job.rows,
                            c.d_head,
                            policy,
                            scratch,
                            stats,
                        );
                    }
                } else {
                    let per = (jobs + workers - 1) / workers;
                    let d_head = c.d_head;
                    std::thread::scope(|scope| {
                        let mut handles = Vec::with_capacity(workers);
                        for (chunk, scratch) in
                            grid.chunks_mut(per).zip(bws.shards.iter_mut())
                        {
                            handles.push(scope.spawn(move || {
                                let mut local = StepStats::default();
                                for job in chunk.iter_mut() {
                                    attend_group(
                                        &job.prefix,
                                        job.prefix_len,
                                        &mut job.rows,
                                        d_head,
                                        policy,
                                        scratch,
                                        &mut local,
                                    );
                                }
                                local
                            }));
                        }
                        // Merge in shard order: deterministic aggregate.
                        for h in handles {
                            stats.add(&h.join().expect("attention worker panicked"));
                        }
                    });
                }
            }
            // --- output projection + residual + MLP (serial per seq) ---
            for s in 0..b {
                let xs = &mut bws.x[s * c.d_model..(s + 1) * c.d_model];
                let att_s = &bws.att[s * c.d_model..(s + 1) * c.d_model];
                let tmp = &mut bws.tmp;
                matvec(att_s, self.layer_tensor("wo", layer), &mut tmp.proj);
                for (x, &p) in xs.iter_mut().zip(&tmp.proj) {
                    *x += p;
                }
                rms_norm(xs, &self.layer_tensor("mlp_norm", layer).data, c.rms_eps, &mut tmp.h);
                matvec(&tmp.h, self.layer_tensor("w1", layer), &mut tmp.ffn_a);
                matvec(&tmp.h, self.layer_tensor("w3", layer), &mut tmp.ffn_b);
                for (a, &bb) in tmp.ffn_a.iter_mut().zip(&tmp.ffn_b) {
                    *a = silu(*a) * bb;
                }
                matvec(&tmp.ffn_a, self.layer_tensor("w2", layer), &mut tmp.proj);
                for (x, &p) in xs.iter_mut().zip(&tmp.proj) {
                    *x += p;
                }
            }
        }
        // Final norm + output head per sequence.
        let mut all = Vec::with_capacity(b);
        for s in 0..b {
            let xs = &bws.x[s * c.d_model..(s + 1) * c.d_model];
            let tmp = &mut bws.tmp;
            rms_norm(xs, &self.tensor("final_norm").data, c.rms_eps, &mut tmp.h);
            matvec(&tmp.h, self.tensor("w_out"), &mut tmp.logits);
            all.push(tmp.logits.clone());
        }
        all
    }

    /// Prefill a prompt through the decode path (token by token) and
    /// return all logits [t, vocab]. `policy` applies from position
    /// `sparse_from` onward (early positions have tiny caches where
    /// sparsity is meaningless).
    pub fn prefill(
        &self,
        tokens: &[u32],
        kv: &mut KvState,
        policy: AttentionPolicy,
        stats: &mut StepStats,
    ) -> Vec<f32> {
        let mut ws = Workspace::new(self);
        let mut all = Vec::with_capacity(tokens.len() * self.cfg.vocab);
        for &t in tokens {
            let logits = self.decode_step(t, kv, policy, &mut ws, stats);
            all.extend_from_slice(&logits);
        }
        all
    }

    /// Full dense forward (reference path for golden tests): [t, vocab].
    pub fn forward_full(&self, tokens: &[u32]) -> Vec<f32> {
        let mut kv = KvState::new(self.cfg.n_layers, self.cfg.n_heads, self.cfg.d_head, None);
        let mut stats = StepStats::default();
        self.prefill(tokens, &mut kv, AttentionPolicy::Dense, &mut stats)
    }

    /// Mean negative log-likelihood (nats/byte) of `tokens[1..]` given the
    /// running prefix under the given policy — exp() of this is the
    /// perplexity of Section 7.
    pub fn nll(&self, tokens: &[u32], policy: AttentionPolicy) -> f64 {
        assert!(tokens.len() >= 2);
        let mut kv = KvState::new(
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.d_head,
            Some(crate::hsr::HsrBackend::BallTree),
        );
        let mut ws = Workspace::new(self);
        let mut stats = StepStats::default();
        let mut total = 0f64;
        for i in 0..tokens.len() - 1 {
            let logits = self.decode_step(tokens[i], &mut kv, policy, &mut ws, &mut stats);
            let lse = log_sum_exp(&logits);
            total += (lse - logits[tokens[i + 1] as usize]) as f64;
        }
        total / (tokens.len() - 1) as f64
    }
}

/// One head of cached attention under a policy. `out` has length d_head.
/// All buffers come from the caller's [`AttentionPlan`] (one per
/// thread). The sparse branch is a thin caller of the session layer:
/// `session::plan_top_r_row` runs Algorithm 1's scored HSR query with
/// the per-head calibrated threshold (full-half-space fallback on a
/// miss, quantile recalibration for the next step), and the session's
/// bucketed `execute_plan` evaluates the planned row — so no inner
/// product on this path is ever computed twice, and the evaluation code
/// is literally the one the decode/prefill engines run.
fn attend_head(
    hk: &mut super::kv::HeadKv,
    q: &[f32],
    d_head: usize,
    policy: AttentionPolicy,
    plan: &mut AttentionPlan,
    out: &mut [f32],
    stats: &mut StepStats,
) {
    let n = hk.len();
    stats.dense_equivalent += n;
    let r = match policy {
        AttentionPolicy::Dense => n,
        AttentionPolicy::TopR(spec) => spec.r_for(n),
    };
    if r >= n {
        // Dense (or top-r covering everything): one blocked scoring pass,
        // one fused softmax — no index set, no second dot pass.
        crate::attention::softmax::softmax_attention_row(
            q,
            &hk.keys,
            &hk.values,
            d_head,
            &mut plan.buf.scores,
            out,
        );
        stats.attended += n;
        return;
    }

    // --- Algorithm 1 inference: plan (scored HSR query + exact top-r +
    // calibration) then execute (bucketed gather), via the session API.
    // `HeadKv` is itself the `HalfSpaceReport` the planner queries.
    let new_calib = session::plan_top_r_row(
        &*hk,
        q,
        r,
        hk.calib_threshold,
        CALIBRATION_SLACK,
        plan,
    );
    if new_calib.is_some() {
        hk.calib_threshold = new_calib;
    }
    stats.fallbacks += plan.fallbacks;
    stats.hsr.add(&plan.stats);
    stats.attended += plan.fired[0];
    session::execute_plan(plan, &hk.values, d_head, out);
}

/// One (tail head, query row, output row) attention job row.
type RowJob<'r> = (&'r mut HeadKv, &'r [f32], &'r mut [f32]);

/// One unit of the batched attention sweep: the member rows of one
/// shared-prefix group at one (layer, head), plus that head's chain
/// segments. A singleton job with no prefix is exactly the historical
/// per-(sequence, head) grid cell.
struct GroupJob<'p, 'r> {
    /// This head's slice of each chain segment, with global start
    /// offsets (empty → unshared sequence).
    prefix: Vec<(&'p HeadKv, usize)>,
    prefix_len: usize,
    rows: Vec<RowJob<'r>>,
}

/// Resolved value storage for one shared-prefix row: global key index
/// `j` maps to a chain segment row (`j < prefix_len`) or a private tail
/// row. The execute phase axpy-accumulates through this resolver in
/// ascending key order — bit-identical to contiguous storage.
struct SegmentedRows<'a, 'p> {
    prefix: &'a [(&'p HeadKv, usize)],
    prefix_len: usize,
    tail: &'a HeadKv,
}

impl session::ValueRows for SegmentedRows<'_, '_> {
    fn value_row(&self, j: usize) -> &[f32] {
        if j < self.prefix_len {
            for &(h, start) in self.prefix {
                if j < start + h.len() {
                    return h.value_row(j - start);
                }
            }
            unreachable!("prefix key index {j} beyond the segment chain");
        }
        self.tail.value_row(j - self.prefix_len)
    }
}

/// Dense softmax attention for one row over the segmented layout:
/// chain segments in order, then the tail. With no prefix this is the
/// contiguous [`crate::attention::softmax::softmax_attention_row`].
fn dense_shared_row(
    prefix: &[(&HeadKv, usize)],
    tail: &HeadKv,
    q: &[f32],
    d_head: usize,
    plan: &mut AttentionPlan,
    out: &mut [f32],
) {
    if prefix.is_empty() {
        crate::attention::softmax::softmax_attention_row(
            q,
            &tail.keys,
            &tail.values,
            d_head,
            &mut plan.buf.scores,
            out,
        );
        return;
    }
    let mut parts: Vec<(&[f32], &[f32])> = Vec::with_capacity(prefix.len() + 1);
    for &(h, _) in prefix {
        parts.push((h.keys.as_slice(), h.values.as_slice()));
    }
    parts.push((tail.keys.as_slice(), tail.values.as_slice()));
    crate::attention::softmax::softmax_attention_row_segmented(
        q,
        &parts,
        d_head,
        &mut plan.buf.scores,
        out,
    );
}

/// Attention for one shared-prefix group at one (layer, head) — the
/// member rows plus that head's chain segment slices (what a
/// [`GroupJob`] carries in the batched sweep; the single-token path
/// passes a reused buffer and a stack row instead). The
/// singleton/no-prefix case is a straight call into [`attend_head`]
/// (same floats, same stats — the pre-kvstore path). Otherwise: dense /
/// covering-r rows evaluate
/// individually over the segmented layout, and the calibrated top-r
/// rows plan as ONE query block — a shared multi-query traversal per
/// chain segment plus per-member tail scans
/// ([`session::plan_top_r_shared`]) — then execute row-by-row through
/// the segment-resolving gather. Selected sets are exact top-r
/// regardless of calibration, so outputs are bit-identical to the
/// per-sequence path; only the traversal work (and therefore
/// [`QueryStats`]) shrinks with group fan-out.
fn attend_group(
    prefix: &[(&HeadKv, usize)],
    prefix_len: usize,
    rows: &mut [RowJob<'_>],
    d_head: usize,
    policy: AttentionPolicy,
    plan: &mut AttentionPlan,
    stats: &mut StepStats,
) {
    if prefix.is_empty() && rows.len() == 1 {
        let (tail, q, out) = &mut rows[0];
        attend_head(tail, q, d_head, policy, plan, out, stats);
        return;
    }
    // The small Vecs below (grouped/rs/calibs + the &dyn views) are
    // rebuilt per (layer, group, head) job: the reference vectors cannot
    // persist in a lifetime-free Scratch, and their cost is amortized
    // over the whole member block's traversal + gather work (a grouped
    // job only exists when there IS a block to amortize over; the
    // singleton/no-prefix hot path above allocates nothing).
    let mut grouped: Vec<usize> = Vec::new();
    for (m, row) in rows.iter_mut().enumerate() {
        let (tail, q, out) = &mut *row;
        let n = prefix_len + tail.len();
        stats.dense_equivalent += n;
        let r = match policy {
            AttentionPolicy::Dense => n,
            AttentionPolicy::TopR(spec) => spec.r_for(n),
        };
        if r >= n {
            dense_shared_row(prefix, &**tail, q, d_head, plan, &mut **out);
            stats.attended += n;
        } else {
            grouped.push(m);
        }
    }
    if grouped.is_empty() {
        return;
    }
    // Pack the group's query rows and collect per-member specs.
    plan.buf.qblock.clear();
    for &m in &grouped {
        plan.buf.qblock.extend_from_slice(rows[m].1);
    }
    let rs: Vec<usize> = grouped
        .iter()
        .map(|&m| {
            let n = prefix_len + rows[m].0.len();
            match policy {
                AttentionPolicy::Dense => n, // unreachable: dense rows covered above
                AttentionPolicy::TopR(spec) => spec.r_for(n),
            }
        })
        .collect();
    let calibs: Vec<Option<f32>> = grouped
        .iter()
        .map(|&m| rows[m].0.calib_threshold)
        .collect();
    let mut new_calibs: Vec<Option<f32>> = Vec::with_capacity(grouped.len());
    {
        let prefix_dyn: Vec<(&dyn HalfSpaceReport, usize)> = prefix
            .iter()
            .map(|&(h, start)| (h as &dyn HalfSpaceReport, start))
            .collect();
        let tails: Vec<&dyn HalfSpaceReport> = grouped
            .iter()
            .map(|&m| &*rows[m].0 as &dyn HalfSpaceReport)
            .collect();
        session::plan_top_r_shared(
            &prefix_dyn,
            prefix_len,
            d_head,
            &tails,
            &rs,
            &calibs,
            CALIBRATION_SLACK,
            plan,
            &mut new_calibs,
        );
    }
    stats.hsr.add(&plan.stats);
    stats.fallbacks += plan.fallbacks;
    for (gi, &m) in grouped.iter().enumerate() {
        let (tail, _q, out) = &mut rows[m];
        if new_calibs[gi].is_some() {
            tail.calib_threshold = new_calibs[gi];
        }
        stats.attended += plan.fired[gi];
        let values = SegmentedRows { prefix, prefix_len, tail: &**tail };
        session::execute_plan_row_resolved(plan, gi, d_head, &values, &mut **out);
    }
}

/// Greedy argmax sampling.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Temperature sampling with a deterministic RNG.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let scaled: Vec<f32> = logits.iter().map(|&l| l / temperature).collect();
    let probs = crate::attention::softmax::softmax(&scaled);
    rng.categorical(&probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_zero_is_identity() {
        let mut x = vec![0.3f32, -1.2, 0.7, 2.0];
        let orig = x.clone();
        apply_rope(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![0.3f32, -1.2, 0.7, 2.0, 1.0, -0.5, 0.1, 0.9];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        apply_rope(&mut x, 123, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn rope_relative_property() {
        // <R_p x, R_q y> depends only on p − q.
        let x = vec![0.5f32, -0.3, 1.1, 0.2];
        let y = vec![-0.7f32, 0.9, 0.4, -1.3];
        let ip = |p: usize, qpos: usize| {
            let mut a = x.clone();
            let mut b = y.clone();
            apply_rope(&mut a, p, 10000.0);
            apply_rope(&mut b, qpos, 10000.0);
            crate::hsr::dot(&a, &b)
        };
        assert!((ip(7, 3) - ip(11, 7)).abs() < 1e-4);
    }

    #[test]
    fn rspec_scaling() {
        assert_eq!(RSpec::Fixed(16).r_for(1000), 16);
        assert_eq!(RSpec::paper().r_for(1024), (1024f64.powf(0.8).ceil()) as usize);
        assert_eq!(RSpec::Pow(0.8).r_for(1), 1);
    }

    /// Tiny deterministic model so the batched-decode parity test runs
    /// without exported artifacts (see [`Model::synthetic`]).
    fn tiny_model(_rng: &mut crate::util::rng::Rng) -> Model {
        Model::synthetic(200, 2, 2, 4)
    }

    /// `decode_step_batch` must be bit-identical to per-sequence
    /// `decode_step` — same logits and the same evolution of the per-head
    /// calibration state — for every thread count, under both the dense
    /// and the calibrated top-r policy.
    #[test]
    fn batched_decode_step_matches_serial_bitwise() {
        let mut rng = crate::util::rng::Rng::new(200);
        let model = tiny_model(&mut rng);
        let c = model.cfg.clone();
        let steps = 12usize;
        let b = 3usize;
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|_| (0..steps).map(|_| rng.below(c.vocab) as u32).collect())
            .collect();
        for policy in [
            AttentionPolicy::Dense,
            AttentionPolicy::TopR(RSpec::Fixed(3)),
        ] {
            // Serial reference: each sequence decoded independently.
            let mut serial_logits: Vec<Vec<f32>> = Vec::new();
            let mut serial_stats = StepStats::default();
            for p in &prompts {
                let mut kv = KvState::new(
                    c.n_layers,
                    c.n_heads,
                    c.d_head,
                    Some(crate::hsr::HsrBackend::BallTree),
                );
                let mut ws = Workspace::new(&model);
                let mut last = Vec::new();
                for &tok in p {
                    last = model.decode_step(tok, &mut kv, policy, &mut ws, &mut serial_stats);
                }
                serial_logits.push(last);
            }
            for threads in [1usize, 2, 3] {
                let mut kvs: Vec<KvState> = (0..b)
                    .map(|_| {
                        KvState::new(
                            c.n_layers,
                            c.n_heads,
                            c.d_head,
                            Some(crate::hsr::HsrBackend::BallTree),
                        )
                    })
                    .collect();
                let mut bws = BatchWorkspace::new(&model);
                bws.threads = threads;
                let mut batch_stats = StepStats::default();
                let mut batch_logits: Vec<Vec<f32>> = Vec::new();
                for t in 0..steps {
                    let tokens: Vec<u32> = prompts.iter().map(|p| p[t]).collect();
                    let mut refs: Vec<&mut KvState> = kvs.iter_mut().collect();
                    batch_logits = model.decode_step_batch(
                        &tokens,
                        &mut refs,
                        policy,
                        &mut bws,
                        &mut batch_stats,
                    );
                }
                assert_eq!(serial_logits, batch_logits, "threads={threads} {policy:?}");
                assert_eq!(serial_stats.attended, batch_stats.attended, "threads={threads}");
                assert_eq!(serial_stats.fallbacks, batch_stats.fallbacks, "threads={threads}");
                assert_eq!(serial_stats.hsr, batch_stats.hsr, "threads={threads}");
            }
        }
    }

    #[test]
    fn argmax_and_sample() {
        let logits = vec![0.0f32, 5.0, -1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = crate::util::rng::Rng::new(0);
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // Low temperature: overwhelmingly picks the max.
        let picks: Vec<u32> = (0..50).map(|_| sample(&logits, 0.1, &mut rng)).collect();
        assert!(picks.iter().filter(|&&p| p == 1).count() > 45);
    }
}
