//! Reusable per-thread scratch arena for the attention hot paths.
//!
//! Algorithm 1/2 inner loops need four working buffers per query row: the
//! HSR report, its raw scores, the top-r selection, and the activation
//! values that the weighted sum consumes (exps for softmax, ReLU^α powers
//! for ReLU). Allocating them per row costs more than the attention math
//! itself at paper-regime sparsity (k ≈ n^{4/5} entries of a few bytes),
//! so the engine threads one [`Scratch`] through every row instead:
//! decode structures own one, serial prefill owns one, and each parallel
//! prefill shard owns one. Buffers only ever grow (`clear` keeps
//! capacity), so steady state performs zero heap allocation per row.

/// Reusable buffers for one attention worker (one thread).
#[derive(Debug, Default)]
pub struct Scratch {
    /// HSR-reported key indices.
    pub fire: Vec<u32>,
    /// Raw inner products parallel to `fire` (score-carrying queries).
    pub scores: Vec<f32>,
    /// Top-r subset of `fire` (global key indices, ascending).
    pub selected: Vec<u32>,
    /// Activation buffer for the evaluated subset: scaled scores in,
    /// exp/ReLU^α weights out (transformed in place by the row kernels).
    pub exps: Vec<f32>,
    // --- batched-decode extensions (one worker's shard of B rows) ---
    /// Argsort permutation for canonical ascending-index row order.
    pub perm: Vec<u32>,
    /// CSR fired indices across the shard's rows (ascending per row).
    pub idx: Vec<u32>,
    /// Normalized attention weights parallel to `idx`.
    pub w: Vec<f32>,
    /// CSR row boundaries into `idx`/`w` (len = rows + 1).
    pub row_ptr: Vec<usize>,
    /// Per-row 1/normalizer (0.0 marks a degenerate all-zero row).
    pub inv: Vec<f32>,
    /// Sorted, deduped union of the shard's fired indices.
    pub union_idx: Vec<u32>,
    /// Value rows gathered for the current union bucket.
    pub packed: Vec<f32>,
    /// Per-row walk cursors into the CSR arrays (bucket sweep state).
    pub cursor: Vec<usize>,
    // --- multi-query (shared HSR traversal) extensions ---
    /// Per-row raw-score thresholds for one query block.
    pub bs: Vec<f32>,
    /// Per-row report buffers for one query block (fired indices).
    pub many_idx: Vec<Vec<u32>>,
    /// Per-row carried raw scores, parallel to `many_idx`.
    pub many_scores: Vec<Vec<f32>>,
    /// Contiguous `[rows, d]` copy of a shared-prefix group's query rows
    /// (the members' q vectors live in per-sequence buffers; the block
    /// traversal wants them packed).
    pub qblock: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pre-size every buffer for reports of about `k` entries.
    pub fn with_capacity(k: usize) -> Scratch {
        Scratch {
            fire: Vec::with_capacity(k),
            scores: Vec::with_capacity(k),
            selected: Vec::with_capacity(k),
            exps: Vec::with_capacity(k),
            ..Scratch::default()
        }
    }

    /// Clear all buffers, retaining capacity.
    pub fn clear(&mut self) {
        self.fire.clear();
        self.scores.clear();
        self.selected.clear();
        self.exps.clear();
        self.perm.clear();
        self.idx.clear();
        self.w.clear();
        self.row_ptr.clear();
        self.inv.clear();
        self.union_idx.clear();
        self.packed.clear();
        self.cursor.clear();
        self.bs.clear();
        for v in self.many_idx.iter_mut() {
            v.clear();
        }
        for v in self.many_scores.iter_mut() {
            v.clear();
        }
        self.qblock.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clear_retains_capacity() {
        let mut s = Scratch::with_capacity(64);
        s.fire.extend(0..100u32);
        s.scores.extend((0..100).map(|x| x as f32));
        let cap_fire = s.fire.capacity();
        let cap_scores = s.scores.capacity();
        s.clear();
        assert!(s.fire.is_empty() && s.scores.is_empty());
        assert_eq!(s.fire.capacity(), cap_fire);
        assert_eq!(s.scores.capacity(), cap_scores);
    }
}
