//! Top-k index selection: NN(r, q, K) of Definition B.2 — the indices of
//! the r largest attention scores. Two paths:
//!
//! * [`top_r_indices`] — dense O(n) selection via `select_nth_unstable`
//!   (used by baselines and by Figure-3 evaluation).
//! * [`top_r_of_subset`] — selection restricted to an HSR-reported
//!   candidate set, the "report superset, then top-r" step Theorem 4.2
//!   needs when the threshold b over-reports.

/// Indices of the r largest values in `scores` (ties broken arbitrarily),
/// returned sorted by index. r is clamped to n.
pub fn top_r_indices(scores: &[f32], r: usize) -> Vec<u32> {
    let n = scores.len();
    let r = r.min(n);
    if r == 0 {
        return Vec::new();
    }
    if r == n {
        return (0..n as u32).collect();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // Partition so the r largest are in front.
    idx.select_nth_unstable_by(r - 1, |&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(r);
    idx.sort_unstable();
    idx
}

/// Top-r of a candidate subset: `candidates` are key indices, `scores[t]`
/// is the score of `candidates[t]`. Returns global indices, sorted.
pub fn top_r_of_subset(candidates: &[u32], scores: &[f32], r: usize) -> Vec<u32> {
    assert_eq!(candidates.len(), scores.len());
    let k = candidates.len();
    let r = r.min(k);
    if r == 0 {
        return Vec::new();
    }
    let mut order: Vec<u32> = (0..k as u32).collect();
    if r < k {
        order.select_nth_unstable_by(r - 1, |&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        order.truncate(r);
    }
    let mut out: Vec<u32> = order.into_iter().map(|t| candidates[t as usize]).collect();
    out.sort_unstable();
    out
}

/// Allocation-free top-r selection that **carries scores along**: fills
/// `out_idx` with the global indices of the r best candidates (ascending)
/// and `out_scores` with their scores, parallel to `out_idx`. This is the
/// hot-path variant used by decode/prefill: the caller already paid for
/// the scores in the HSR query, and downstream softmax consumes them
/// directly, so nothing is re-dotted. Buffers are cleared first and only
/// their capacity is reused across rows.
///
/// Exact score ties at the r-boundary break by **smaller global index**,
/// so the selected *set* depends only on the (index, score) pairs — not
/// on the order the HSR backend reported them in. The shared-prefix KV
/// store relies on this: a chain-of-segments report and a single
/// private-index report enumerate the same candidates in different
/// orders and must still select identical rows.
pub fn top_r_select_into(
    candidates: &[u32],
    scores: &[f32],
    r: usize,
    out_idx: &mut Vec<u32>,
    out_scores: &mut Vec<f32>,
) {
    assert_eq!(candidates.len(), scores.len());
    out_idx.clear();
    out_scores.clear();
    let k = candidates.len();
    let r = r.min(k);
    if r == 0 {
        return;
    }
    if r == k {
        out_idx.extend_from_slice(candidates);
        out_scores.extend_from_slice(scores);
        return;
    }
    // Select candidate *positions* in out_idx, then materialize.
    out_idx.extend(0..k as u32);
    out_idx.select_nth_unstable_by(r - 1, |&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| candidates[a as usize].cmp(&candidates[b as usize]))
    });
    out_idx.truncate(r);
    out_idx.sort_unstable_by_key(|&t| candidates[t as usize]);
    for t in out_idx.iter_mut() {
        out_scores.push(scores[*t as usize]);
        *t = candidates[*t as usize];
    }
}

/// The r-th largest value of `scores` (the selection threshold): the
/// smallest score still inside NN(r, ·, ·). Returns -inf for r == 0.
pub fn rth_largest(scores: &[f32], r: usize) -> f32 {
    if r == 0 || scores.is_empty() {
        return f32::NEG_INFINITY;
    }
    let r = r.min(scores.len());
    let mut v = scores.to_vec();
    let (_, nth, _) = v.select_nth_unstable_by(r - 1, |a, b| {
        b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal)
    });
    *nth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn brute_top_r(scores: &[f32], r: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(r.min(scores.len()));
        idx.sort_unstable();
        idx
    }

    #[test]
    fn matches_brute_force_without_ties() {
        let mut rng = Rng::new(31);
        for _ in 0..50 {
            let n = rng.range(1, 300);
            let r = rng.range(0, n + 3);
            // Gaussian draws: ties have probability ~0.
            let scores = rng.gaussian_vec_f32(n, 1.0);
            assert_eq!(top_r_indices(&scores, r), brute_top_r(&scores, r));
        }
    }

    #[test]
    fn with_ties_returns_correct_count_and_threshold() {
        let scores = vec![1.0f32, 2.0, 2.0, 2.0, 0.0];
        let got = top_r_indices(&scores, 2);
        assert_eq!(got.len(), 2);
        for &i in &got {
            assert!(scores[i as usize] >= 2.0);
        }
    }

    #[test]
    fn subset_selection_matches_dense_when_subset_covers_topr() {
        let mut rng = Rng::new(33);
        let n = 200;
        let scores: Vec<f32> = rng.gaussian_vec_f32(n, 1.0);
        let r = 10;
        let dense = top_r_indices(&scores, r);
        // Candidate set = top 50 (a superset of top 10).
        let cands = top_r_indices(&scores, 50);
        let sub_scores: Vec<f32> = cands.iter().map(|&i| scores[i as usize]).collect();
        assert_eq!(top_r_of_subset(&cands, &sub_scores, r), dense);
    }

    #[test]
    fn select_into_breaks_ties_by_index_order_independently() {
        // Three tied scores at the r-boundary: the kept set must be the
        // smallest global indices, regardless of candidate order.
        let mut idx_buf = Vec::new();
        let mut score_buf = Vec::new();
        let orders: [(&[u32], &[f32]); 2] = [
            (&[10, 30, 20, 40], &[1.0, 0.5, 0.5, 0.5]),
            (&[40, 20, 30, 10], &[0.5, 0.5, 0.5, 1.0]),
        ];
        for (cands, scores) in orders {
            top_r_select_into(cands, scores, 2, &mut idx_buf, &mut score_buf);
            assert_eq!(idx_buf, vec![10, 20], "order-dependent tie-break");
            assert_eq!(score_buf, vec![1.0, 0.5]);
        }
    }

    #[test]
    fn select_into_matches_of_subset() {
        let mut rng = Rng::new(34);
        let mut idx_buf = Vec::new();
        let mut score_buf = Vec::new();
        for _ in 0..30 {
            let k = rng.range(1, 120);
            let r = rng.range(0, k + 4);
            let candidates: Vec<u32> = {
                // Distinct, unsorted global ids.
                let mut c: Vec<u32> = (0..k as u32).map(|x| x * 3 + 1).collect();
                for i in (1..c.len()).rev() {
                    c.swap(i, rng.below(i + 1));
                }
                c
            };
            let scores = rng.gaussian_vec_f32(k, 1.0);
            let want = top_r_of_subset(&candidates, &scores, r);
            top_r_select_into(&candidates, &scores, r, &mut idx_buf, &mut score_buf);
            if r >= k {
                // Full take preserves candidate order instead of sorting.
                assert_eq!(idx_buf, candidates);
            } else {
                assert_eq!(idx_buf, want, "k={k} r={r}");
            }
            assert_eq!(idx_buf.len(), score_buf.len());
            // Carried scores must be each index's own score.
            for (t, &g) in idx_buf.iter().enumerate() {
                let pos = candidates.iter().position(|&c| c == g).unwrap();
                assert_eq!(score_buf[t], scores[pos]);
            }
        }
    }

    #[test]
    fn edge_cases() {
        assert!(top_r_indices(&[], 5).is_empty());
        assert!(top_r_indices(&[1.0], 0).is_empty());
        assert_eq!(top_r_indices(&[1.0, 2.0], 10), vec![0, 1]);
        assert_eq!(rth_largest(&[], 3), f32::NEG_INFINITY);
        assert_eq!(rth_largest(&[5.0, 1.0, 3.0], 2), 3.0);
        assert_eq!(rth_largest(&[5.0, 1.0, 3.0], 100), 1.0);
    }
}
