//! Shared-prefix KV store property tests.
//!
//! The acceptance bar for the kvstore subsystem: N sequences forked from
//! a common prompt must produce **bit-identical** outputs to N
//! independent sequences — across HSR backends (incl. the no-index
//! ablation), both attention policies (dense and calibrated top-r),
//! grouped batched decode at every thread count, and through
//! eviction-then-refault. All tests run on `Model::synthetic` with
//! `d_head <= 8`, where every SIMD dot reduction in the crate is
//! layout-independent, so float equality can be asserted exactly.

use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{GenerationParams, SchedulerConfig};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::kvstore::{PagePool, PrefixCacheMode, PrefixView, SharedKvMut};
use hsr_attn::model::kv::KvState;
use hsr_attn::model::transformer::{
    argmax, AttentionPolicy, BatchWorkspace, RSpec, StepStats, Workspace,
};
use hsr_attn::model::Model;
use std::sync::Arc;

fn prompt_bytes(seed: u32, len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 11 + seed * 37 + 3) % 256).collect()
}

/// Run `prompts` to completion on a fresh engine, returning each
/// request's generated tokens (by submission order) and the metrics.
fn run_engine(
    model: &Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    mode: PrefixCacheMode,
    prompts: &[Vec<u32>],
    gen: usize,
    cache_tokens: usize,
    prefill_chunk: usize,
) -> (Vec<Vec<u32>>, hsr_attn::engine::metrics::Metrics) {
    let mut eng = Engine::new(
        Arc::clone(model),
        EngineConfig {
            policy,
            hsr_backend: backend,
            prefix_cache: mode,
            cache_capacity_tokens: cache_tokens,
            block_tokens: 16,
            scheduler: SchedulerConfig { prefill_chunk, ..Default::default() },
            ..Default::default()
        },
    );
    let ids: Vec<u64> = prompts
        .iter()
        .map(|p| {
            eng.submit(
                p.clone(),
                GenerationParams { max_new_tokens: gen, ..Default::default() },
            )
        })
        .collect();
    eng.run_to_completion();
    let mut done = eng.take_finished();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), ids.len(), "every request must complete");
    let metrics = eng.metrics.clone();
    (done.into_iter().map(|r| r.tokens).collect(), metrics)
}

/// N sequences forked from a common 48-token prompt (each with a
/// distinct 8-token suffix) generate bit-identically with the prefix
/// cache on vs off, across HSR backends — including the no-index
/// ablation — and both attention policies.
#[test]
fn forked_prompts_match_independent_sequences_all_backends_and_policies() {
    let model = Arc::new(Model::synthetic(77, 2, 2, 8));
    let common = prompt_bytes(0, 48);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|s| {
            let mut p = common.clone();
            p.extend(prompt_bytes(100 + s, 8));
            p
        })
        .collect();
    let cases: Vec<(AttentionPolicy, Option<HsrBackend>)> = vec![
        (AttentionPolicy::Dense, Some(HsrBackend::BallTree)),
        (AttentionPolicy::Dense, None),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::BallTree)),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::Projected)),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::Brute)),
        (AttentionPolicy::TopR(RSpec::paper()), None),
        (AttentionPolicy::TopR(RSpec::Fixed(24)), Some(HsrBackend::BallTree)),
    ];
    for (policy, backend) in cases {
        let (off, m_off) = run_engine(
            &model,
            policy,
            backend,
            PrefixCacheMode::Off,
            &prompts,
            10,
            1 << 16,
            16,
        );
        let (on, m_on) = run_engine(
            &model,
            policy,
            backend,
            PrefixCacheMode::default(),
            &prompts,
            10,
            1 << 16,
            16,
        );
        assert_eq!(off, on, "policy={policy:?} backend={backend:?}");
        assert_eq!(m_off.prefill_tokens_skipped, 0);
        assert!(
            m_on.prefill_tokens_skipped >= 48,
            "cohort must share the common prefix (skipped {})",
            m_on.prefill_tokens_skipped
        );
        assert!(m_on.prefix_hits > 0);
    }
}

/// A cohort of identical prompts cooperatively prefills (each shared
/// token computed exactly once fleet-wide, the rest adopted) and its
/// decode rows run as shared-prefix groups — while still generating
/// exactly what independent sequences generate.
#[test]
fn identical_prompt_cohort_skips_prefill_and_groups_decode() {
    let model = Arc::new(Model::synthetic(78, 2, 2, 8));
    let prompts: Vec<Vec<u32>> = (0..8).map(|_| prompt_bytes(5, 80)).collect();
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let backend = Some(HsrBackend::BallTree);
    let (off, _) = run_engine(
        &model, policy, backend, PrefixCacheMode::Off, &prompts, 8, 1 << 16, 64,
    );
    let (on, m) = run_engine(
        &model,
        policy,
        backend,
        PrefixCacheMode::default(),
        &prompts,
        8,
        1 << 16,
        64,
    );
    assert_eq!(off, on);
    // All 8 outputs identical (identical prompts, greedy sampling).
    for o in &on[1..] {
        assert_eq!(o, &on[0]);
    }
    // 8 × 80 = 640 prompt tokens; the shared 79-token prefix should be
    // computed once and adopted everywhere else.
    assert!(
        m.prefill_tokens_skipped >= 400,
        "cooperative prefill must dominate (skipped {})",
        m.prefill_tokens_skipped
    );
    assert!(
        m.grouped_decode_rows > 0,
        "shared-chain members must decode as one query block"
    );
    assert!(m.prefix_tokens_inserted > 0);
}

/// Evicting a cached prefix under pool pressure and then refaulting the
/// same prompt must not change outputs: the refault re-prefills and
/// republishes, and later clones still match the off-cache baseline.
#[test]
fn eviction_then_refault_is_transparent() {
    let model = Arc::new(Model::synthetic(79, 2, 2, 8));
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let backend = Some(HsrBackend::BallTree);
    let hot = prompt_bytes(1, 60);
    // Interleave the hot prompt with distinct filler prompts; the small
    // pool (256 tokens = 16 blocks) forces cached segments out between
    // reuses of the hot prompt.
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for wave in 0..3u32 {
        prompts.push(hot.clone());
        prompts.push(prompt_bytes(10 + wave, 60));
        prompts.push(prompt_bytes(20 + wave, 60));
    }
    let (off, _) = run_engine(
        &model, policy, backend, PrefixCacheMode::Off, &prompts, 6, 256, 16,
    );
    let (on, m) = run_engine(
        &model,
        policy,
        backend,
        PrefixCacheMode::default(),
        &prompts,
        6,
        256,
        16,
    );
    assert_eq!(off, on);
    // The hot prompt's three runs agree with each other (greedy).
    assert_eq!(on[0], on[3]);
    assert_eq!(on[0], on[6]);
    assert!(
        m.prefix_segments_evicted > 0,
        "a 16-block pool must evict cached prefixes under this load"
    );
}

/// Model-level bitwise check: decoding against (chain of 2 frozen pool
/// segments + private tail) yields logits **bit-identical** to a single
/// private KV cache over the same tokens — for dense and calibrated
/// top-r, on an indexed and an index-free backend.
#[test]
fn shared_layout_logits_bitwise_equal_unshared() {
    let model = Model::synthetic(31, 2, 2, 8);
    let c = model.cfg.clone();
    let prompt = prompt_bytes(9, 60);
    let split_a = 24usize; // segment 1: [0, 24)
    let split_b = 40usize; // segment 2: [24, 40); tail: [40, ...)
    for backend in [Some(HsrBackend::BallTree), None] {
        for policy in [
            AttentionPolicy::Dense,
            AttentionPolicy::TopR(RSpec::paper()),
            AttentionPolicy::TopR(RSpec::Fixed(16)),
        ] {
            // --- unshared reference: one private cache, log every step ---
            let mut ref_logits: Vec<Vec<f32>> = Vec::new();
            let mut kv = KvState::new(c.n_layers, c.n_heads, c.d_head, backend);
            let mut ws = Workspace::new(&model);
            let mut stats = StepStats::default();
            for &t in &prompt {
                ref_logits.push(model.decode_step(t, &mut kv, policy, &mut ws, &mut stats));
            }
            let mut tok = argmax(ref_logits.last().unwrap());
            for _ in 0..6 {
                let l = model.decode_step(tok, &mut kv, policy, &mut ws, &mut stats);
                tok = argmax(&l);
                ref_logits.push(l);
            }

            // --- shared layout: freeze [0,24) and [24,40) into pool
            // segments (sourced from an independent prefill — the model
            // is deterministic, so the rows are identical), then drive
            // the tail through the shared view. ---
            let mut src = KvState::new(c.n_layers, c.n_heads, c.d_head, backend);
            let mut ws_src = Workspace::new(&model);
            let mut st_src = StepStats::default();
            for &t in &prompt[..split_b] {
                model.decode_step(t, &mut src, policy, &mut ws_src, &mut st_src);
            }
            let mut pool = PagePool::new(1 << 14, 16, backend);
            let id_a = pool
                .create_segment(&prompt[..split_a], 0, &src, 0)
                .expect("pool fits segment a");
            let id_b = pool
                .create_segment(&prompt[split_a..split_b], split_a, &src, split_a)
                .expect("pool fits segment b");
            let seg_a = pool.segment(id_a);
            let seg_b = pool.segment(id_b);
            let mut tail = KvState::new(c.n_layers, c.n_heads, c.d_head, backend);
            let mut ws2 = Workspace::new(&model);
            let mut st2 = StepStats::default();
            let mut shared_logits: Vec<Vec<f32>> = Vec::new();
            for &t in &prompt[split_b..] {
                let mut skv = SharedKvMut {
                    prefix: PrefixView {
                        segments: vec![(&seg_a.kv, 0), (&seg_b.kv, split_a)],
                        len: split_b,
                    },
                    tail: &mut tail,
                };
                shared_logits.push(model.decode_step_shared(t, &mut skv, policy, &mut ws2, &mut st2));
            }
            let mut tok = argmax(shared_logits.last().unwrap());
            for _ in 0..6 {
                let mut skv = SharedKvMut {
                    prefix: PrefixView {
                        segments: vec![(&seg_a.kv, 0), (&seg_b.kv, split_a)],
                        len: split_b,
                    },
                    tail: &mut tail,
                };
                let l = model.decode_step_shared(tok, &mut skv, policy, &mut ws2, &mut st2);
                tok = argmax(&l);
                shared_logits.push(l);
            }
            assert_eq!(
                &ref_logits[split_b..],
                &shared_logits[..],
                "bitwise logits mismatch: backend={backend:?} policy={policy:?}"
            );
        }
    }
}

/// Grouped batched decode (one multi-query traversal per chain segment
/// for the whole group) is bit-identical to per-sequence decode, for
/// every worker thread count.
#[test]
fn grouped_batch_decode_matches_singletons_bitwise() {
    let model = Model::synthetic(32, 2, 2, 8);
    let c = model.cfg.clone();
    let backend = Some(HsrBackend::BallTree);
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let prompt = prompt_bytes(13, 50);
    let b = 3usize;

    // Frozen shared prefix [0, 50) sourced from a deterministic prefill.
    let mut src = KvState::new(c.n_layers, c.n_heads, c.d_head, backend);
    let mut ws = Workspace::new(&model);
    let mut st = StepStats::default();
    for &t in &prompt {
        model.decode_step(t, &mut src, policy, &mut ws, &mut st);
    }
    let mut pool = PagePool::new(1 << 14, 16, backend);
    let id = pool.create_segment(&prompt, 0, &src, 0).expect("fits");
    let seg = pool.segment(id);

    // Per-member divergent continuation tokens.
    let conts: Vec<Vec<u32>> = (0..b as u32).map(|s| prompt_bytes(40 + s, 5)).collect();

    // Build one set of tails by any driver; rebuilt identically below.
    let build_tails = |drive_batched: Option<usize>| -> Vec<Vec<Vec<f32>>> {
        // Returns per-member logits per step.
        let mut tails: Vec<KvState> = (0..b)
            .map(|_| KvState::new(c.n_layers, c.n_heads, c.d_head, backend))
            .collect();
        let mut out: Vec<Vec<Vec<f32>>> = vec![Vec::new(); b];
        match drive_batched {
            None => {
                let mut ws2 = Workspace::new(&model);
                let mut st2 = StepStats::default();
                for step in 0..conts[0].len() {
                    for (m, tail) in tails.iter_mut().enumerate() {
                        let mut skv = SharedKvMut {
                            prefix: PrefixView {
                                segments: vec![(&seg.kv, 0)],
                                len: prompt.len(),
                            },
                            tail,
                        };
                        out[m].push(model.decode_step_shared(
                            conts[m][step],
                            &mut skv,
                            policy,
                            &mut ws2,
                            &mut st2,
                        ));
                    }
                }
            }
            Some(threads) => {
                let mut bws = BatchWorkspace::new(&model);
                bws.threads = threads;
                let mut st2 = StepStats::default();
                let groups = vec![(0..b).collect::<Vec<usize>>()];
                for step in 0..conts[0].len() {
                    let tokens: Vec<u32> = (0..b).map(|m| conts[m][step]).collect();
                    let mut views: Vec<SharedKvMut> = tails
                        .iter_mut()
                        .map(|tail| SharedKvMut {
                            prefix: PrefixView {
                                segments: vec![(&seg.kv, 0)],
                                len: prompt.len(),
                            },
                            tail,
                        })
                        .collect();
                    let logits = model.decode_step_batch_shared(
                        &tokens, &mut views, &groups, policy, &mut bws, &mut st2,
                    );
                    for (m, l) in logits.into_iter().enumerate() {
                        out[m].push(l);
                    }
                }
            }
        }
        out
    };

    let serial = build_tails(None);
    for threads in [1usize, 2, 3] {
        let batched = build_tails(Some(threads));
        assert_eq!(serial, batched, "threads={threads}");
    }
}
