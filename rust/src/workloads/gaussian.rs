//! The paper's Gaussian attention workload: every entry of Q ~ N(0, σ_q²)
//! and of K ~ N(0, σ_k²) i.i.d. (assumptions of Lemma 6.1, Theorem 4.1,
//! Theorem 5.1). V is drawn N(0, 1) — Remark 4.4's subgaussian case.

use crate::attention::threshold::ThresholdParams;
use crate::util::rng::Rng;

/// A generated attention problem instance.
#[derive(Debug, Clone)]
pub struct AttentionInstance {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub m: usize,
    pub n: usize,
    pub d: usize,
    /// The Lemma 6.1 threshold parameters used to draw this instance.
    pub params: ThresholdParams,
}

impl AttentionInstance {
    /// Draw an instance with the standard unit-variance profile.
    pub fn gaussian(rng: &mut Rng, m: usize, n: usize, d: usize) -> AttentionInstance {
        let params = ThresholdParams::standard(d, m);
        AttentionInstance {
            q: rng.gaussian_vec_f32(m * d, params.sigma_q),
            k: rng.gaussian_vec_f32(n * d, params.sigma_k),
            v: rng.gaussian_vec_f32(n * d, 1.0),
            m,
            n,
            d,
            params,
        }
    }

    /// The Lemma 6.1 threshold b for this instance's n.
    pub fn lemma_bias(&self) -> f32 {
        self.params.bias(self.n) as f32
    }

    /// Query row i.
    pub fn query_row(&self, i: usize) -> &[f32] {
        &self.q[i * self.d..(i + 1) * self.d]
    }
}

/// Anisotropic keys: `heavy` dominant coordinates with std `scale`, the
/// rest at std `tail`. Models the concentrated score directions of trained
/// attention key caches (see `hsr::projected`).
pub fn anisotropic_keys(
    rng: &mut Rng,
    n: usize,
    d: usize,
    heavy: usize,
    scale: f64,
    tail: f64,
) -> Vec<f32> {
    let mut pts = vec![0f32; n * d];
    for i in 0..n {
        for j in 0..d {
            let sigma = if j < heavy { scale } else { tail };
            pts[i * d + j] = rng.normal(0.0, sigma) as f32;
        }
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_shapes() {
        let mut rng = Rng::new(1);
        let inst = AttentionInstance::gaussian(&mut rng, 3, 100, 8);
        assert_eq!(inst.q.len(), 24);
        assert_eq!(inst.k.len(), 800);
        assert_eq!(inst.v.len(), 800);
        assert_eq!(inst.query_row(2).len(), 8);
        assert!(inst.lemma_bias() > 0.0);
    }

    #[test]
    fn anisotropic_variance_profile() {
        let mut rng = Rng::new(2);
        let n = 5000;
        let d = 8;
        let k = anisotropic_keys(&mut rng, n, d, 2, 4.0, 0.5);
        let var = |j: usize| {
            let mut s = 0f64;
            let mut s2 = 0f64;
            for i in 0..n {
                let x = k[i * d + j] as f64;
                s += x;
                s2 += x * x;
            }
            s2 / n as f64 - (s / n as f64).powi(2)
        };
        assert!(var(0) > 12.0 && var(0) < 20.0);
        assert!(var(5) < 0.5);
    }
}
