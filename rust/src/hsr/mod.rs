//! Half-Space Reporting (HSR) data structures — the paper's core substrate.
//!
//! The half-space range reporting problem (Definition B.10 of the paper,
//! after Agarwal–Eppstein–Matoušek [AEM92]): preprocess a set S of n points
//! in R^d so that, given a query half-space H = {x : <a, x> >= b}, all
//! points of S ∩ H are reported quickly. The paper's Algorithm 3 interface:
//!
//! ```text
//! INIT(S, n, d)     — build over the key vectors
//! QUERY(a, b)       — report {x in S : sgn(<a,x> - b) >= 0}
//! ```
//!
//! The paper only *cites* the AEM92 asymptotics (Corollary 3.1) and notes
//! (Appendix A) that no implementation of the original structure exists.
//! This module provides working structures spanning the same design space:
//!
//! * [`brute::BruteHsr`] — the naive O(n) scan, the comparator every
//!   theorem's "naive O(mn)" baseline refers to.
//! * [`balltree::BallTreeHsr`] — Part-1 analogue: O(n log n) build,
//!   output-sensitive queries via ball pruning and whole-subtree reporting.
//! * [`layers2d::ConvexLayers2d`] — Part-2 analogue, exact for d = 2:
//!   O(n log n) build, O((1 + k_layers) log n + k) query via convex-layer
//!   peeling — genuinely O(log n + k)-shaped where it is computable.
//! * [`dynamic::DynamicHsr`] — the logarithmic method over any static
//!   backend, giving amortized-logarithmic inserts (Theorem B.11's update
//!   clause); this is what the decode engine uses as keys are appended.
//!
//! All queries are **exact** (no approximate nearest-neighbour relaxation —
//! the paper contrasts itself with [FA23] on precisely this point).

pub mod balltree;
pub mod brute;
pub mod dynamic;
pub mod layers2d;
pub mod projected;

use crate::util::rng::Rng;

/// Instrumentation counters filled in by `query_into`, used by tests and
/// benches to verify output-sensitivity (e.g. that a ball-tree query
/// touches o(n) points on the paper's Gaussian workloads).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Internal nodes / layers visited.
    pub nodes_visited: usize,
    /// Points whose inner product was explicitly evaluated.
    pub points_scanned: usize,
    /// Points reported without evaluation (whole-subtree reports).
    pub bulk_reported: usize,
    /// Total points reported.
    pub reported: usize,
}

impl QueryStats {
    /// Total work proxy: evaluated points + visited nodes.
    pub fn work(&self) -> usize {
        self.nodes_visited + self.points_scanned
    }

    pub fn add(&mut self, other: &QueryStats) {
        self.nodes_visited += other.nodes_visited;
        self.points_scanned += other.points_scanned;
        self.bulk_reported += other.bulk_reported;
        self.reported += other.reported;
    }
}

/// The HSR interface (paper Algorithm 3). Implementations are immutable
/// after construction; dynamic insertion is layered on via
/// [`dynamic::DynamicHsr`].
pub trait HalfSpaceReport: Send + Sync {
    /// Number of indexed points.
    fn len(&self) -> usize;

    /// True if no points are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point dimensionality d.
    fn dim(&self) -> usize;

    /// Report every index i with `<a, x_i> >= b`, appending to `out`
    /// (order unspecified). `stats` accumulates work counters.
    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats);

    /// Score-carrying report: append every qualifying index to `out` AND
    /// its raw inner product `<a, x_i>` to `scores` (parallel vectors,
    /// order unspecified). Downstream consumers (softmax top-r, ReLU
    /// evaluation) already need these inner products — reporting them
    /// here means the dot the query paid for is never recomputed.
    ///
    /// Work counters keep [`HalfSpaceReport::query_into`] semantics:
    /// `points_scanned` counts points evaluated *to decide membership*;
    /// scoring a bulk-reported subtree is attention-side work and is not
    /// counted as a scan.
    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    );

    /// Convenience wrapper returning a fresh, sorted index vector.
    fn query(&self, a: &[f32], b: f32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        self.query_into(a, b, &mut out, &mut stats);
        out.sort_unstable();
        out
    }

    /// Convenience wrapper returning (index, raw-dot) pairs sorted by
    /// index (tests / diagnostics; hot paths use `query_scored_into`).
    fn query_scored(&self, a: &[f32], b: f32) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        let mut scores = Vec::new();
        let mut stats = QueryStats::default();
        self.query_scored_into(a, b, &mut out, &mut scores, &mut stats);
        let mut pairs: Vec<(u32, f32)> = out.into_iter().zip(scores).collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs
    }
}

/// Which static HSR backend to use. The engine and every bench take this
/// as a config knob so backends can be ablated against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HsrBackend {
    /// Naive linear scan (the paper's O(mn) baseline).
    Brute,
    /// Ball-tree partition structure (Part-1 analogue, any d).
    BallTree,
    /// Convex-layers halfplane reporting (Part-2 analogue, d = 2 only).
    Layers2d,
    /// Projection-augmented ball tree (exact; prunes on anisotropic keys).
    Projected,
}

impl HsrBackend {
    pub fn parse(s: &str) -> Option<HsrBackend> {
        match s.to_ascii_lowercase().as_str() {
            "brute" | "naive" => Some(HsrBackend::Brute),
            "balltree" | "ball" | "tree" => Some(HsrBackend::BallTree),
            "layers2d" | "layers" | "convex" => Some(HsrBackend::Layers2d),
            "projected" | "proj" | "pca" => Some(HsrBackend::Projected),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HsrBackend::Brute => "brute",
            HsrBackend::BallTree => "balltree",
            HsrBackend::Layers2d => "layers2d",
            HsrBackend::Projected => "projected",
        }
    }
}

/// Build a static HSR structure over `n` points stored row-major in
/// `points` (length n*d). Panics if `Layers2d` is requested with d != 2.
pub fn build_hsr(
    backend: HsrBackend,
    points: &[f32],
    d: usize,
) -> Box<dyn HalfSpaceReport> {
    match backend {
        HsrBackend::Brute => Box::new(brute::BruteHsr::build(points, d)),
        HsrBackend::BallTree => Box::new(balltree::BallTreeHsr::build(points, d)),
        HsrBackend::Layers2d => {
            assert_eq!(d, 2, "ConvexLayers2d requires d = 2 (got d = {d})");
            Box::new(layers2d::ConvexLayers2d::build(points))
        }
        HsrBackend::Projected => {
            // Default projection rank: enough for trained-key anisotropy.
            Box::new(projected::ProjectedHsr::build(points, d, 6.min(d)))
        }
    }
}

/// Inner product of two equal-length slices. Thin alias for the
/// runtime-dispatched SIMD kernel (kept here because every HSR backend
/// and half the crate imports `hsr::dot`).
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::kernel::simd::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Generate `n` Gaussian points N(0, sigma^2)^d, row-major — the workload
/// of Lemma 6.1. Shared helper for tests and benches.
pub fn gaussian_points(rng: &mut Rng, n: usize, d: usize, sigma: f64) -> Vec<f32> {
    rng.gaussian_vec_f32(n * d, sigma)
}

/// Reference implementation used to cross-check every backend in tests:
/// a straight scan over the raw points.
pub fn reference_query(points: &[f32], d: usize, a: &[f32], b: f32) -> Vec<u32> {
    let n = points.len() / d;
    let mut out = Vec::new();
    for i in 0..n {
        if dot(&points[i * d..(i + 1) * d], a) >= b {
            out.push(i as u32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(1);
        for len in [0usize, 1, 3, 4, 7, 16, 65] {
            let a = r.gaussian_vec_f32(len, 1.0);
            let b = r.gaussian_vec_f32(len, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn backend_parse() {
        assert_eq!(HsrBackend::parse("balltree"), Some(HsrBackend::BallTree));
        assert_eq!(HsrBackend::parse("BRUTE"), Some(HsrBackend::Brute));
        assert_eq!(HsrBackend::parse("convex"), Some(HsrBackend::Layers2d));
        assert_eq!(HsrBackend::parse("projected"), Some(HsrBackend::Projected));
        assert_eq!(HsrBackend::parse("proj"), Some(HsrBackend::Projected));
        assert_eq!(HsrBackend::parse("PCA"), Some(HsrBackend::Projected));
        assert_eq!(HsrBackend::parse("??"), None);
    }

    /// Property test: every backend agrees with the reference scan on
    /// random Gaussian instances across dimensions and thresholds.
    #[test]
    fn backends_match_reference() {
        let mut rng = Rng::new(42);
        for trial in 0..30 {
            let d = [2usize, 3, 8, 16][trial % 4];
            let n = rng.range(1, 400);
            let points = gaussian_points(&mut rng, n, d, 1.0);
            let mut backends: Vec<Box<dyn HalfSpaceReport>> = vec![
                build_hsr(HsrBackend::Brute, &points, d),
                build_hsr(HsrBackend::BallTree, &points, d),
                build_hsr(HsrBackend::Projected, &points, d),
            ];
            if d == 2 {
                backends.push(build_hsr(HsrBackend::Layers2d, &points, d));
            }
            for _ in 0..5 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.5) as f32;
                let expect = reference_query(&points, d, &a, b);
                for be in &backends {
                    let got = be.query(&a, b);
                    assert_eq!(got, expect, "n={n} d={d} b={b}");
                }
            }
        }
    }

    /// Score-carrying queries report exactly the `query_into` set, with
    /// each score equal to the raw inner product — on every backend.
    #[test]
    fn scored_queries_match_plain_plus_dots() {
        let mut rng = Rng::new(43);
        for trial in 0..20 {
            let d = [2usize, 5, 8, 16][trial % 4];
            let n = rng.range(1, 500);
            let points = gaussian_points(&mut rng, n, d, 1.0);
            let mut backends: Vec<Box<dyn HalfSpaceReport>> = vec![
                build_hsr(HsrBackend::Brute, &points, d),
                build_hsr(HsrBackend::BallTree, &points, d),
                build_hsr(HsrBackend::Projected, &points, d),
            ];
            if d == 2 {
                backends.push(build_hsr(HsrBackend::Layers2d, &points, d));
            }
            for _ in 0..4 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.0) as f32;
                let expect_idx = reference_query(&points, d, &a, b);
                for be in &backends {
                    let pairs = be.query_scored(&a, b);
                    let idx: Vec<u32> = pairs.iter().map(|&(i, _)| i).collect();
                    assert_eq!(idx, expect_idx, "n={n} d={d}");
                    for &(i, s) in &pairs {
                        let want = dot(&points[i as usize * d..(i as usize + 1) * d], &a);
                        assert!(
                            (s - want).abs() < 1e-4 * (1.0 + want.abs()),
                            "n={n} d={d} i={i}: {s} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_input() {
        let points: Vec<f32> = vec![];
        for be in [HsrBackend::Brute, HsrBackend::BallTree] {
            let h = build_hsr(be, &points, 4);
            assert!(h.is_empty());
            assert!(h.query(&[1.0, 0.0, 0.0, 0.0], 0.0).is_empty());
        }
    }

    #[test]
    #[should_panic]
    fn layers2d_requires_d2() {
        let points = vec![0.0f32; 12];
        let _ = build_hsr(HsrBackend::Layers2d, &points, 3);
    }
}
