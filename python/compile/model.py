"""L2: the JAX transformer (byte-level char-LM) used by every experiment.

Architecture (chosen to be exactly mirrorable by the rust native forward
in `rust/src/model/transformer.rs` — parity is asserted via golden vectors
exported by `aot.py`):

  token embedding  ->  L x [ RMSNorm -> RoPE multi-head causal softmax
  attention -> residual -> RMSNorm -> SwiGLU MLP -> residual ]
  -> RMSNorm -> output projection (untied)

No biases anywhere; fp32 everywhere (the CPU PJRT plugin and the rust
mirror both run fp32 — bfloat16 is a TPU-only concern noted in
DESIGN.md §Hardware-Adaptation).

The attention inner loop can be routed through the L1 Pallas kernels
(``use_pallas=True``) so the exported decode-step HLO exercises the same
kernel code path the paper's hot spot lives in.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import hsr_attn, ref

VOCAB_SIZE = 256
RMS_EPS = 1e-5
ROPE_THETA = 10000.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    ffn_mult: int = 3  # SwiGLU hidden = ffn_mult * d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return self.ffn_mult * self.d_model

    def param_count(self) -> int:
        per_layer = (
            2 * self.d_model  # two norms
            + 4 * self.d_model * self.d_model  # wq wk wv wo
            + 3 * self.d_model * self.d_ffn  # w1 w3 w2
        )
        return (
            VOCAB_SIZE * self.d_model
            + self.n_layers * per_layer
            + self.d_model
            + self.d_model * VOCAB_SIZE
        )


# The three model sizes standing in for the paper's three LLMs (Figure 3);
# see DESIGN.md §3 substitution note.
CONFIGS = {
    "mini": ModelConfig("mini", d_model=64, n_layers=2, n_heads=2),
    "small": ModelConfig("small", d_model=128, n_layers=4, n_heads=4),
    "base": ModelConfig("base", d_model=192, n_layers=5, n_heads=6),
}


def init_params(cfg: ModelConfig, seed: int) -> dict[str, jax.Array]:
    """Scaled-normal init; names are the contract with the rust loader."""
    rng = np.random.default_rng(seed)

    def normal(shape, scale):
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    p: dict[str, jax.Array] = {}
    p["tok_emb"] = normal((VOCAB_SIZE, cfg.d_model), 0.02)
    attn_scale = 1.0 / math.sqrt(cfg.d_model)
    out_scale = 1.0 / math.sqrt(2.0 * cfg.n_layers * cfg.d_model)
    for i in range(cfg.n_layers):
        p[f"attn_norm.{i}"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"wq.{i}"] = normal((cfg.d_model, cfg.d_model), attn_scale)
        p[f"wk.{i}"] = normal((cfg.d_model, cfg.d_model), attn_scale)
        p[f"wv.{i}"] = normal((cfg.d_model, cfg.d_model), attn_scale)
        p[f"wo.{i}"] = normal((cfg.d_model, cfg.d_model), out_scale)
        p[f"mlp_norm.{i}"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"w1.{i}"] = normal((cfg.d_model, cfg.d_ffn), attn_scale)
        p[f"w3.{i}"] = normal((cfg.d_model, cfg.d_ffn), attn_scale)
        p[f"w2.{i}"] = normal((cfg.d_ffn, cfg.d_model), out_scale)
    p["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["w_out"] = normal((cfg.d_model, VOCAB_SIZE), attn_scale)
    return p


def rms_norm(x, w):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * w


def silu(x):
    return x * jax.nn.sigmoid(x)


def rope_angles(positions, d_head: int):
    """positions: [...]; returns cos/sin of shape [..., d_head//2]."""
    half = d_head // 2
    freqs = ROPE_THETA ** (-(jnp.arange(half, dtype=jnp.float32) * 2.0 / d_head))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions):
    """x: [..., d_head] with consecutive-pair layout (x0,x1),(x2,x3),...;
    positions broadcastable to x[..., 0]'s shape."""
    d_head = x.shape[-1]
    cos, sin = rope_angles(positions, d_head)
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    out = jnp.stack([out_even, out_odd], axis=-1)
    return out.reshape(x.shape)


def _split_heads(x, n_heads: int):
    """[t, d_model] -> [n_heads, t, d_head]."""
    t, dm = x.shape
    return x.reshape(t, n_heads, dm // n_heads).transpose(1, 0, 2)


def _merge_heads(x):
    """[n_heads, t, d_head] -> [t, d_model]."""
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def forward(params: dict[str, Any], cfg: ModelConfig, tokens):
    """Full-sequence forward (training / prefill). tokens: [t] int32 ->
    logits [t, VOCAB_SIZE]."""
    t = tokens.shape[0]
    x = params["tok_emb"][tokens]  # [t, d]
    positions = jnp.arange(t)
    for i in range(cfg.n_layers):
        h = rms_norm(x, params[f"attn_norm.{i}"])
        q = _split_heads(h @ params[f"wq.{i}"], cfg.n_heads)
        k = _split_heads(h @ params[f"wk.{i}"], cfg.n_heads)
        v = _split_heads(h @ params[f"wv.{i}"], cfg.n_heads)
        q = apply_rope(q, positions[None, :])
        k = apply_rope(k, positions[None, :])
        att = jax.vmap(ref.causal_softmax_attention)(q, k, v)  # [H, t, dh]
        x = x + _merge_heads(att) @ params[f"wo.{i}"]
        h = rms_norm(x, params[f"mlp_norm.{i}"])
        x = x + (silu(h @ params[f"w1.{i}"]) * (h @ params[f"w3.{i}"])) @ params[f"w2.{i}"]
    x = rms_norm(x, params["final_norm"])
    return x @ params["w_out"]


def forward_batch(params, cfg: ModelConfig, tokens):
    """tokens: [b, t] -> [b, t, vocab]."""
    return jax.vmap(lambda tk: forward(params, cfg, tk))(tokens)


def loss_fn(params, cfg: ModelConfig, inputs, targets):
    """Mean next-token cross entropy. inputs/targets: [b, t] int32."""
    logits = forward_batch(params, cfg, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# Decode step with KV cache — the generation-decoding scenario (m = Θ(1)).
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, token, pos, k_cache, v_cache, *, use_pallas=False):
    """One autoregressive step against a fixed-size cache.

    token: [] int32; pos: [] int32 (0-based position of this token);
    k_cache/v_cache: [L, H, N, dh] with rows >= pos unused.
    Returns (logits [vocab], new_k [L, H, dh], new_v [L, H, dh]).
    The caller owns cache writes (functional style keeps the HLO lean).
    """
    x = params["tok_emb"][token]  # [d]
    new_ks = []
    new_vs = []
    for i in range(cfg.n_layers):
        h = rms_norm(x, params[f"attn_norm.{i}"])
        q = (h @ params[f"wq.{i}"]).reshape(cfg.n_heads, cfg.d_head)
        k = (h @ params[f"wk.{i}"]).reshape(cfg.n_heads, cfg.d_head)
        v = (h @ params[f"wv.{i}"]).reshape(cfg.n_heads, cfg.d_head)
        q = apply_rope(q, jnp.full((cfg.n_heads,), pos))
        k = apply_rope(k, jnp.full((cfg.n_heads,), pos))
        new_ks.append(k)
        new_vs.append(v)
        # Attend over cache rows [0, pos) plus the current token's k/v,
        # which is placed (functionally) at row `pos` of the cache.
        keys = jax.lax.dynamic_update_slice(
            k_cache[i], k[:, None, :], (0, pos, 0)
        )  # [H, N, dh]
        vals = jax.lax.dynamic_update_slice(v_cache[i], v[:, None, :], (0, pos, 0))
        count = jnp.full((cfg.n_heads,), pos + 1, jnp.int32)
        if use_pallas:
            att = hsr_attn.masked_softmax_attention(q, keys, vals, count)
        else:
            att = ref.masked_softmax_attention(q, keys, vals, count)
        x = x + att.reshape(cfg.d_model) @ params[f"wo.{i}"]
        h = rms_norm(x, params[f"mlp_norm.{i}"])
        x = x + (silu(h @ params[f"w1.{i}"]) * (h @ params[f"w3.{i}"])) @ params[f"w2.{i}"]
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["w_out"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def prefill(params, cfg: ModelConfig, tokens):
    """Prompt prefilling: returns (logits [t, vocab], k_cache [L,H,t,dh],
    v_cache [L,H,t,dh]) — the caches Algorithm 1 is initialized with."""
    t = tokens.shape[0]
    x = params["tok_emb"][tokens]
    positions = jnp.arange(t)
    ks = []
    vs = []
    for i in range(cfg.n_layers):
        h = rms_norm(x, params[f"attn_norm.{i}"])
        q = _split_heads(h @ params[f"wq.{i}"], cfg.n_heads)
        k = _split_heads(h @ params[f"wk.{i}"], cfg.n_heads)
        v = _split_heads(h @ params[f"wv.{i}"], cfg.n_heads)
        q = apply_rope(q, positions[None, :])
        k = apply_rope(k, positions[None, :])
        ks.append(k)
        vs.append(v)
        att = jax.vmap(ref.causal_softmax_attention)(q, k, v)
        x = x + _merge_heads(att) @ params[f"wo.{i}"]
        h = rms_norm(x, params[f"mlp_norm.{i}"])
        x = x + (silu(h @ params[f"w1.{i}"]) * (h @ params[f"w3.{i}"])) @ params[f"w2.{i}"]
    x = rms_norm(x, params["final_norm"])
    return x @ params["w_out"], jnp.stack(ks), jnp.stack(vs)
