//! The continuous-batching serving engine: Algorithm 1 integrated with a
//! paged KV cache, chunked prefill, preemption and metrics — the L3
//! system the paper's decoding/prefilling scenarios live inside.
//!
//! One `Engine` drives one model replica single-threaded (the router in
//! `router.rs` shards requests across engines/threads). Each `step()`:
//!
//! 1. **Admit** waiting requests while the batch and the block pool have
//!    room (prompt blocks are reserved up front — no mid-prefill OOM).
//! 2. **Prefill** admitted sequences in chunks (budgeted per step so long
//!    prompts cannot starve decodes — "chunked prefill").
//! 3. **Decode** one token for every running sequence whose prompt is
//!    done, via the HSR-sparse attention policy.
//! 4. **Preempt** (release blocks, drop KV, requeue) when the pool is
//!    exhausted, per the configured victim policy.

use super::kv_cache::BlockAllocator;
use super::metrics::Metrics;
use super::request::{
    FinishReason, GenerationParams, Request, RequestId, Response, Sequence,
};
use super::scheduler::SchedulerConfig;
use crate::attention::session::AttentionConfig;
use crate::hsr::HsrBackend;
use crate::model::transformer::RSpec;
use crate::model::kv::KvState;
use crate::model::transformer::{
    sample, AttentionPolicy, BatchWorkspace, StepStats, Workspace,
};
use crate::model::Model;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub policy: AttentionPolicy,
    /// HSR backend for per-head indices; None → brute scans inside the
    /// sparse policy (ablation) — ignored under `AttentionPolicy::Dense`.
    pub hsr_backend: Option<HsrBackend>,
    /// Total KV-cache capacity in tokens (across all sequences).
    pub cache_capacity_tokens: usize,
    /// Block granularity of the pool.
    pub block_tokens: usize,
    pub scheduler: SchedulerConfig,
    /// Sampling seed (deterministic engines → reproducible serving runs).
    pub seed: u64,
    /// Base of the request-id space (routers give each worker a disjoint
    /// range so ids are globally unique).
    pub id_offset: u64,
    /// Worker threads for the batched per-(layer, head) decode sweep:
    /// 0 → one per available core, 1 → serial. Outputs are identical
    /// either way (deterministic shard merge).
    pub decode_threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AttentionPolicy::Dense,
            hsr_backend: Some(HsrBackend::BallTree),
            cache_capacity_tokens: 1 << 20,
            block_tokens: 64,
            scheduler: SchedulerConfig::default(),
            seed: 0,
            id_offset: 0,
            decode_threads: 0,
        }
    }
}

impl EngineConfig {
    /// Build a serving config from the unified [`AttentionConfig`]. The
    /// serving engine consumes exactly three of its knobs: `backend`
    /// feeds the per-head dynamic indices, `threads` drives the batched
    /// per-(layer, head) decode sweep, and `top_r` (if set) becomes a
    /// fixed-r sparse policy — otherwise the paper's r = n^{4/5}
    /// scaling. `kind`, `threshold` and `adaptive_sigma_k` do **not**
    /// apply here: the transformer path is softmax-only and calibrates
    /// its per-head thresholds at runtime from observed score quantiles
    /// (see `model/transformer.rs`), so those fields are ignored.
    pub fn from_attention(att: AttentionConfig) -> EngineConfig {
        EngineConfig {
            policy: match att.top_r {
                Some(r) => AttentionPolicy::TopR(RSpec::Fixed(r)),
                None => AttentionPolicy::TopR(RSpec::paper()),
            },
            hsr_backend: Some(att.backend),
            decode_threads: att.threads,
            ..EngineConfig::default()
        }
    }
}

/// A single-replica serving engine.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: EngineConfig,
    allocator: BlockAllocator,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    finished: Vec<Response>,
    ws: Workspace,
    bws: BatchWorkspace,
    rng: crate::util::rng::Rng,
    pub metrics: Metrics,
    next_id: RequestId,
}

impl Engine {
    pub fn new(model: Arc<Model>, cfg: EngineConfig) -> Engine {
        let ws = Workspace::new(&model);
        let mut bws = BatchWorkspace::new(&model);
        bws.threads = cfg.decode_threads;
        Engine {
            allocator: BlockAllocator::new(cfg.cache_capacity_tokens, cfg.block_tokens),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            ws,
            bws,
            rng: crate::util::rng::Rng::new(cfg.seed),
            metrics: Metrics::default(),
            next_id: cfg.id_offset + 1,
            model,
            cfg,
        }
    }

    fn new_sequence(&self, req: Request) -> Sequence {
        let c = &self.model.cfg;
        Sequence {
            id: req.id,
            priority: req.id, // submission order
            kv: KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend),
            prompt: req.prompt,
            params: req.params,
            generated: Vec::new(),
            submitted: Instant::now(),
            first_token_at: None,
            blocks: Vec::new(),
            prefilled: 0,
        }
    }

    /// Submit a request; returns its id.
    pub fn submit(&mut self, prompt: Vec<u32>, params: GenerationParams) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, prompt, params };
        self.metrics.requests_submitted += 1;
        self.metrics.prompt_tokens += req.prompt.len() as u64;
        let seq = self.new_sequence(req);
        self.waiting.push_back(seq);
        id
    }

    /// Whether any work remains.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Sequences currently decoding/prefilling.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Drain completed responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// One scheduler iteration; returns the number of tokens processed.
    ///
    /// Sequences are served strictly in priority (submission) order and a
    /// sequence may only preempt strictly-younger ones, so the oldest
    /// running sequence always makes progress — no preemption livelock.
    ///
    /// Prefill chunks run inline during the priority walk; decode-ready
    /// sequences are *collected* and then decoded as **one batched model
    /// step** — every sequence's row flows through the per-(layer, head)
    /// attention sweep together instead of sequence-by-sequence.
    pub fn step(&mut self) -> usize {
        let t0 = Instant::now();
        self.admit();
        let mut tokens = 0usize;
        let budget = self.cfg.scheduler.step_token_budget.max(1);
        let mut stats = StepStats::default();
        let mut decode_ids: Vec<RequestId> = Vec::new();

        // Serve in priority order; `running` mutates during the loop, so
        // look sequences up by id.
        let mut order: Vec<(u64, RequestId)> =
            self.running.iter().map(|s| (s.priority, s.id)).collect();
        order.sort_unstable();
        for (_, sid) in order {
            if tokens >= budget {
                break;
            }
            let Some(i) = self.running.iter().position(|s| s.id == sid) else {
                continue; // finished or preempted earlier in this step
            };
            // Reserve capacity for this sequence's next chunk; preempt
            // younger sequences if the pool is exhausted.
            let needed_now = {
                let seq = &self.running[i];
                if seq.prefilled < seq.prompt.len() {
                    let chunk = self
                        .cfg
                        .scheduler
                        .prefill_chunk
                        .min(seq.prompt.len() - seq.prefilled)
                        .min(budget - tokens)
                        .max(1);
                    seq.cached_tokens() + chunk
                } else {
                    seq.cached_tokens() + 1
                }
            };
            if !self.reserve_for(i, needed_now) {
                continue; // cannot make room without evicting elders: wait
            }
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("sequence survives its own reservation");
            let seq = &mut self.running[i];
            if seq.prefilled < seq.prompt.len() {
                // --- chunked prefill ---
                let chunk = self
                    .cfg
                    .scheduler
                    .prefill_chunk
                    .min(seq.prompt.len() - seq.prefilled)
                    .min(budget - tokens)
                    .max(1);
                for t in 0..chunk {
                    let tok = seq.prompt[seq.prefilled + t];
                    let logits = self.model.decode_step(
                        tok,
                        &mut seq.kv,
                        self.cfg.policy,
                        &mut self.ws,
                        &mut stats,
                    );
                    // Logits of the last prompt token seed the first
                    // generated token.
                    if seq.prefilled + t + 1 == seq.prompt.len() {
                        let next = sample(&logits, seq.params.temperature, &mut self.rng);
                        seq.generated.push(next);
                        seq.first_token_at = Some(Instant::now());
                    }
                }
                seq.prefilled += chunk;
                tokens += chunk;
            } else {
                // --- decode-ready: defer into the batched model step ---
                let last = *seq
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token");
                let finished_by_stop = seq.params.stop_token == Some(last);
                if finished_by_stop || seq.done() {
                    self.finish(i, if finished_by_stop { FinishReason::StopToken } else { FinishReason::Length });
                    continue; // running[i] replaced by swap_remove
                }
                // Safe to defer: the walk visits oldest-first and
                // reservations only ever preempt strictly-younger
                // sequences, so a collected member is never evicted
                // before the batch runs.
                decode_ids.push(sid);
                tokens += 1;
            }
        }
        self.decode_batch(&decode_ids, &mut stats);
        self.metrics.record_step_stats(&stats);
        if tokens > 0 {
            self.metrics.step_latency.record(t0.elapsed());
        }
        tokens
    }

    /// Decode one token for each collected sequence as a single batched
    /// model step (the per-(layer, head) sweep runs over all their rows
    /// at once), then sample in priority order so the RNG stream stays
    /// deterministic.
    fn decode_batch(&mut self, ids: &[RequestId], stats: &mut StepStats) {
        if ids.is_empty() {
            return;
        }
        // Batch members in running-vector order (for borrow splitting);
        // each entry is (running index, id).
        let mut members: Vec<(usize, RequestId)> = ids
            .iter()
            .map(|&sid| {
                let i = self
                    .running
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("batch members survive the walk");
                (i, sid)
            })
            .collect();
        members.sort_unstable();
        let tokens: Vec<u32> = members
            .iter()
            .map(|&(i, _)| {
                *self.running[i]
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token")
            })
            .collect();
        let model = Arc::clone(&self.model);
        let policy = self.cfg.policy;
        let bws = &mut self.bws;
        let mut kvs: Vec<&mut KvState> = Vec::with_capacity(members.len());
        let mut next_member = 0usize;
        for (i, seq) in self.running.iter_mut().enumerate() {
            if next_member < members.len() && members[next_member].0 == i {
                kvs.push(&mut seq.kv);
                next_member += 1;
            }
        }
        debug_assert_eq!(kvs.len(), members.len());
        let logits = model.decode_step_batch(&tokens, &mut kvs, policy, bws, stats);
        drop(kvs);
        // Sample in submission-priority order (the `ids` order).
        for &sid in ids {
            let bpos = members
                .iter()
                .position(|&(_, s)| s == sid)
                .expect("member list covers ids");
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("no sequence finishes during the batch");
            let seq = &mut self.running[i];
            let next = sample(&logits[bpos], seq.params.temperature, &mut self.rng);
            seq.generated.push(next);
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(Instant::now());
            }
            self.metrics.generated_tokens += 1;
        }
    }

    /// True once every admitted prompt is fully prefilled and nothing is
    /// waiting — the steady decode phase the serving bench reports
    /// separately from time-to-first-token.
    pub fn steady_state(&self) -> bool {
        self.waiting.is_empty()
            && self.running.iter().all(|s| s.prefilled >= s.prompt.len())
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.has_work() {
            let processed = self.step();
            if processed > 0 {
                continue;
            }
            // No progress: abort whatever can provably never run.
            // (a) A running sequence larger than the whole pool.
            let seq_too_big = self.running.iter().position(|s| {
                self.allocator.blocks_for(s.prompt.len() + s.params.max_new_tokens)
                    > self.allocator.total_blocks()
            });
            if let Some(idx) = seq_too_big {
                self.finish(idx, FinishReason::Aborted);
                continue;
            }
            // (b) Nothing running and the head-of-line waiting request can
            // never be admitted (prompt exceeds the pool).
            if self.running.is_empty() {
                if let Some(seq) = self.waiting.front() {
                    if self.allocator.blocks_for(seq.prompt.len() + 1)
                        > self.allocator.total_blocks()
                    {
                        let mut seq = self.waiting.pop_front().unwrap();
                        self.allocator.release(&mut seq.blocks);
                        self.emit_response(seq, FinishReason::Aborted);
                        continue;
                    }
                }
            }
        }
    }

    /// Admit waiting sequences while there is batch room and pool room
    /// for their prompts.
    fn admit(&mut self) {
        while self.running.len() < self.cfg.scheduler.max_batch {
            let Some(seq) = self.waiting.front() else { break };
            // Reserve the full prompt + one decode block up front.
            let need = self.allocator.blocks_for(seq.prompt.len() + 1);
            if need > self.allocator.free_blocks() {
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            let mut blocks = self.allocator.alloc(need).expect("checked free_blocks");
            seq.blocks.append(&mut blocks);
            self.running.push(seq);
        }
    }

    /// Ensure sequence `idx` holds blocks for `needed_tokens`, preempting
    /// strictly-younger sequences if necessary. Returns false if room
    /// could not be made. The requesting sequence is never evicted here.
    fn reserve_for(&mut self, idx: usize, needed_tokens: usize) -> bool {
        let sid = self.running[idx].id;
        loop {
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("requester is never preempted by reserve_for");
            let my_priority = self.running[i].priority;
            let seq = &mut self.running[i];
            if self.allocator.ensure(&mut seq.blocks, needed_tokens) {
                return true;
            }
            // Evict a strictly-younger sequence, if any.
            let candidates: Vec<(usize, usize, u64)> = self
                .running
                .iter()
                .enumerate()
                .filter(|&(_, s)| s.priority > my_priority)
                .map(|(j, s)| (j, s.cached_tokens(), s.priority))
                .collect();
            match self.cfg.scheduler.pick_victim(&candidates) {
                Some(victim) => self.preempt(victim),
                None => return false, // only elders left: wait our turn
            }
        }
    }

    /// Preempt: release blocks, drop KV, requeue for full recompute.
    fn preempt(&mut self, idx: usize) {
        let mut seq = self.running.swap_remove(idx);
        self.allocator.release(&mut seq.blocks);
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        seq.prefilled = 0;
        // Generated tokens so far are preserved: they are re-fed as part
        // of the (extended) prompt on re-admission.
        let mut prompt = std::mem::take(&mut seq.prompt);
        prompt.extend(seq.generated.iter().copied());
        // The last generated token must be re-generated after recompute;
        // keep it in the prompt and let decode continue from there.
        seq.prompt = prompt;
        self.metrics.requests_preempted += 1;
        self.waiting.push_front(seq);
    }

    /// Finish running[idx] with the given reason.
    fn finish(&mut self, idx: usize, reason: FinishReason) {
        let mut seq = self.running.swap_remove(idx);
        self.allocator.release(&mut seq.blocks);
        self.emit_response(seq, reason);
    }

    fn emit_response(&mut self, seq: Sequence, reason: FinishReason) {
        let latency = seq.submitted.elapsed();
        let ttft = seq
            .first_token_at
            .map(|t| t.duration_since(seq.submitted))
            .unwrap_or(latency);
        self.metrics.requests_completed += 1;
        self.metrics.request_latency.record(latency);
        self.metrics.ttft.record(ttft);
        self.finished.push(Response {
            id: seq.id,
            tokens: seq.generated,
            finish: reason,
            latency_ms: latency.as_secs_f64() * 1e3,
            ttft_ms: ttft.as_secs_f64() * 1e3,
            prompt_len: seq.prompt.len(),
        });
    }

    /// Pool utilization (diagnostics).
    pub fn cache_utilization(&self) -> f64 {
        self.allocator.utilization()
    }
}
