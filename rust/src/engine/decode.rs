//! Algorithm 1 — Generation Decoding.
//!
//! The paper's `GenerationDecoding` data structure, verbatim:
//!
//! ```text
//! INIT({K_i}, V, n, d):   b ← σ_a √(0.4 log n);  HSR.INIT({K_i}, n, d)
//! INFERENCE(Q, m):        for i in 1..m:
//!                           S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                           A_{i,j} ← ReLU^α(⟨Q_i,K_j⟩/√d − b)  (or Softmax)
//!                         return D^{-1} A V
//! ```
//!
//! The KV cache (K, V) is fixed at INIT (generation-decoding scenario,
//! m = Θ(1) queries per step); the paper's Part-2 HSR (heavy
//! preprocessing, cheap queries) maps to whichever backend the caller
//! selects — see DESIGN.md §3 for the substitution. Support for appending
//! freshly generated keys (the auto-regressive loop of Theorem D.2) comes
//! from the dynamic logarithmic-method wrapper.

use crate::attention::relu::relu_attention_row_scored;
use crate::attention::softmax::softmax_attention_row_scored;
use crate::attention::threshold::ThresholdParams;
use crate::attention::topk::top_r_select_into;
use crate::attention::AttentionKind;
use crate::hsr::dynamic::DynamicHsr;
use crate::hsr::{HalfSpaceReport, HsrBackend, QueryStats};
use crate::kernel::Scratch;

/// The paper's Algorithm 1 over raw K/V matrices.
pub struct GenerationDecoding {
    /// HSR structure over the keys (dynamic: supports appends).
    hsr: DynamicHsr,
    /// Keys, row-major [n, d] (grows on append).
    keys: Vec<f32>,
    /// Values, row-major [n, d].
    values: Vec<f32>,
    d: usize,
    /// Threshold b on the scaled score ⟨q,k⟩/√d (Lemma 6.1).
    pub bias: f32,
    /// Which attention to evaluate on the reported set.
    pub kind: AttentionKind,
    /// For softmax: restrict to top-r of the report (Theorem 4.2);
    /// None → use the whole reported set.
    pub top_r: Option<usize>,
    /// Key std σ_k for the per-query adaptive softmax threshold.
    pub sigma_k: f64,
    /// Accumulated query-work counters.
    pub stats: QueryStats,
    /// Reusable row buffers (no allocation in the decode inner loop).
    scratch: Scratch,
}

impl GenerationDecoding {
    /// INIT: build the HSR structure over the KV cache.
    /// `bias` is on the scaled score; pass
    /// `ThresholdParams::practical_bias` / `bias` / a calibrated value.
    pub fn init(
        keys: &[f32],
        values: &[f32],
        d: usize,
        bias: f32,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        assert_eq!(keys.len(), values.len());
        assert_eq!(keys.len() % d, 0);
        GenerationDecoding {
            hsr: DynamicHsr::from_points(backend, keys, d),
            keys: keys.to_vec(),
            values: values.to_vec(),
            d,
            bias,
            kind,
            top_r: None,
            sigma_k: 1.0,
            stats: QueryStats::default(),
            scratch: Scratch::new(),
        }
    }

    /// INIT with the paper's Lemma 6.1 threshold for Gaussian K/Q.
    pub fn init_gaussian(
        keys: &[f32],
        values: &[f32],
        d: usize,
        m: usize,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        let n = keys.len() / d;
        let params = ThresholdParams::standard(d, m);
        let bias = params.practical_bias(n.max(2)) as f32;
        GenerationDecoding::init(keys, values, d, bias, kind, backend)
    }

    /// Number of cached (key, value) rows.
    pub fn len(&self) -> usize {
        self.keys.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Append a generated token's (k, v) — Theorem D.2's auto-regressive
    /// cache growth, amortized-logarithmic via the dynamic HSR.
    pub fn append(&mut self, key: &[f32], value: &[f32]) {
        assert_eq!(key.len(), self.d);
        assert_eq!(value.len(), self.d);
        self.hsr.insert(key);
        self.keys.extend_from_slice(key);
        self.values.extend_from_slice(value);
    }

    /// INFERENCE for a single query row; writes the attention output into
    /// `out` (length d) and returns the activated-set size k̃.
    pub fn inference_row(&mut self, q: &[f32], out: &mut [f32]) -> usize {
        assert_eq!(q.len(), self.d);
        // HSR threshold is on the raw inner product: ⟨q,k⟩ ≥ b·√d.
        // Softmax top-r uses a *per-query adaptive* threshold instead:
        // <q,k> | q ~ N(0, ‖q‖²σ_k²), so aiming the expected report at 2r
        // needs b_raw = ‖q‖σ_k√(2 ln(n/2r)) — a fixed b under-reports for
        // small-norm queries (and triggers costly full-scan fallbacks).
        let b_raw = match (self.kind, self.top_r) {
            (AttentionKind::Softmax, Some(r)) => {
                let n = self.len().max(2) as f64;
                let target = (2 * r).max(1) as f64;
                let t = (2.0 * (n / target).ln()).max(0.0).sqrt();
                (crate::hsr::norm(q) as f64 * self.sigma_k * t) as f32
            }
            _ => self.bias * (self.d as f32).sqrt(),
        };
        let inv_sqrt_d = 1.0 / (self.d as f32).sqrt();
        // Score-carrying HSR query: the report arrives with the raw inner
        // products, so nothing below re-dots a key the traversal already
        // evaluated. All row buffers come from the reusable scratch.
        self.scratch.fire.clear();
        self.scratch.scores.clear();
        self.hsr.query_scored_into(
            q,
            b_raw,
            &mut self.scratch.fire,
            &mut self.scratch.scores,
            &mut self.stats,
        );
        match self.kind {
            AttentionKind::Relu { alpha, bias } => {
                debug_assert!(
                    (bias - self.bias).abs() < 1e-6,
                    "ReLU bias must equal the HSR threshold for exactness"
                );
                for s in self.scratch.scores.iter_mut() {
                    *s *= inv_sqrt_d;
                }
                relu_attention_row_scored(
                    &self.scratch.fire,
                    &mut self.scratch.scores,
                    &self.values,
                    self.d,
                    alpha,
                    self.bias,
                    out,
                );
                self.scratch.fire.len()
            }
            AttentionKind::Softmax => {
                // Theorem 4.2 needs R = NN(r, q, K): if the threshold
                // under-reported (|fire| < r), fall back to the full
                // half-space so the top-r below is exact.
                if let Some(r) = self.top_r {
                    if self.scratch.fire.len() < r.min(self.len()) {
                        self.scratch.fire.clear();
                        self.scratch.scores.clear();
                        self.hsr.query_scored_into(
                            q,
                            f32::NEG_INFINITY,
                            &mut self.scratch.fire,
                            &mut self.scratch.scores,
                            &mut self.stats,
                        );
                    }
                }
                match self.top_r {
                    Some(r) if r < self.scratch.fire.len() => {
                        top_r_select_into(
                            &self.scratch.fire,
                            &self.scratch.scores,
                            r,
                            &mut self.scratch.selected,
                            &mut self.scratch.exps,
                        );
                        for s in self.scratch.exps.iter_mut() {
                            *s *= inv_sqrt_d;
                        }
                        softmax_attention_row_scored(
                            &self.scratch.selected,
                            &mut self.scratch.exps,
                            &self.values,
                            self.d,
                            out,
                        );
                        self.scratch.selected.len()
                    }
                    _ => {
                        for s in self.scratch.scores.iter_mut() {
                            *s *= inv_sqrt_d;
                        }
                        softmax_attention_row_scored(
                            &self.scratch.fire,
                            &mut self.scratch.scores,
                            &self.values,
                            self.d,
                            out,
                        );
                        self.scratch.fire.len()
                    }
                }
            }
        }
    }

    /// INFERENCE over a full Q (m × d): returns the m × d output.
    pub fn inference(&mut self, q: &[f32]) -> Vec<f32> {
        let m = q.len() / self.d;
        let mut out = vec![0f32; m * self.d];
        for i in 0..m {
            let (qs, qe) = (i * self.d, (i + 1) * self.d);
            self.inference_row(&q[qs..qe], &mut out[qs..qe]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::softmax::softmax_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    /// Algorithm 1 with ReLU attention is *exact* vs the naive dense
    /// computation (the paper's "no error for ReLU" claim).
    #[test]
    fn relu_matches_dense_exactly() {
        let mut rng = Rng::new(101);
        for backend in [HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected] {
            let inst = AttentionInstance::gaussian(&mut rng, 4, 600, 8);
            let bias = inst.params.practical_bias(inst.n) as f32;
            for alpha in [1u32, 2] {
                let mut gd = GenerationDecoding::init(
                    &inst.k,
                    &inst.v,
                    inst.d,
                    bias,
                    AttentionKind::Relu { alpha, bias },
                    backend,
                );
                let got = gd.inference(&inst.q);
                let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, alpha, bias);
                assert!(
                    linf(&got, &want) < 1e-4,
                    "backend={backend:?} alpha={alpha}: {}",
                    linf(&got, &want)
                );
            }
        }
    }

    /// Softmax with top-r over the report is close to dense and the error
    /// shrinks as r grows (Theorem 4.3's shape).
    #[test]
    fn softmax_topr_error_shrinks() {
        let mut rng = Rng::new(102);
        let inst = AttentionInstance::gaussian(&mut rng, 2, 800, 8);
        let dense = softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        let mut last_err = f32::INFINITY;
        for r in [8usize, 64, 512, 800] {
            let mut gd = GenerationDecoding::init(
                &inst.k,
                &inst.v,
                inst.d,
                f32::NEG_INFINITY, // report everything; top-r selects
                AttentionKind::Softmax,
                HsrBackend::BallTree,
            );
            gd.top_r = Some(r);
            let got = gd.inference(&inst.q);
            let err = linf(&got, &dense);
            assert!(err <= last_err * 1.25 + 1e-6, "r={r} err={err} last={last_err}");
            last_err = last_err.min(err);
        }
        assert!(last_err < 1e-5, "full r must be exact: {last_err}");
    }

    /// Appending keys (auto-regressive growth) stays consistent with a
    /// from-scratch build.
    #[test]
    fn append_matches_rebuild() {
        let mut rng = Rng::new(103);
        let d = 6;
        let inst = AttentionInstance::gaussian(&mut rng, 1, 200, d);
        let bias = 0.2f32;
        let kind = AttentionKind::Relu { alpha: 1, bias };
        let mut grown = GenerationDecoding::init(
            &inst.k[..100 * d],
            &inst.v[..100 * d],
            d,
            bias,
            kind,
            HsrBackend::BallTree,
        );
        for j in 100..200 {
            grown.append(&inst.k[j * d..(j + 1) * d], &inst.v[j * d..(j + 1) * d]);
        }
        let mut fresh =
            GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
        let mut out_a = vec![0f32; d];
        let mut out_b = vec![0f32; d];
        let q: Vec<f32> = inst.q[..d].to_vec();
        grown.inference_row(&q, &mut out_a);
        fresh.inference_row(&q, &mut out_b);
        assert!(linf(&out_a, &out_b) < 1e-5);
    }

    /// The activated-set size tracks Lemma 6.1: k̃ ≤ 2 n^{4/5}.
    #[test]
    fn activated_count_respects_lemma() {
        let mut rng = Rng::new(104);
        let inst = AttentionInstance::gaussian(&mut rng, 8, 4096, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let mut gd = GenerationDecoding::init(
            &inst.k,
            &inst.v,
            inst.d,
            bias,
            AttentionKind::Relu { alpha: 1, bias },
            HsrBackend::BallTree,
        );
        let bound = inst.params.row_bound(inst.n) as usize;
        let mut out = vec![0f32; inst.d];
        let mut any = 0usize;
        for i in 0..inst.m {
            let q: Vec<f32> = inst.query_row(i).to_vec();
            let fired = gd.inference_row(&q, &mut out);
            assert!(fired <= bound, "row {i}: fired {fired} > bound {bound}");
            any += fired;
        }
        assert!(any > 0, "nothing fired at the practical threshold");
    }
}
