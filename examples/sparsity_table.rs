//! Table 1 reproduction: sparsity level across sequence lengths.
//!
//! Prints the paper's analytic table (activated = n^{4/5}) next to the
//! *measured* activation counts on the Gaussian workload with the
//! Lemma 6.1 practical threshold — measured counts must stay below the
//! 2n^{4/5} bound.
//!
//! Run: cargo run --release --example sparsity_table [-- --max-n 1048576]

use hsr_attn::attention::relu::count_activated;
use hsr_attn::attention::threshold::{sparsity_table, ThresholdParams};
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let max_n = args.usize_or("max-n", 1 << 20);
    let d = args.usize_or("d", 64);
    let measure_cap = args.usize_or("measure-cap", 131_072); // keep memory sane
    let ns: Vec<usize> = (10..=20)
        .map(|p| 1usize << p)
        .filter(|&n| n <= max_n)
        .collect();

    println!("Table 1: sparsity level across sequence lengths (d = {d})");
    println!(
        "{:>10} | {:>12} {:>9} | {:>12} {:>10}",
        "n", "analytic", "sparsity", "measured", "bound ok"
    );
    println!("{}", "-".repeat(64));
    let mut rng = Rng::new(1);
    for row in sparsity_table(&ns) {
        let (measured, ok) = if row.n <= measure_cap {
            let m = 4usize;
            let params = ThresholdParams::standard(d, m);
            let bias = params.practical_bias(row.n) as f32;
            let q = rng.gaussian_vec_f32(m * d, 1.0);
            let k = rng.gaussian_vec_f32(row.n * d, 1.0);
            let counts = count_activated(&q, &k, d, bias);
            let avg = counts.iter().sum::<usize>() / m;
            let bound = params.row_bound(row.n);
            (
                format!("{avg}"),
                if counts.iter().all(|&c| (c as f64) <= bound) { "yes" } else { "NO" },
            )
        } else {
            ("-".to_string(), "-")
        };
        println!(
            "{:>10} | {:>12.0} {:>8.2}% | {:>12} {:>10}",
            row.n,
            row.activated,
            row.sparsity * 100.0,
            measured,
            ok
        );
    }
    println!("\npaper Table 1 reference: n=1k -> 251 (0.75), n=1024k -> 64304 (0.94)");
    println!("(analytic column = n^(4/5), identical to the paper's construction;");
    println!(" measured column = empirical activation at the practical Lemma 6.1 b)");
}
