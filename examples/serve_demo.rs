//! END-TO-END SERVING DRIVER (the deliverable-(b) mandated example).
//!
//! Loads the build-time-trained char-LM from `artifacts/`, replays a
//! Poisson serving trace through the multi-worker router — prefill +
//! continuous-batched decode with per-(layer,head) dynamic HSR indices —
//! and reports latency/throughput for the dense baseline vs the
//! HSR-sparse top-r policy (Algorithm 1 inside a real serving loop).
//!
//! Run:  make artifacts && cargo run --release --example serve_demo
//! Args: --model small --requests 32 --workers 2 --gen 48 --rate 8
//!       --policy both|dense|sparse

use hsr_attn::engine::{EngineConfig, GenerationParams, Router};
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats;
use hsr_attn::workloads::trace::{generate, TraceParams};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn run_policy(
    name: &str,
    model: Arc<Model>,
    policy: AttentionPolicy,
    workers: usize,
    requests: usize,
    gen_tokens: usize,
    rate: f64,
) {
    let mut rng = Rng::new(7);
    let trace = generate(
        &mut rng,
        &TraceParams {
            rate,
            prompt_log_mean: 4.6, // ~100 tokens
            prompt_log_std: 0.6,
            prompt_min: 16,
            prompt_max: 512,
            mean_new_tokens: gen_tokens as f64,
            max_new_tokens: gen_tokens,
            ..Default::default()
        },
        requests,
    );
    // Prompt content: synthetic corpus-like text bytes.
    let corpus: Vec<u32> = {
        let text = "the merchant carries copper coins by the river. remember: \
                    alder keeps the amber token. a courier guards sealed \
                    letters near the gate. the alder token is amber. ";
        text.bytes().cycle().take(8192).map(|b| b as u32).collect()
    };

    let router = Router::new(
        model,
        EngineConfig { policy, ..Default::default() },
        workers,
    );
    let t0 = Instant::now();
    let mut total_prompt = 0usize;
    let mut rejected = 0usize;
    for req in &trace {
        // Honour arrival times (compressed 4x for demo runtime).
        let due = req.arrival_s / 4.0;
        let now = t0.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
        }
        let start = rng.below(corpus.len() - req.prompt_len);
        let accepted = router
            .submit(
                corpus[start..start + req.prompt_len].to_vec(),
                GenerationParams {
                    max_new_tokens: req.max_new_tokens,
                    temperature: 0.0,
                    stop_token: None,
                    deadline: None,
                },
            )
            .is_ok();
        if accepted {
            total_prompt += req.prompt_len;
        } else {
            rejected += 1;
        }
    }
    router.wait_idle();
    let wall = t0.elapsed().as_secs_f64();
    let responses = router.take_responses();
    let metrics = router.shutdown();
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let ttfts: Vec<f64> = responses.iter().map(|r| r.ttft_ms).collect();
    let gen_total: usize = responses.iter().map(|r| r.tokens.len()).sum();

    println!("\n--- policy = {name} ({workers} workers, {requests} requests) ---");
    if rejected > 0 {
        println!("admission control shed {rejected} requests (default caps)");
    }
    println!(
        "completed {} / {}  in {wall:.2}s   throughput: {:.1} gen tok/s ({:.1} total tok/s)",
        responses.len(),
        requests,
        gen_total as f64 / wall,
        (gen_total + total_prompt) as f64 / wall,
    );
    println!(
        "request latency ms: p50 {:.1}  p90 {:.1}  p99 {:.1}   ttft p50 {:.1}",
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 90.0),
        stats::percentile(&latencies, 99.0),
        stats::percentile(&ttfts, 50.0),
    );
    println!("engine metrics:\n{}", metrics.summary());
}

fn main() {
    let args = Args::from_env();
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let model_name = args.str_or("model", "small");
    let requests = args.usize_or("requests", 24);
    let workers = args.usize_or("workers", 2);
    let gen_tokens = args.usize_or("gen", 48);
    let rate = args.f64_or("rate", 8.0);
    let which = args.str_or("policy", "both").to_string();

    let model = Arc::new(Model::load_named(&dir, model_name).expect("load model"));
    println!(
        "== serve_demo: model '{}' ({} layers, d_model {}, vocab {}) ==",
        model.cfg.name, model.cfg.n_layers, model.cfg.d_model, model.cfg.vocab
    );

    if which == "both" || which == "dense" {
        run_policy(
            "dense (naive O(n) attention)",
            model.clone(),
            AttentionPolicy::Dense,
            workers,
            requests,
            gen_tokens,
            rate,
        );
    }
    if which == "both" || which == "sparse" {
        run_policy(
            "hsr-sparse top-r = n^(4/5) (Algorithm 1)",
            model,
            AttentionPolicy::TopR(RSpec::paper()),
            workers,
            requests,
            gen_tokens,
            rate,
        );
    }
    println!("\n(done — see EXPERIMENTS.md §E2E for recorded numbers)");
}
