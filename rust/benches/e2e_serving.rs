//! Bench/reproduction: **headline claim** — end-to-end serving
//! throughput/latency with HSR-sparse attention vs the dense baseline,
//! on the trained char-LM, plus the batching-policy ablation.
//!
//! Run after `make artifacts`. Skips gracefully if artifacts are missing.

use hsr_attn::bench::banner;
use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{GenerationParams, SchedulerConfig};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct RunResult {
    wall_s: f64,
    gen_tokens: u64,
    /// Decode throughput measured only over steps that started in steady
    /// state (all admitted prompts prefilled, nothing waiting) — the
    /// batching win, undiluted by prefill.
    steady_tok_per_s: f64,
    /// Time to first token, p50 across requests.
    ttft_p50_ns: u64,
    attended_frac: f64,
    p50_step_ns: u64,
}

fn run(
    model: Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    requests: usize,
    prompt_len: usize,
    gen: usize,
    max_batch: usize,
) -> RunResult {
    let mut rng = Rng::new(11);
    let mut eng = Engine::new(
        model,
        EngineConfig {
            policy,
            hsr_backend: backend,
            scheduler: SchedulerConfig { max_batch, ..Default::default() },
            ..Default::default()
        },
    );
    let corpus: Vec<u32> = "the merchant carries copper coins by the river. \
        remember: alder keeps the amber token. the alder token is amber. "
        .bytes()
        .cycle()
        .take(8192)
        .map(|b| b as u32)
        .collect();
    for _ in 0..requests {
        let s = rng.below(corpus.len() - prompt_len);
        eng.submit(
            corpus[s..s + prompt_len].to_vec(),
            GenerationParams { max_new_tokens: gen, temperature: 0.0, stop_token: None },
        );
    }
    let t0 = Instant::now();
    // Drive manually so steps that start in steady state (post-admission,
    // all prompts prefilled) can be timed separately from prefill-heavy
    // ones — time-to-first-token must not dilute the decode throughput.
    let mut steady_ns: u128 = 0;
    let mut steady_tok: u64 = 0;
    while eng.has_work() {
        let was_steady = eng.steady_state();
        let g0 = eng.metrics.generated_tokens;
        let ts = Instant::now();
        let processed = eng.step();
        if was_steady {
            steady_ns += ts.elapsed().as_nanos();
            steady_tok += eng.metrics.generated_tokens - g0;
        }
        if processed == 0 {
            eng.run_to_completion(); // stuck-work fallback (aborts)
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    RunResult {
        wall_s,
        gen_tokens: eng.metrics.generated_tokens + requests as u64, // + seeded
        steady_tok_per_s: if steady_ns > 0 {
            steady_tok as f64 / (steady_ns as f64 * 1e-9)
        } else {
            0.0
        },
        ttft_p50_ns: eng.metrics.ttft.percentile_ns(50.0),
        attended_frac: eng.metrics.attended_fraction(),
        p50_step_ns: eng.metrics.step_latency.percentile_ns(50.0),
    }
}

fn main() {
    banner("e2e_serving", "headline: sparse vs dense serving throughput/latency");
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts`; skipping");
        return;
    }
    let model_name = args.str_or("model", "small");
    let requests = args.usize_or("requests", 12);
    let prompt_len = args.usize_or("prompt", 384);
    let gen = args.usize_or("gen", 96);
    let model = Arc::new(Model::load_named(&artifacts_dir(), model_name).unwrap());
    println!(
        "model '{}', {} requests x (prompt {} + gen {})\n",
        model_name, requests, prompt_len, gen
    );

    println!(
        "{:<44} {:>9} {:>12} {:>13} {:>10} {:>11} {:>10}",
        "configuration", "wall s", "gen tok/s", "steady tok/s", "ttft p50", "p50 step", "attended"
    );
    let cases: Vec<(String, AttentionPolicy, Option<HsrBackend>, usize)> = vec![
        ("dense baseline (batch 8)".into(), AttentionPolicy::Dense, None, 8),
        (
            "sparse top-r=n^0.8, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, brute scan (ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            None,
            8,
        ),
        (
            "sparse top-r=64 fixed, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::Fixed(64)),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, balltree (batch 1 ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            1,
        ),
    ];
    for (name, policy, backend, batch) in cases {
        let r = run(model.clone(), policy, backend, requests, prompt_len, gen, batch);
        println!(
            "{:<44} {:>9.2} {:>12.1} {:>13.1} {:>10} {:>11} {:>9.1}%",
            name,
            r.wall_s,
            r.gen_tokens as f64 / r.wall_s,
            r.steady_tok_per_s,
            hsr_attn::util::stats::fmt_ns(r.ttft_p50_ns as f64),
            hsr_attn::util::stats::fmt_ns(r.p50_step_ns as f64),
            r.attended_frac * 100.0
        );
    }
    println!("\nexpected: sparse attends a small fraction of entries; steady tok/s");
    println!("isolates the batched decode win from prefill (ttft reported apart);");
    println!("wall-clock gains grow with context (see decode_time for scaling).");
}
