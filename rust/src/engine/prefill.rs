//! Algorithm 2 — Prompt Prefilling.
//!
//! The paper's `PromptPrefilling` data structure: both Q and K vary per
//! call (m = Θ(n)), so the HSR structure is built *inside* INFERENCE with
//! the cheap Part-1 build and queried once per query row:
//!
//! ```text
//! INFERENCE({K_i}, {Q_r}, V, n, m, d):
//!   b ← σ_a √(0.4 log n)
//!   HSR.INIT({K_i}, n, d)                       (O(n log n))
//!   for i in 1..m:  S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                   A_{i,j} ← ReLU^α(…)  or Softmax(…)
//!   return D^{-1} A V
//! ```
//!
//! Engineering beyond the pseudocode (all output-preserving):
//!
//! * **Score-carrying queries** — `query_scored_into` reports (index,
//!   raw-dot) pairs, so the softmax/ReLU evaluation never recomputes an
//!   inner product the HSR traversal already paid for.
//! * **Scratch reuse** — one [`Scratch`] arena per worker; the per-row
//!   loop performs no heap allocation in steady state.
//! * **Parallel rows** — the m query rows are embarrassingly parallel
//!   over the immutable HSR structure; they are sharded across scoped
//!   threads (`threads` knob, 0 = auto) with per-shard `QueryStats`
//!   merged in shard order. Output is bit-identical to the serial path.

use crate::attention::relu::relu_attention_row_scored;
use crate::attention::softmax::softmax_attention_row_scored;
use crate::attention::threshold::ThresholdParams;
use crate::attention::topk::top_r_select_into;
use crate::attention::AttentionKind;
use crate::hsr::{build_hsr, HalfSpaceReport, HsrBackend, QueryStats};
use crate::kernel::Scratch;

/// Output of one prefill run.
pub struct PrefillResult {
    /// Attention output, row-major [m, d].
    pub out: Vec<f32>,
    /// Activated entries per query row (the k̃_i of Lemma 6.1).
    pub fired: Vec<usize>,
    /// HSR work counters.
    pub stats: QueryStats,
}

/// Algorithm 2 configuration.
#[derive(Debug, Clone, Copy)]
pub struct PromptPrefilling {
    pub kind: AttentionKind,
    pub backend: HsrBackend,
    /// Softmax: keep only the top-r of each report (Theorem 5.2).
    pub top_r: Option<usize>,
    /// Override the Lemma 6.1 threshold (scaled-score units).
    pub bias_override: Option<f32>,
    /// Worker threads for the query-row loop: 0 → one per available
    /// core, 1 → serial. The result is bit-identical either way.
    pub threads: usize,
}

impl PromptPrefilling {
    pub fn new(kind: AttentionKind, backend: HsrBackend) -> PromptPrefilling {
        PromptPrefilling { kind, backend, top_r: None, bias_override: None, threads: 0 }
    }

    /// INFERENCE: full attention of Q, K, V (non-causal — the paper's
    /// prompt-prefilling / cross-attention setting).
    pub fn inference(
        &self,
        q: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        m: usize,
        d: usize,
    ) -> PrefillResult {
        assert_eq!(q.len(), m * d);
        assert_eq!(keys.len(), n * d);
        assert_eq!(values.len(), n * d);
        let params = ThresholdParams::standard(d, m.max(1));
        let bias = self
            .bias_override
            .unwrap_or_else(|| params.practical_bias(n.max(2)) as f32);
        // Part-1 build: O(n log n)-shaped.
        let hsr = build_hsr(self.backend, keys, d);
        let hsr: &dyn HalfSpaceReport = hsr.as_ref();
        let b_raw = bias * (d as f32).sqrt();

        let mut out = vec![0f32; m * d];
        let mut fired = vec![0usize; m];
        let mut stats = QueryStats::default();
        if m == 0 {
            return PrefillResult { out, fired, stats };
        }

        let workers = crate::kernel::effective_threads(self.threads, m);
        if workers <= 1 {
            let mut scratch = Scratch::new();
            for i in 0..m {
                fired[i] = self.row_inference(
                    hsr,
                    &q[i * d..(i + 1) * d],
                    values,
                    n,
                    d,
                    bias,
                    b_raw,
                    &mut out[i * d..(i + 1) * d],
                    &mut scratch,
                    &mut stats,
                );
            }
        } else {
            // Shard rows contiguously; each worker owns disjoint chunks
            // of `out`/`fired` and a private Scratch + QueryStats.
            let rows_per = (m + workers - 1) / workers;
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for (shard, (out_chunk, fired_chunk)) in out
                    .chunks_mut(rows_per * d)
                    .zip(fired.chunks_mut(rows_per))
                    .enumerate()
                {
                    let row0 = shard * rows_per;
                    handles.push(scope.spawn(move || {
                        let mut scratch = Scratch::new();
                        let mut local = QueryStats::default();
                        for (t, (orow, f)) in out_chunk
                            .chunks_mut(d)
                            .zip(fired_chunk.iter_mut())
                            .enumerate()
                        {
                            let i = row0 + t;
                            *f = self.row_inference(
                                hsr,
                                &q[i * d..(i + 1) * d],
                                values,
                                n,
                                d,
                                bias,
                                b_raw,
                                orow,
                                &mut scratch,
                                &mut local,
                            );
                        }
                        local
                    }));
                }
                // Merge in shard order so the aggregate is deterministic.
                for h in handles {
                    stats.add(&h.join().expect("prefill worker panicked"));
                }
            });
        }
        PrefillResult { out, fired, stats }
    }

    /// One query row: score-carrying HSR report, then evaluate the
    /// attention on exactly the reported (or top-r) set. Returns k̃_i.
    #[allow(clippy::too_many_arguments)]
    fn row_inference(
        &self,
        hsr: &dyn HalfSpaceReport,
        qi: &[f32],
        values: &[f32],
        n: usize,
        d: usize,
        bias: f32,
        b_raw: f32,
        orow: &mut [f32],
        scratch: &mut Scratch,
        stats: &mut QueryStats,
    ) -> usize {
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        scratch.fire.clear();
        scratch.scores.clear();
        hsr.query_scored_into(qi, b_raw, &mut scratch.fire, &mut scratch.scores, stats);
        match self.kind {
            AttentionKind::Relu { alpha, .. } => {
                for s in scratch.scores.iter_mut() {
                    *s *= inv_sqrt_d;
                }
                relu_attention_row_scored(
                    &scratch.fire,
                    &mut scratch.scores,
                    values,
                    d,
                    alpha,
                    bias,
                    orow,
                );
                scratch.fire.len()
            }
            AttentionKind::Softmax => {
                // Under-reported threshold: fall back to the full
                // half-space so top-r is exact (Theorem 5.2).
                if let Some(r) = self.top_r {
                    if scratch.fire.len() < r.min(n) {
                        scratch.fire.clear();
                        scratch.scores.clear();
                        hsr.query_scored_into(
                            qi,
                            f32::NEG_INFINITY,
                            &mut scratch.fire,
                            &mut scratch.scores,
                            stats,
                        );
                    }
                }
                match self.top_r {
                    Some(r) if r < scratch.fire.len() => {
                        top_r_select_into(
                            &scratch.fire,
                            &scratch.scores,
                            r,
                            &mut scratch.selected,
                            &mut scratch.exps,
                        );
                        for s in scratch.exps.iter_mut() {
                            *s *= inv_sqrt_d;
                        }
                        softmax_attention_row_scored(
                            &scratch.selected,
                            &mut scratch.exps,
                            values,
                            d,
                            orow,
                        );
                        scratch.selected.len()
                    }
                    _ => {
                        for s in scratch.scores.iter_mut() {
                            *s *= inv_sqrt_d;
                        }
                        softmax_attention_row_scored(
                            &scratch.fire,
                            &mut scratch.scores,
                            values,
                            d,
                            orow,
                        );
                        scratch.fire.len()
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    #[test]
    fn relu_prefill_matches_dense() {
        let mut rng = Rng::new(111);
        let inst = AttentionInstance::gaussian(&mut rng, 150, 150, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        for backend in [HsrBackend::Brute, HsrBackend::BallTree] {
            let pp = PromptPrefilling {
                kind: AttentionKind::Relu { alpha: 2, bias },
                backend,
                top_r: None,
                bias_override: Some(bias),
                threads: 0,
            };
            let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
            let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 2, bias);
            assert!(linf(&res.out, &want) < 1e-4, "backend={backend:?}");
            assert_eq!(res.fired.len(), inst.m);
        }
    }

    #[test]
    fn layers2d_backend_for_d2() {
        let mut rng = Rng::new(112);
        let inst = AttentionInstance::gaussian(&mut rng, 60, 200, 2);
        let bias = 0.1f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::Layers2d,
            top_r: None,
            bias_override: Some(bias),
            threads: 0,
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 1, bias);
        assert!(linf(&res.out, &want) < 1e-4);
    }

    #[test]
    fn softmax_topr_stays_close_to_dense() {
        let mut rng = Rng::new(113);
        let inst = AttentionInstance::gaussian(&mut rng, 100, 400, 8);
        let mut pp = PromptPrefilling::new(AttentionKind::Softmax, HsrBackend::BallTree);
        pp.bias_override = Some(f32::NEG_INFINITY);
        pp.top_r = Some(128);
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let dense = crate::attention::softmax::softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        // 128 of 400 top entries carries most of the exp mass; isotropic
        // Gaussian scores are the *worst* case for top-r truncation (no
        // massive activation), so the tolerance here is loose. The
        // massive-activation sweep in benches/error_topr.rs is the sharp
        // version of this check.
        assert!(linf(&res.out, &dense) < 0.3, "err={}", linf(&res.out, &dense));
        assert!(res.fired.iter().all(|&f| f <= 128));
    }

    #[test]
    fn fired_counts_respect_lemma_bound() {
        let mut rng = Rng::new(114);
        let inst = AttentionInstance::gaussian(&mut rng, 64, 2048, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::BallTree,
            top_r: None,
            bias_override: Some(bias),
            threads: 0,
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let bound = inst.params.row_bound(inst.n) as usize;
        assert!(res.fired.iter().all(|&f| f <= bound));
        assert!(res.fired.iter().sum::<usize>() > 0);
    }

    /// Parallel prefill must be **bit-identical** to serial: same `out`
    /// floats, same per-row fired counts, same merged work counters —
    /// for both attention kinds, with and without top-r.
    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(115);
        let inst = AttentionInstance::gaussian(&mut rng, 64, 512, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let cases: Vec<PromptPrefilling> = vec![
            PromptPrefilling {
                kind: AttentionKind::Relu { alpha: 2, bias },
                backend: HsrBackend::BallTree,
                top_r: None,
                bias_override: Some(bias),
                threads: 1,
            },
            PromptPrefilling {
                kind: AttentionKind::Softmax,
                backend: HsrBackend::BallTree,
                top_r: Some(64),
                bias_override: Some(f32::NEG_INFINITY),
                threads: 1,
            },
            PromptPrefilling {
                kind: AttentionKind::Softmax,
                backend: HsrBackend::Brute,
                top_r: Some(32),
                bias_override: Some(bias),
                threads: 1,
            },
        ];
        for mut pp in cases {
            pp.threads = 1;
            let serial = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
            for threads in [2usize, 3, 7] {
                pp.threads = threads;
                let par = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
                assert_eq!(serial.out, par.out, "threads={threads} kind={:?}", pp.kind);
                assert_eq!(serial.fired, par.fired, "threads={threads}");
                assert_eq!(serial.stats, par.stats, "threads={threads}");
            }
        }
    }

    /// The row loop reuses one Scratch per worker: the report buffer must
    /// keep its capacity across rows (the pre-kernel code `mem::take`-d
    /// the buffer, forcing a fresh allocation every subsequent row).
    #[test]
    fn scratch_capacity_survives_rows() {
        let mut rng = Rng::new(116);
        let inst = AttentionInstance::gaussian(&mut rng, 16, 256, 8);
        let pp = PromptPrefilling {
            kind: AttentionKind::Softmax,
            backend: HsrBackend::BallTree,
            top_r: Some(16),
            bias_override: Some(f32::NEG_INFINITY),
            threads: 1,
        };
        let hsr = build_hsr(pp.backend, &inst.k, inst.d);
        let mut scratch = Scratch::new();
        let mut stats = QueryStats::default();
        let mut orow = vec![0f32; inst.d];
        let b_raw = f32::NEG_INFINITY;
        for i in 0..inst.m {
            pp.row_inference(
                hsr.as_ref(),
                inst.query_row(i),
                &inst.v,
                inst.n,
                inst.d,
                0.0,
                b_raw,
                &mut orow,
                &mut scratch,
                &mut stats,
            );
            // Full report: the fire buffer holds all n entries and must
            // retain that capacity for the next row.
            assert!(scratch.fire.capacity() >= inst.n, "row {i} lost its buffer");
        }
    }
}
