//! Figure 3 reproduction: perplexity of three model sizes under Softmax
//! attention with top-r indices, r ∈ {2^2 … full}.
//!
//! The paper evaluates LLaMA 3.1 8B / Mistral Nemo 12B / Phi 3.5 Mini on
//! PaulGrahamEssays with 2^15-token contexts; this environment has no
//! model weights or datasets, so three build-time-trained char-LMs stand
//! in, evaluated on held-out synthetic text (DESIGN.md §3, substitution
//! 2/3). The claim under test is architectural: perplexity stays flat
//! until r becomes very small.
//!
//! Run: make artifacts && cargo run --release --example perplexity_topr
//!      [-- --ctx 2048 --models mini,small,base]

use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::util::cli::Args;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Deterministic held-out text: same generator family as the training
/// corpus (python/compile/data.py), different seed space. Mirrors
/// data_mod.eval_document's structure closely enough for a byte LM.
fn held_out_text(len: usize) -> Vec<u32> {
    // Mirror of the corpus templates (ASCII): enough long-range texture
    // for the eval; determinism matters more than novelty here.
    let subjects = ["the merchant", "a courier", "the archivist", "our captain", "the gardener"];
    let verbs = ["carries", "guards", "studies", "repairs", "paints"];
    let objects = ["copper coins", "sealed letters", "glass lenses", "star charts", "dried herbs"];
    let places = ["by the river", "near the gate", "under the bridge", "in the tower"];
    let names = ["alder", "brook", "cedar", "dahlia", "ember"];
    let secrets = ["amber", "basalt", "cobalt", "dusk", "echo"];
    let mut rng = hsr_attn::util::rng::Rng::new(0xF16_3);
    let mut s = String::new();
    let mut pending: Vec<(String, String)> = Vec::new();
    let mut i = 0usize;
    while s.len() < len {
        if i % 6 == 5 {
            let n = names[rng.below(names.len())];
            let sec = secrets[rng.below(secrets.len())];
            s.push_str(&format!("remember: {n} keeps the {sec} token. "));
            pending.push((format!("the {n} token is "), sec.to_string()));
        } else if !pending.is_empty() && rng.bool(0.35) {
            let (q, a) = pending.swap_remove(rng.below(pending.len()));
            s.push_str(&q);
            s.push_str(&a);
            s.push_str(". ");
        } else {
            s.push_str(&format!(
                "{} {} {} {}. ",
                subjects[rng.below(subjects.len())],
                verbs[rng.below(verbs.len())],
                objects[rng.below(objects.len())],
                places[rng.below(places.len())]
            ));
        }
        i += 1;
    }
    s.truncate(len);
    s.bytes().map(|b| b as u32).collect()
}

fn main() {
    let args = Args::from_env();
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let ctx = args.usize_or("ctx", 2048);
    let models: Vec<String> = args
        .str_or("models", "mini,small,base")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let tokens = held_out_text(ctx);
    // r sweep: 2^2 .. 2^11 then "full" — the paper's Figure 3 x-axis
    // shape scaled to this context length.
    let mut rs: Vec<usize> = (2..=11).map(|p| 1usize << p).filter(|&r| r < ctx).collect();
    rs.push(ctx); // full == dense

    println!("Figure 3: perplexity vs top-r (held-out synthetic text, ctx = {ctx})");
    print!("{:>14}", "model \\ r");
    for &r in &rs {
        if r == ctx {
            print!("{:>9}", "full");
        } else {
            print!("{r:>9}");
        }
    }
    println!();
    println!("{}", "-".repeat(14 + 9 * rs.len()));

    for name in &models {
        let model = match Model::load_named(&dir, name) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        print!("{:>14}", format!("{name}({}k)", estimate_params(&model) / 1000));
        let mut row = Vec::new();
        for &r in &rs {
            let policy = if r >= ctx {
                AttentionPolicy::Dense
            } else {
                AttentionPolicy::TopR(RSpec::Fixed(r))
            };
            let nll = model.nll(&tokens, policy);
            let ppl = nll.exp();
            row.push(ppl);
            print!("{ppl:>9.3}");
        }
        println!();
        // Figure 3's claim: flat until r < 2^4.
        let full = *row.last().unwrap();
        let at_64 = row[rs.iter().position(|&r| r == 64).unwrap()];
        if at_64 < full * 1.15 {
            // matches the paper's observation
        } else {
            println!("   (note: perplexity at r=64 deviates {:.1}% from full)",
                     100.0 * (at_64 / full - 1.0));
        }
    }
    println!("\npaper claim: \"significant increase in perplexity only when r < 2^4\";");
    println!("expected shape: columns are ~flat until the far left of the table.");
}

fn estimate_params(model: &Model) -> usize {
    model.weights.tensors.values().map(|t| t.numel()).sum()
}
