//! Golden-vector parity: the rust native forward must reproduce the JAX
//! forward (exported by aot.py) on the trained weights. This is the
//! load-bearing test for the whole L2 ↔ L3 contract — if RMSNorm, RoPE,
//! SwiGLU or the attention differ in any detail, these fail loudly.

use hsr_attn::model::transformer::AttentionPolicy;
use hsr_attn::model::Model;
use hsr_attn::util::tensor_io::TensorBundle;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn load_golden(name: &str) -> (Model, TensorBundle) {
    let dir = artifacts_dir();
    let model = Model::load_named(&dir, name).expect("model bundle");
    let golden = TensorBundle::load(&dir.join(format!("golden_{name}"))).expect("golden bundle");
    (model, golden)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_model(name: &str, tol: f32) {
    let (model, golden) = load_golden(name);
    for seq in ["a", "b"] {
        let tokens: Vec<u32> = golden
            .get(&format!("tokens_{seq}"))
            .unwrap()
            .data
            .iter()
            .map(|&t| t as u32)
            .collect();
        let want = &golden.get(&format!("logits_{seq}")).unwrap().data;
        let got = model.forward_full(&tokens);
        let err = max_abs_diff(&got, want);
        assert!(
            err < tol,
            "{name}/seq_{seq}: native forward deviates from JAX by {err}"
        );
    }
}

#[test]
fn native_forward_matches_jax_mini() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    check_model("mini", 2e-3);
}

#[test]
fn native_forward_matches_jax_small() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    check_model("small", 2e-3);
}

#[test]
fn native_forward_matches_jax_base() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    check_model("base", 3e-3);
}

#[test]
fn native_decode_step_matches_jax_decode() {
    if !have_artifacts() {
        return;
    }
    let (model, golden) = load_golden("small");
    let tokens: Vec<u32> = golden
        .get("tokens_a")
        .unwrap()
        .data
        .iter()
        .map(|&t| t as u32)
        .collect();
    let want = &golden.get("decode_logits").unwrap().data;
    // Native: prefill 31 tokens then decode the 32nd — i.e. forward over
    // 32 tokens and take the last row.
    let got_all = model.forward_full(&tokens[..32]);
    let vocab = model.cfg.vocab;
    let got = &got_all[31 * vocab..32 * vocab];
    let err = max_abs_diff(got, want);
    assert!(err < 2e-3, "decode-step parity error {err}");
}

/// Sparse top-r attention with large r must match dense closely on the
/// trained model (regression test for the calibrated HSR path).
#[test]
fn sparse_policy_consistent_with_dense_on_trained_model() {
    if !have_artifacts() {
        return;
    }
    let (model, golden) = load_golden("mini");
    let tokens: Vec<u32> = golden
        .get("tokens_a")
        .unwrap()
        .data
        .iter()
        .map(|&t| t as u32)
        .collect();
    let dense = model.forward_full(&tokens);
    // r covering the whole cache ≡ dense.
    use hsr_attn::model::kv::KvState;
    use hsr_attn::model::transformer::RSpec;
    let mut kv = KvState::new(
        model.cfg.n_layers,
        model.cfg.n_heads,
        model.cfg.d_head,
        Some(hsr_attn::hsr::HsrBackend::BallTree),
    );
    let mut stats = Default::default();
    let sparse = model.prefill(
        &tokens,
        &mut kv,
        AttentionPolicy::TopR(RSpec::Fixed(4096)),
        &mut stats,
    );
    let err = max_abs_diff(&sparse, &dense);
    assert!(err < 1e-4, "top-r(covering) vs dense deviates by {err}");
}

#[test]
fn perplexity_is_sane_and_topr_close_to_dense() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let model = Model::load_named(&dir, "mini").expect("model");
    // Held-out-ish sample: reuse golden tokens (64 bytes).
    let (_, golden) = load_golden("mini");
    let mut tokens: Vec<u32> = golden
        .get("tokens_a")
        .unwrap()
        .data
        .iter()
        .map(|&t| t as u32)
        .collect();
    tokens.extend(
        golden
            .get("tokens_b")
            .unwrap()
            .data
            .iter()
            .map(|&t| t as u32),
    );
    use hsr_attn::model::transformer::RSpec;
    let nll_dense = model.nll(&tokens, AttentionPolicy::Dense);
    let nll_topr = model.nll(&tokens, AttentionPolicy::TopR(RSpec::Fixed(32)));
    // Trained to ~0.66 nats/byte on train data; held-out short seq looser.
    assert!(nll_dense < 4.0, "dense nll {nll_dense} too high — model broken?");
    // r=32 over <=63-token caches is nearly dense.
    assert!((nll_topr - nll_dense).abs() < 0.15, "topr {nll_topr} vs dense {nll_dense}");
}
