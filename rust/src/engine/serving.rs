//! The continuous-batching serving engine: Algorithm 1 integrated with a
//! paged KV cache, chunked prefill, preemption, a shared-prefix radix
//! cache and metrics — the L3 system the paper's decoding/prefilling
//! scenarios live inside.
//!
//! One `Engine` drives one model replica single-threaded (the router in
//! `router.rs` shards requests across engines/threads). Each `step()`:
//!
//! 1. **Admit** waiting requests while the batch and the block pool have
//!    room. Admission first matches the prompt against the radix prefix
//!    cache ([`crate::kvstore`]): matched tokens are *adopted* — never
//!    prefilled — and the sequence only reserves pool blocks for its
//!    private tail, so N clones of a cached prompt cost O(tail) each
//!    instead of O(prompt).
//! 2. **Prefill** admitted sequences in chunks (budgeted per step so long
//!    prompts cannot starve decodes — "chunked prefill"). Every chunk is
//!    bracketed by the adopt/publish hooks in `prefill.rs`: freshly
//!    computed prompt ranges are published into the radix cache and
//!    sibling sequences leapfrog onto them at their next chunk boundary,
//!    so each shared token is prefilled exactly once fleet-wide.
//! 3. **Decode** one token for every running sequence whose prompt is
//!    done, via the HSR-sparse attention policy. Sequences sharing a
//!    prefix chain decode as ONE query block — a single multi-query HSR
//!    traversal per chain segment per head.
//! 4. **Preempt** (release blocks, drop KV, requeue) when the pool is
//!    exhausted, per the configured victim policy — after first
//!    reclaiming unreferenced cached prefixes (LRU).

use super::metrics::Metrics;
use super::request::{
    FinishReason, GenerationParams, Request, RequestId, Response, Sequence,
};
use super::scheduler::SchedulerConfig;
use crate::attention::session::AttentionConfig;
use crate::hsr::HsrBackend;
use crate::kvstore::{
    PrefixCacheMode, PrefixStore, SharedKvMut, SpillConfig, SpillPolicy, TierConfig,
};
use crate::model::kv::KvState;
use crate::model::transformer::RSpec;
use crate::model::transformer::{
    sample, AttentionPolicy, BatchWorkspace, StepStats, Workspace,
};
use crate::model::Model;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker thread mid-step (exercises `catch_unwind`
    /// supervision in the router).
    Panic,
    /// One-shot sleep of `ms` milliseconds at the trigger step.
    Delay { ms: u32 },
    /// Sleep `ms` milliseconds at the trigger step **and every step
    /// after** — a wedged-but-alive worker.
    Stall { ms: u32 },
}

/// One deterministic fault: fire `kind` on worker `worker` when its
/// engine reaches step `step` (1-based; `Engine::step` counts calls).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub worker: u32,
    pub step: u64,
    pub kind: FaultKind,
}

/// Max faults a plan can hold (fixed array keeps `FaultPlan: Copy`).
pub const MAX_FAULTS: usize = 4;

/// Deterministic fault-injection plan, carried in [`EngineConfig`] so
/// supervision is testable: the router filters the plan per worker, and
/// clears it on the replacement engine after a caught panic so each
/// fault fires exactly once.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: [Option<Fault>; MAX_FAULTS],
}

impl FaultPlan {
    /// The empty plan (also `Default`).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a fault (builder style). Panics past [`MAX_FAULTS`] entries.
    pub fn with(mut self, f: Fault) -> FaultPlan {
        for slot in self.entries.iter_mut() {
            if slot.is_none() {
                *slot = Some(f);
                return self;
            }
        }
        panic!("FaultPlan holds at most {MAX_FAULTS} faults");
    }

    /// The sub-plan targeting one worker.
    pub fn for_worker(&self, worker: usize) -> FaultPlan {
        let mut out = FaultPlan::default();
        for f in self.entries.into_iter().flatten() {
            if f.worker as usize == worker {
                out = out.with(f);
            }
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }

    /// The fault firing at `step`, if any (plan already filtered to this
    /// worker). `Panic`/`Delay` fire at their exact step; `Stall` fires
    /// at its step and every later one.
    pub fn fire_at(&self, step: u64) -> Option<FaultKind> {
        self.entries.into_iter().flatten().find_map(|f| match f.kind {
            FaultKind::Panic | FaultKind::Delay { .. } if step == f.step => Some(f.kind),
            FaultKind::Stall { .. } if step >= f.step => Some(f.kind),
            _ => None,
        })
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: AttentionPolicy,
    /// HSR backend for per-head indices; None → brute scans inside the
    /// sparse policy (ablation) — ignored under `AttentionPolicy::Dense`.
    pub hsr_backend: Option<HsrBackend>,
    /// Total KV-cache capacity in tokens (across all sequences *and* the
    /// shared-prefix cache — one physical pool).
    pub cache_capacity_tokens: usize,
    /// Block granularity of the pool.
    pub block_tokens: usize,
    /// Shared-prefix KV cache policy (`on`, `off`, or a minimum matched
    /// token count). Adoption always selects the exact same top-r index
    /// sets as unshared decode (set-exactness is layout-independent);
    /// outputs are additionally bit-identical wherever the SIMD dot
    /// reduction is layout-independent (`d_head <= 8` or the scalar
    /// dispatch tier — see README "Prefix cache"). For larger heads the
    /// difference is confined to last-ulp dot-reduction order.
    pub prefix_cache: PrefixCacheMode,
    /// Cold-tier spill store for the prefix cache: where LRU-evicted,
    /// unreferenced segments demote to (lossless-compressed) instead of
    /// being destroyed, to be refaulted on a later prefix match. `Off`
    /// keeps the pre-tier destroy-on-evict behavior.
    pub spill: SpillConfig,
    /// What happens to a demoted segment's HSR indices: serialized into
    /// the cold record, or rebuilt from the keys at refault (see
    /// [`SpillPolicy`]).
    pub spill_policy: SpillPolicy,
    pub scheduler: SchedulerConfig,
    /// Sampling seed (deterministic engines → reproducible serving runs).
    pub seed: u64,
    /// Base of the request-id space (routers give each worker a disjoint
    /// range so ids are globally unique).
    pub id_offset: u64,
    /// Worker threads for the batched per-(layer, head) decode sweep:
    /// 0 → one per available core, 1 → serial. Outputs are identical
    /// either way (deterministic shard merge).
    pub decode_threads: usize,
    /// Deterministic fault injection (empty in production). The engine
    /// consults the plan at the top of every `step`; the router filters
    /// it per worker.
    pub faults: FaultPlan,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AttentionPolicy::Dense,
            hsr_backend: Some(HsrBackend::BallTree),
            cache_capacity_tokens: 1 << 20,
            block_tokens: 64,
            prefix_cache: PrefixCacheMode::default(),
            spill: SpillConfig::Off,
            spill_policy: SpillPolicy::default(),
            scheduler: SchedulerConfig::default(),
            seed: 0,
            id_offset: 0,
            decode_threads: 0,
            faults: FaultPlan::none(),
        }
    }
}

impl EngineConfig {
    /// Build a serving config from the unified [`AttentionConfig`]. The
    /// serving engine consumes exactly three of its knobs: `backend`
    /// feeds the per-head dynamic indices, `threads` drives the batched
    /// per-(layer, head) decode sweep, and `top_r` (if set) becomes a
    /// fixed-r sparse policy — otherwise the paper's r = n^{4/5}
    /// scaling. `kind`, `threshold` and `adaptive_sigma_k` do **not**
    /// apply here: the transformer path is softmax-only and calibrates
    /// its per-head thresholds at runtime from observed score quantiles
    /// (see `model/transformer.rs`), so those fields are ignored.
    pub fn from_attention(att: AttentionConfig) -> EngineConfig {
        EngineConfig {
            policy: match att.top_r {
                Some(r) => AttentionPolicy::TopR(RSpec::Fixed(r)),
                None => AttentionPolicy::TopR(RSpec::paper()),
            },
            hsr_backend: Some(att.backend),
            decode_threads: att.threads,
            ..EngineConfig::default()
        }
    }
}

/// A single-replica serving engine.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: EngineConfig,
    /// Shared-prefix KV store: block pool (capacity + payload owner in
    /// one place) plus the refcounted radix prefix index.
    store: PrefixStore,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    finished: Vec<Response>,
    ws: Workspace,
    bws: BatchWorkspace,
    rng: crate::util::rng::Rng,
    pub metrics: Metrics,
    next_id: RequestId,
    /// `step()` calls so far (drives deterministic fault injection).
    steps: u64,
}

impl Engine {
    pub fn new(model: Arc<Model>, cfg: EngineConfig) -> Engine {
        let ws = Workspace::new(&model);
        let mut bws = BatchWorkspace::new(&model);
        bws.threads = cfg.decode_threads;
        // Segments only carry HSR indices a sparse policy will query.
        let seg_backend = match cfg.policy {
            AttentionPolicy::Dense => None,
            AttentionPolicy::TopR(_) => cfg.hsr_backend,
        };
        Engine {
            store: PrefixStore::with_tier(
                cfg.cache_capacity_tokens,
                cfg.block_tokens,
                seg_backend,
                cfg.prefix_cache,
                &TierConfig { spill: cfg.spill.clone(), policy: cfg.spill_policy },
            ),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            ws,
            bws,
            rng: crate::util::rng::Rng::new(cfg.seed),
            metrics: Metrics::default(),
            next_id: cfg.id_offset + 1,
            steps: 0,
            model,
            cfg,
        }
    }

    fn new_sequence(&self, req: Request) -> Sequence {
        let c = &self.model.cfg;
        Sequence {
            id: req.id,
            priority: req.id, // submission order
            kv: KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend),
            prompt: req.prompt,
            params: req.params,
            generated: Vec::new(),
            submitted: Instant::now(),
            first_token_at: None,
            blocks: Vec::new(),
            prefilled: 0,
            folded: 0,
            prefix: Vec::new(),
            prefix_len: 0,
            attempts: req.attempts,
            stream: req.stream,
        }
    }

    /// Submit a request; returns its id. Engine-assigned ids start at
    /// `cfg.id_offset + 1`; this path never rejects (the bounded-queue
    /// entry point is [`Engine::submit_request`]).
    pub fn submit(&mut self, prompt: Vec<u32>, params: GenerationParams) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.enqueue_request(Request { id, prompt, params, attempts: 0, stream: None });
        id
    }

    /// Submit a caller-assigned request, rejecting (and returning it)
    /// when the waiting queue is at `scheduler.max_waiting` — the
    /// per-worker bound behind the router's admission control.
    pub fn submit_request(&mut self, req: Request) -> Result<RequestId, Request> {
        if self.waiting.len() >= self.cfg.scheduler.max_waiting {
            return Err(req);
        }
        let id = req.id;
        self.enqueue_request(req);
        Ok(id)
    }

    fn enqueue_request(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        self.metrics.prompt_tokens += req.prompt.len() as u64;
        let seq = self.new_sequence(req);
        self.waiting.push_back(seq);
    }

    /// Whether any work remains.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Sequences currently decoding/prefilling.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// The shared-prefix store (diagnostics / tests).
    pub fn prefix_store(&self) -> &PrefixStore {
        &self.store
    }

    /// Drain completed responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// One scheduler iteration; returns the number of tokens processed.
    ///
    /// Sequences are served strictly in priority (submission) order and a
    /// sequence may only preempt strictly-younger ones, so the oldest
    /// running sequence always makes progress — no preemption livelock.
    ///
    /// Prefill chunks run inline during the priority walk (bracketed by
    /// the radix adopt/publish hooks); decode-ready sequences are
    /// *collected* and then decoded as **one batched model step** —
    /// every sequence's row flows through the per-(layer, head)
    /// attention sweep together, grouped by shared prefix chain.
    pub fn step(&mut self) -> usize {
        let t0 = Instant::now();
        self.steps += 1;
        if let Some(kind) = self.cfg.faults.fire_at(self.steps) {
            match kind {
                FaultKind::Panic => panic!(
                    "injected fault: worker panic at engine step {}",
                    self.steps
                ),
                FaultKind::Delay { ms } | FaultKind::Stall { ms } => {
                    std::thread::sleep(std::time::Duration::from_millis(ms as u64));
                }
            }
        }
        self.abort_expired();
        self.abort_severed();
        self.admit();
        let model = Arc::clone(&self.model);
        let mut tokens = 0usize;
        let budget = self.cfg.scheduler.step_token_budget.max(1);
        let mut stats = StepStats::default();
        let mut decode_ids: Vec<RequestId> = Vec::new();

        // Serve in priority order; `running` mutates during the loop, so
        // look sequences up by id.
        let mut order: Vec<(u64, RequestId)> =
            self.running.iter().map(|s| (s.priority, s.id)).collect();
        order.sort_unstable();
        for (_, sid) in order {
            if tokens >= budget {
                break;
            }
            let Some(i) = self.running.iter().position(|s| s.id == sid) else {
                continue; // finished or preempted earlier in this step
            };
            // Adopt a longer cached prefix before sizing the reservation
            // — adoption shrinks the tail this sequence needs blocks for
            // (and releases the blocks its dropped tail held).
            {
                let seq = &mut self.running[i];
                if seq.prefilled < seq.prompt.len() {
                    super::prefill::adopt_cached_prefix(
                        &mut self.store,
                        seq,
                        &mut self.metrics,
                        &model.cfg,
                        self.cfg.hsr_backend,
                        self.cfg.scheduler.refault_token_budget,
                    );
                }
            }
            // Reserve capacity for this sequence's next chunk (private
            // tail only — the shared chain holds its own pages); preempt
            // younger sequences if the pool is exhausted.
            let needed_now = {
                let seq = &self.running[i];
                if seq.prefilled < seq.prompt.len() {
                    let chunk = self
                        .cfg
                        .scheduler
                        .prefill_chunk
                        .min(seq.prompt.len() - seq.prefilled)
                        .min(budget - tokens)
                        .max(1);
                    seq.tail_tokens() + chunk
                } else {
                    seq.tail_tokens() + 1
                }
            };
            if !self.reserve_for(i, needed_now) {
                continue; // cannot make room without evicting elders: wait
            }
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("sequence survives its own reservation");
            let seq = &mut self.running[i];
            if seq.prefilled < seq.prompt.len() {
                // --- chunked prefill ---
                let chunk = self
                    .cfg
                    .scheduler
                    .prefill_chunk
                    .min(seq.prompt.len() - seq.prefilled)
                    .min(budget - tokens)
                    .max(1);
                {
                    // The chain cannot change inside the chunk, so the
                    // view is built once per chunk, not per token.
                    let mut skv = SharedKvMut {
                        prefix: self.store.chain_view(&seq.prefix),
                        tail: &mut seq.kv,
                    };
                    for t in 0..chunk {
                        let tok = seq.prompt[seq.prefilled + t];
                        let logits = model.decode_step_shared(
                            tok,
                            &mut skv,
                            self.cfg.policy,
                            &mut self.ws,
                            &mut stats,
                        );
                        // Logits of the last prompt token seed the first
                        // generated token.
                        if seq.prefilled + t + 1 == seq.prompt.len() {
                            let next =
                                sample(&logits, seq.params.temperature, &mut self.rng);
                            seq.generated.push(next);
                            seq.first_token_at = Some(Instant::now());
                            // Folded tokens re-fed after a preemption go
                            // through prefill, not this sample — only the
                            // genuinely new token is streamed, so the wire
                            // sequence stays contiguous across preemptions.
                            if let Some(sink) = &seq.stream {
                                if sink.push_token(next) {
                                    self.metrics.tokens_streamed += 1;
                                }
                            }
                        }
                    }
                }
                seq.prefilled += chunk;
                tokens += chunk;
                // Publish the freshly computed range so siblings (and
                // future identical prompts) can adopt it.
                let headroom = self.cfg.scheduler.prefix_headroom_blocks;
                super::prefill::publish_prefix(
                    &mut self.store,
                    seq,
                    &mut self.metrics,
                    headroom,
                );
            } else {
                // --- decode-ready: defer into the batched model step ---
                let last = *seq
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token");
                let finished_by_stop = seq.params.stop_token == Some(last);
                if finished_by_stop || seq.done() {
                    self.finish(i, if finished_by_stop { FinishReason::StopToken } else { FinishReason::Length });
                    continue; // running[i] replaced by swap_remove
                }
                // Safe to defer: the walk visits oldest-first and
                // reservations only ever preempt strictly-younger
                // sequences, so a collected member is never evicted
                // before the batch runs.
                decode_ids.push(sid);
                tokens += 1;
            }
        }
        self.decode_batch(&decode_ids, &mut stats);
        self.metrics.record_step_stats(&stats);
        self.sync_tier_metrics();
        if tokens > 0 {
            self.metrics.step_latency.record(t0.elapsed());
        }
        tokens
    }

    /// Copy the pool's cumulative tier counters onto the metrics (the
    /// events happen deep inside the pool, far from any `&mut Metrics`,
    /// so the pool accumulates and the engine syncs once per step).
    /// Set-style, not additive: both sides are totals for this engine.
    fn sync_tier_metrics(&mut self) {
        let s = self.store.pool.tier_stats();
        self.metrics.segments_spilled = s.segments_spilled;
        self.metrics.segments_refaulted = s.segments_refaulted;
        self.metrics.spill_bytes = s.spill_bytes;
        self.metrics.refault_rebuild_ms = s.refault_rebuild_ns as f64 * 1e-6;
        self.metrics.dedup_hits = s.dedup_hits;
        self.metrics.dedup_bytes_saved = s.dedup_bytes_saved;
    }

    /// Decode one token for each collected sequence as a single batched
    /// model step, with the batch partitioned into shared-prefix groups:
    /// members of one group (identical segment chains) flow through the
    /// per-(layer, head) sweep as ONE query block per chain segment.
    /// Sampling stays in priority order so the RNG stream is
    /// deterministic regardless of grouping.
    fn decode_batch(&mut self, ids: &[RequestId], stats: &mut StepStats) {
        if ids.is_empty() {
            return;
        }
        // Batch members in running-vector order (for borrow splitting);
        // each entry is (running index, id).
        let mut members: Vec<(usize, RequestId)> = ids
            .iter()
            .map(|&sid| {
                let i = self
                    .running
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("batch members survive the walk");
                (i, sid)
            })
            .collect();
        members.sort_unstable();
        let tokens: Vec<u32> = members
            .iter()
            .map(|&(i, _)| {
                *self.running[i]
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token")
            })
            .collect();
        // Shared-prefix grouping over the batch (chains are radix node
        // id vectors; equal chain ⇒ identical shared segments).
        let chains: Vec<&[u32]> = members
            .iter()
            .map(|&(i, _)| self.running[i].prefix.as_slice())
            .collect();
        let groups = super::decode::group_by_chain(&chains);
        for g in &groups {
            if g.len() > 1 {
                self.metrics.grouped_decode_rows += g.len() as u64;
            }
        }
        drop(chains);
        let model = Arc::clone(&self.model);
        let policy = self.cfg.policy;
        let store = &self.store;
        let bws = &mut self.bws;
        let mut views: Vec<SharedKvMut> = Vec::with_capacity(members.len());
        let mut next_member = 0usize;
        for (i, seq) in self.running.iter_mut().enumerate() {
            if next_member < members.len() && members[next_member].0 == i {
                views.push(SharedKvMut {
                    prefix: store.chain_view(&seq.prefix),
                    tail: &mut seq.kv,
                });
                next_member += 1;
            }
        }
        debug_assert_eq!(views.len(), members.len());
        let logits =
            model.decode_step_batch_shared(&tokens, &mut views, &groups, policy, bws, stats);
        drop(views);
        // Sample in submission-priority order (the `ids` order).
        for &sid in ids {
            let bpos = members
                .iter()
                .position(|&(_, s)| s == sid)
                .expect("member list covers ids");
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("no sequence finishes during the batch");
            let seq = &mut self.running[i];
            let next = sample(&logits[bpos], seq.params.temperature, &mut self.rng);
            seq.generated.push(next);
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(Instant::now());
            }
            self.metrics.generated_tokens += 1;
            if let Some(sink) = &seq.stream {
                // A refused push means the consumer overran the buffer;
                // the sink is now severed and abort_severed() sheds this
                // sequence at the top of the next step.
                if sink.push_token(next) {
                    self.metrics.tokens_streamed += 1;
                }
            }
        }
    }

    /// True once every admitted prompt is fully prefilled and nothing is
    /// waiting — the steady decode phase the serving bench reports
    /// separately from time-to-first-token.
    pub fn steady_state(&self) -> bool {
        self.waiting.is_empty()
            && self.running.iter().all(|s| s.prefilled >= s.prompt.len())
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.has_work() {
            let processed = self.step();
            if processed > 0 {
                continue;
            }
            // No progress anywhere. Transient contention never reaches
            // this point (any served token counts as progress), so what
            // follows are genuine-stall fallbacks, tried mildest-first.
            //
            // (0) The pool may be wedged by adopted chain segments whose
            // only references belong to the stalled sequences themselves
            // — self-reference makes them unevictable. Shed the oldest
            // holder's chain (deref + targeted evict + private
            // recompute): its pages return to the pool and the classic
            // guarantee that the oldest sequence can claim the whole
            // pool is restored. Repeated stalls shed the remaining
            // holders one per iteration, so this terminates.
            let holder = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.prefix.is_empty())
                .min_by_key(|(_, s)| s.priority)
                .map(|(i, _)| i);
            if let Some(idx) = holder {
                self.shed_prefix(idx);
                continue;
            }
            // (a) A running sequence larger than the whole pool.
            let seq_too_big = self.running.iter().position(|s| {
                self.store.pool.blocks_for(s.prompt.len() + s.params.max_new_tokens)
                    > self.store.pool.total_blocks()
            });
            if let Some(idx) = seq_too_big {
                self.finish(idx, FinishReason::Aborted);
                continue;
            }
            // (b) Nothing running and the head-of-line waiting request can
            // never be admitted (prompt exceeds the pool).
            if self.running.is_empty() {
                if let Some(seq) = self.waiting.front() {
                    if self.store.pool.blocks_for(seq.prompt.len() + 1)
                        > self.store.pool.total_blocks()
                    {
                        let mut seq = self.waiting.pop_front().unwrap();
                        self.store.pool.release(&mut seq.blocks);
                        self.emit_response(seq, FinishReason::Aborted);
                        continue;
                    }
                }
            }
        }
    }

    /// Remove waiting[j], release anything it holds, and emit a terminal
    /// response. (Waiting sequences normally hold no blocks or chain
    /// refs; releasing is defensive.)
    fn drop_waiting(&mut self, j: usize, reason: FinishReason) {
        let mut seq = self.waiting.remove(j).expect("index in bounds");
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        self.emit_response(seq, reason);
    }

    /// Abort every sequence — running or waiting — past its deadline,
    /// releasing its KV blocks and chain references. Runs at the top of
    /// each step, so an expired sequence never burns another decode.
    fn abort_expired(&mut self) {
        let now = Instant::now();
        let expired = |p: &GenerationParams| p.deadline.is_some_and(|d| now >= d);
        let mut i = 0;
        while i < self.running.len() {
            if expired(&self.running[i].params) {
                self.metrics.deadline_aborts += 1;
                self.finish(i, FinishReason::DeadlineExceeded);
                // finish() swap_removes: recheck index i.
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.waiting.len() {
            if expired(&self.waiting[j].params) {
                self.metrics.deadline_aborts += 1;
                self.drop_waiting(j, FinishReason::DeadlineExceeded);
            } else {
                j += 1;
            }
        }
    }

    /// Shed every sequence whose stream sink was severed (the consumer
    /// fell a full send-buffer behind). Runs at the top of each step so
    /// a severed stream stops consuming decode budget immediately; the
    /// sequence still reaches exactly one terminal outcome (`Cancelled`
    /// here — the router maps a severed sink to a `slow_consumer`
    /// terminal error frame). Waiting sequences are swept too: a
    /// preempted sequence keeps its sink and can sever while requeued.
    fn abort_severed(&mut self) {
        let severed =
            |s: &Sequence| s.stream.as_ref().is_some_and(|k| k.is_severed());
        let mut i = 0;
        while i < self.running.len() {
            if severed(&self.running[i]) {
                self.metrics.slow_consumer_sheds += 1;
                self.finish(i, FinishReason::Cancelled);
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.waiting.len() {
            if severed(&self.waiting[j]) {
                self.metrics.slow_consumer_sheds += 1;
                self.drop_waiting(j, FinishReason::Cancelled);
            } else {
                j += 1;
            }
        }
    }

    /// Cancel a request wherever it lives (running or waiting); returns
    /// true if found. The request still reaches exactly one terminal
    /// outcome: a `Cancelled` response carrying whatever was generated.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(i) = self.running.iter().position(|s| s.id == id) {
            self.metrics.disconnect_aborts += 1;
            self.finish(i, FinishReason::Cancelled);
            return true;
        }
        if let Some(j) = self.waiting.iter().position(|s| s.id == id) {
            self.metrics.disconnect_aborts += 1;
            self.drop_waiting(j, FinishReason::Cancelled);
            return true;
        }
        false
    }

    /// Abort everything in flight (forced shutdown after the drain
    /// window expires). Every sequence gets an `Aborted` response.
    pub fn abort_all(&mut self) {
        while !self.waiting.is_empty() {
            self.drop_waiting(0, FinishReason::Aborted);
        }
        while !self.running.is_empty() {
            self.finish(0, FinishReason::Aborted);
        }
    }

    /// Drain every in-flight request after a caught panic. Returns
    /// `(retryable, failed)`: retryable requests never produced a
    /// visible token — and, since tokens are streamed at sample time,
    /// never streamed one either — so they are safe to re-dispatch
    /// verbatim to a survivor. The rest had progress a replay could not
    /// reproduce and must be answered with a structured error; each
    /// carries its emitted-token count (tokens streamed for streaming
    /// requests, tokens generated otherwise) for that error's
    /// truncation report. Pool/radix state is *not* released — the
    /// caller discards the whole engine.
    pub fn salvage(&mut self) -> (Vec<Request>, Vec<(Request, u64)>) {
        let mut retry = Vec::new();
        let mut dead = Vec::new();
        let drained: Vec<Sequence> =
            self.waiting.drain(..).chain(self.running.drain(..)).collect();
        for seq in drained {
            let fresh = seq.generated.is_empty() && seq.folded == 0;
            let emitted = seq
                .stream
                .as_ref()
                .map(|s| s.tokens_pushed())
                .unwrap_or(seq.generated.len() as u64);
            let req = Request {
                id: seq.id,
                prompt: seq.prompt,
                params: seq.params,
                attempts: seq.attempts,
                stream: seq.stream,
            };
            if fresh {
                retry.push(req);
            } else {
                dead.push((req, emitted));
            }
        }
        (retry, dead)
    }

    /// After a full drain: evict every cached prefix and report KV
    /// blocks still held — the leak count (0 in a correct engine),
    /// cross-checked against the allocator's debug ledger.
    pub fn reclaim_and_count_leaks(&mut self) -> usize {
        assert!(!self.has_work(), "leak check requires a drained engine");
        // Full teardown reclaims the cold tier too (spill extents are
        // released alongside hot blocks; see `RadixIndex::evict_lru`).
        let evicted = self.store.make_room(usize::MAX);
        self.metrics.prefix_segments_evicted += evicted as u64;
        self.sync_tier_metrics();
        let leaked =
            self.store.pool.total_blocks() - self.store.pool.free_blocks();
        if leaked == 0 {
            self.store.pool.debug_assert_all_free();
        }
        leaked
    }

    /// Admit waiting sequences while there is batch room and pool room
    /// for their prompts. Admission matches the prompt against the radix
    /// cache first: matched tokens are adopted outright (never
    /// prefilled) and only the unmatched remainder reserves pool blocks.
    fn admit(&mut self) {
        while self.running.len() < self.cfg.scheduler.max_batch {
            let Some(front) = self.waiting.front() else { break };
            // A matched chain may hold cold (spilled) nodes; the lookup
            // refaults them within the scheduler's token budget before
            // handing the chain out, LRU-evicting other unreferenced
            // prefixes if blocks are short.
            let (chain, matched) = self.store.lookup_budgeted(
                &front.prompt,
                self.cfg.scheduler.refault_token_budget,
            );
            self.metrics.prefix_segments_evicted +=
                self.store.take_refault_evictions() as u64;
            if self.store.enabled() {
                self.metrics.prefix_lookups += 1;
            }
            // Reserve the unmatched prompt remainder + one decode token.
            let need = self
                .store
                .pool
                .blocks_for(front.prompt.len() - matched + 1);
            if need > self.store.pool.free_blocks() {
                // Keep the candidate chain alive while LRU eviction of
                // other unreferenced prefixes makes room.
                self.store.radix.ref_chain(&chain);
                let evicted = self.store.make_room(need);
                self.metrics.prefix_segments_evicted += evicted as u64;
                self.store.radix.deref_chain(&chain);
            }
            if need > self.store.pool.free_blocks() {
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            // Every admission demands a full-prompt prefill (preempted
            // re-admissions included) — the skip-rate denominator.
            self.metrics.prefill_tokens_demanded += seq.prompt.len() as u64;
            if matched > 0 {
                self.store.radix.ref_chain(&chain);
                seq.prefix = chain;
                seq.prefix_len = matched;
                seq.prefilled = matched;
                self.store.seed_calib(&seq.prefix, &mut seq.kv);
                self.metrics.prefix_hits += 1;
                self.metrics.prefill_tokens_skipped += matched as u64;
            }
            let mut blocks = self.store.pool.alloc(need).expect("checked free_blocks");
            seq.blocks.append(&mut blocks);
            self.running.push(seq);
        }
    }

    /// Ensure sequence `idx` holds blocks for `needed_tail_tokens` of
    /// private tail, first LRU-evicting unreferenced cached prefixes,
    /// then preempting strictly-younger sequences. Returns false if room
    /// could not be made. The requesting sequence is never evicted here.
    fn reserve_for(&mut self, idx: usize, needed_tail_tokens: usize) -> bool {
        let sid = self.running[idx].id;
        loop {
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("requester is never preempted by reserve_for");
            let my_priority = self.running[i].priority;
            let seq = &mut self.running[i];
            if self.store.pool.ensure(&mut seq.blocks, needed_tail_tokens) {
                return true;
            }
            // Reclaim unreferenced cached prefixes before touching any
            // live sequence.
            let deficit = self
                .store
                .pool
                .blocks_for(needed_tail_tokens)
                .saturating_sub(seq.blocks.len());
            let evicted = self.store.make_room(deficit);
            if evicted > 0 {
                self.metrics.prefix_segments_evicted += evicted as u64;
                continue;
            }
            // Evict a strictly-younger sequence, if any. Victim size is
            // its private tail — that is what preemption frees (its
            // chain refs drop too, making those segments evictable).
            let candidates: Vec<(usize, usize, u64)> = self
                .running
                .iter()
                .enumerate()
                .filter(|&(_, s)| s.priority > my_priority)
                .map(|(j, s)| (j, s.tail_tokens(), s.priority))
                .collect();
            match self.cfg.scheduler.pick_victim(&candidates) {
                Some(victim) => self.preempt(victim),
                None => return false, // only elders left: wait our turn
            }
        }
    }

    /// Shed an adopted chain without leaving the running set: drop the
    /// chain references, release the tail, and fold generated tokens
    /// back into the prompt for private recompute (exactly preemption's
    /// recompute semantics, minus the requeue — requeueing would just
    /// re-adopt the same cached chain and stall again). Once shed, the
    /// old chain's segments are unreferenced and this sequence's next
    /// reservation can evict them.
    fn shed_prefix(&mut self, idx: usize) {
        let seq = &mut self.running[idx];
        let chain = std::mem::take(&mut seq.prefix);
        self.store.radix.deref_chain(&chain);
        // Evict what we just released (leaf-first, stopping at nodes
        // other sequences still share) so the next lookup cannot simply
        // re-adopt the chain and wedge again.
        let evicted = self.store.radix.evict_chain(&mut self.store.pool, &chain);
        self.metrics.prefix_segments_evicted += evicted as u64;
        seq.prefix_len = 0;
        self.store.pool.release(&mut seq.blocks);
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        seq.prefilled = 0;
        let mut prompt = std::mem::take(&mut seq.prompt);
        prompt.extend(seq.generated[seq.folded..].iter().copied());
        seq.folded = seq.generated.len();
        seq.prompt = prompt;
        self.metrics.prefix_sheds += 1;
    }

    /// Preempt: release tail blocks, drop the chain references and the
    /// private KV, requeue for full recompute. A re-admitted sequence
    /// typically refaults straight onto its own published prefix — the
    /// radix cache turns preemption recompute into a lookup.
    fn preempt(&mut self, idx: usize) {
        let mut seq = self.running.swap_remove(idx);
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        seq.prefilled = 0;
        // Generated tokens so far are preserved: they are re-fed as part
        // of the (extended) prompt on re-admission. Only the suffix not
        // folded by an earlier preemption/shed is appended — folding all
        // of `generated` twice would duplicate early generations in the
        // prompt.
        let mut prompt = std::mem::take(&mut seq.prompt);
        prompt.extend(seq.generated[seq.folded..].iter().copied());
        seq.folded = seq.generated.len();
        seq.prompt = prompt;
        self.metrics.requests_preempted += 1;
        self.waiting.push_front(seq);
    }

    /// Finish running[idx] with the given reason.
    fn finish(&mut self, idx: usize, reason: FinishReason) {
        let mut seq = self.running.swap_remove(idx);
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        self.emit_response(seq, reason);
    }

    fn emit_response(&mut self, seq: Sequence, reason: FinishReason) {
        let latency = seq.submitted.elapsed();
        let ttft = seq
            .first_token_at
            .map(|t| t.duration_since(seq.submitted))
            .unwrap_or(latency);
        self.metrics.requests_completed += 1;
        self.metrics.request_latency.record(latency);
        self.metrics.ttft.record(ttft);
        self.finished.push(Response {
            id: seq.id,
            tokens: seq.generated,
            finish: reason,
            latency_ms: latency.as_secs_f64() * 1e3,
            ttft_ms: ttft.as_secs_f64() * 1e3,
            prompt_len: seq.prompt.len(),
        });
    }

    /// Pool utilization (diagnostics).
    pub fn cache_utilization(&self) -> f64 {
        self.store.pool.utilization()
    }
}
