//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Shape/dtype of one executable input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One HLO artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Extra scalar attributes (n_ctx, r_max, heads, ...).
    pub attrs: BTreeMap<String, f64>,
}

/// The whole manifest: model configs + HLO artifacts.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub hlo: BTreeMap<String, ArtifactSpec>,
    /// Model-name → config object (raw JSON, parsed by `model::Model`).
    pub models: BTreeMap<String, Json>,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v.req_str("name")?.to_string(),
        shape: v
            .req_arr("shape")?
            .iter()
            .map(|s| s.as_usize().context("bad shape"))
            .collect::<Result<_>>()?,
        dtype: v.req_str("dtype")?.to_string(),
    })
}

impl ArtifactManifest {
    pub fn load(path: &Path) -> Result<ArtifactManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut manifest = ArtifactManifest::default();
        if let Some(Json::Obj(models)) = root.get("models") {
            for (k, v) in models {
                manifest.models.insert(k.clone(), v.clone());
            }
        }
        let Some(Json::Obj(hlo)) = root.get("hlo") else {
            anyhow::bail!("manifest missing 'hlo' object");
        };
        for (key, entry) in hlo {
            let file = entry.req_str("file")?.to_string();
            let inputs = match entry.get("inputs") {
                Some(Json::Arr(v)) => v.iter().map(parse_io).collect::<Result<_>>()?,
                _ => Vec::new(),
            };
            let outputs = match entry.get("outputs") {
                Some(Json::Arr(v)) => v.iter().map(parse_io).collect::<Result<_>>()?,
                _ => Vec::new(),
            };
            let mut attrs = BTreeMap::new();
            if let Json::Obj(m) = entry {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        attrs.insert(k.clone(), x);
                    }
                }
            }
            manifest
                .hlo
                .insert(key.clone(), ArtifactSpec { file, inputs, outputs, attrs });
        }
        Ok(manifest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("hsr_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        std::fs::write(
            &path,
            r#"{"models":{"mini":{"d_model":64}},
                "hlo":{"k":{"file":"k.hlo.txt","r_max":256,
                  "inputs":[{"name":"q","shape":[4,32],"dtype":"f32"}],
                  "outputs":[{"name":"o","shape":[4,32],"dtype":"f32"}]}}}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&path).unwrap();
        assert_eq!(m.hlo["k"].file, "k.hlo.txt");
        assert_eq!(m.hlo["k"].inputs[0].shape, vec![4, 32]);
        assert_eq!(m.hlo["k"].attrs["r_max"], 256.0);
        assert!(m.models.contains_key("mini"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(ArtifactManifest::load(Path::new("/nonexistent/m.json")).is_err());
    }
}
