//! Algorithm 1 — Generation Decoding.
//!
//! The paper's `GenerationDecoding` data structure, verbatim:
//!
//! ```text
//! INIT({K_i}, V, n, d):   b ← σ_a √(0.4 log n);  HSR.INIT({K_i}, n, d)
//! INFERENCE(Q, m):        for i in 1..m:
//!                           S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                           A_{i,j} ← ReLU^α(⟨Q_i,K_j⟩/√d − b)  (or Softmax)
//!                         return D^{-1} A V
//! ```
//!
//! Since the session API landed this type is a **thin caller** of
//! [`AttentionSession`] — the plan→execute split, the multi-query shared
//! HSR traversal, the bucketed value gather, and the scoped-thread row
//! sharding all live in [`crate::attention::session`]. The struct (and
//! its public fields) is kept as a deprecated-style shim for one release
//! so existing callers and benches keep compiling; new code should build
//! an [`AttentionConfig`] and drive the session directly:
//!
//! ```text
//! let mut s = AttentionConfig::new(kind, backend).with_bias(b).build(&k, d);
//! let mut plan = s.plan(&q);           // fired sets + carried scores
//! s.execute(&mut plan, &v, &mut out);  // bucketed gather
//! ```

use crate::attention::session::{AttentionConfig, AttentionSession};
use crate::attention::threshold::ThresholdParams;
use crate::attention::AttentionKind;
use crate::hsr::{HsrBackend, QueryStats};

/// The paper's Algorithm 1 over raw K/V matrices (deprecated shim over
/// [`AttentionSession`]; fields are synced into the session per call).
pub struct GenerationDecoding {
    /// The unified session: dynamic HSR index + plan/execute machinery.
    session: AttentionSession,
    /// Values, row-major [n, d] (grows on append).
    values: Vec<f32>,
    /// Threshold b on the scaled score ⟨q,k⟩/√d (Lemma 6.1).
    pub bias: f32,
    /// Which attention to evaluate on the reported set.
    pub kind: AttentionKind,
    /// For softmax: restrict to top-r of the report (Theorem 4.2);
    /// None → use the whole reported set.
    pub top_r: Option<usize>,
    /// Key std σ_k for the per-query adaptive softmax threshold.
    pub sigma_k: f64,
    /// Worker threads for the batched query-row loop: 0 → one per
    /// available core, 1 → serial. Output is bit-identical either way.
    pub threads: usize,
    /// Accumulated query-work counters.
    pub stats: QueryStats,
}

impl GenerationDecoding {
    /// INIT: build the HSR structure over the KV cache.
    /// `bias` is on the scaled score; pass
    /// `ThresholdParams::practical_bias` / `bias` / a calibrated value.
    pub fn init(
        keys: &[f32],
        values: &[f32],
        d: usize,
        bias: f32,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        assert_eq!(keys.len(), values.len());
        assert_eq!(keys.len() % d, 0);
        let session = AttentionConfig::new(kind, backend)
            .with_bias(bias)
            .with_adaptive(1.0)
            .build(keys, d);
        GenerationDecoding {
            session,
            values: values.to_vec(),
            bias,
            kind,
            top_r: None,
            sigma_k: 1.0,
            threads: 0,
            stats: QueryStats::default(),
        }
    }

    /// INIT with the paper's Lemma 6.1 threshold for Gaussian K/Q.
    pub fn init_gaussian(
        keys: &[f32],
        values: &[f32],
        d: usize,
        m: usize,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        let n = keys.len() / d;
        let params = ThresholdParams::standard(d, m);
        let bias = params.practical_bias(n.max(2)) as f32;
        GenerationDecoding::init(keys, values, d, bias, kind, backend)
    }

    /// Number of cached (key, value) rows.
    pub fn len(&self) -> usize {
        self.session.len()
    }

    pub fn is_empty(&self) -> bool {
        self.session.is_empty()
    }

    /// The underlying session (the non-deprecated API surface).
    pub fn session(&self) -> &AttentionSession {
        &self.session
    }

    /// Append a generated token's (k, v) — Theorem D.2's auto-regressive
    /// cache growth, amortized-logarithmic via the dynamic HSR.
    pub fn append(&mut self, key: &[f32], value: &[f32]) {
        assert_eq!(value.len(), self.session.dim());
        self.session.append_key(key);
        self.values.extend_from_slice(value);
    }

    /// Copy this shim's (externally mutable) knobs into the session.
    fn sync(&mut self) {
        self.session.kind = self.kind;
        self.session.top_r = self.top_r;
        self.session.bias = self.bias;
        self.session.adaptive_sigma_k = Some(self.sigma_k);
        self.session.threads = self.threads;
    }

    /// INFERENCE for a single query row; writes the attention output into
    /// `out` (length d) and returns the activated-set size k̃. This is
    /// exactly the B = 1 case of [`GenerationDecoding::inference_batch`],
    /// so serial and batched decode agree bit-for-bit.
    pub fn inference_row(&mut self, q: &[f32], out: &mut [f32]) -> usize {
        let d = self.session.dim();
        assert_eq!(q.len(), d);
        assert_eq!(out.len(), d);
        let mut fired = [0usize; 1];
        self.sync();
        self.session.run(q, &self.values, out, &mut fired);
        self.stats = self.session.stats;
        fired[0]
    }

    /// INFERENCE over B query rows at once (the batched decode engine):
    /// one [`AttentionSession::run`] — per-row adaptive thresholds and
    /// top-r fallbacks exactly as in the serial path, block-shared HSR
    /// traversals, fused bucketed value gathers, rows sharded across
    /// scoped worker threads. Output is bit-identical to the serial row
    /// loop. Writes the [B, d] attention output into `out` and the
    /// per-row activated-set sizes k̃_i into `fired`.
    pub fn inference_batch_into(&mut self, q: &[f32], out: &mut [f32], fired: &mut [usize]) {
        self.sync();
        self.session.run(q, &self.values, out, fired);
        self.stats = self.session.stats;
    }

    /// INFERENCE over B query rows, allocating the [B, d] output.
    pub fn inference_batch(&mut self, q: &[f32]) -> Vec<f32> {
        let d = self.session.dim();
        let b = q.len() / d;
        let mut out = vec![0f32; b * d];
        let mut fired = vec![0usize; b];
        self.inference_batch_into(q, &mut out, &mut fired);
        out
    }

    /// INFERENCE over a full Q (m × d): returns the m × d output.
    /// Delegates to [`GenerationDecoding::inference_batch`] — the serial
    /// path is just the B = 1 case of the batched one.
    pub fn inference(&mut self, q: &[f32]) -> Vec<f32> {
        self.inference_batch(q)
    }
}

/// Partition a decode batch into shared-prefix groups: members with an
/// identical (non-empty) radix chain decode as one cross-sequence query
/// block — ONE multi-query HSR traversal per chain segment per head —
/// while members with no adopted prefix stay singleton jobs (the
/// historical per-sequence path). Groups preserve first-occurrence
/// order and every input index appears in exactly one group, which is
/// what keeps the batched sweep's shard boundaries (and therefore its
/// stats merge) deterministic.
pub(crate) fn group_by_chain(chains: &[&[u32]]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    // (group index, chain) for non-empty chains seen so far; linear scan
    // is fine — batches are scheduler-bounded.
    let mut seen: Vec<(usize, usize)> = Vec::new(); // (group, exemplar member)
    for (i, &c) in chains.iter().enumerate() {
        if c.is_empty() {
            groups.push(vec![i]);
            continue;
        }
        match seen.iter().find(|&&(_, m)| chains[m] == c) {
            Some(&(g, _)) => groups[g].push(i),
            None => {
                seen.push((groups.len(), i));
                groups.push(vec![i]);
            }
        }
    }
    groups
}

/// Log-probability of `token` under log-softmax of `logits` — the
/// (temperature-independent) score a beam/best-of hypothesis accrues
/// per step. Accumulated in f64 so long hypotheses don't lose the
/// small differences beam pruning decides on.
pub(crate) fn token_logprob(logits: &[f32], token: u32) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&l| (l as f64 - max).exp()).sum();
    logits[token as usize] as f64 - max - lse.ln()
}

/// The `w` highest-log-probability tokens of one logits row, best
/// first; ties break toward the smaller token id so beam expansion is
/// fully deterministic. Returns fewer than `w` entries only when the
/// vocabulary is smaller than `w`.
pub(crate) fn top_w(logits: &[f32], w: usize) -> Vec<(u32, f64)> {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&l| (l as f64 - max).exp()).sum();
    let norm = max + lse.ln();
    let mut scored: Vec<(u32, f64)> = logits
        .iter()
        .enumerate()
        .map(|(t, &l)| (t as u32, l as f64 - norm))
        .collect();
    scored.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    scored.truncate(w);
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::softmax::softmax_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    /// Algorithm 1 with ReLU attention is *exact* vs the naive dense
    /// computation (the paper's "no error for ReLU" claim).
    #[test]
    fn relu_matches_dense_exactly() {
        let mut rng = Rng::new(101);
        for backend in [HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected] {
            let inst = AttentionInstance::gaussian(&mut rng, 4, 600, 8);
            let bias = inst.params.practical_bias(inst.n) as f32;
            for alpha in [1u32, 2] {
                let mut gd = GenerationDecoding::init(
                    &inst.k,
                    &inst.v,
                    inst.d,
                    bias,
                    AttentionKind::Relu { alpha, bias },
                    backend,
                );
                let got = gd.inference(&inst.q);
                let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, alpha, bias);
                assert!(
                    linf(&got, &want) < 1e-4,
                    "backend={backend:?} alpha={alpha}: {}",
                    linf(&got, &want)
                );
            }
        }
    }

    /// Softmax with top-r over the report is close to dense and the error
    /// shrinks as r grows (Theorem 4.3's shape).
    #[test]
    fn softmax_topr_error_shrinks() {
        let mut rng = Rng::new(102);
        let inst = AttentionInstance::gaussian(&mut rng, 2, 800, 8);
        let dense = softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        let mut last_err = f32::INFINITY;
        for r in [8usize, 64, 512, 800] {
            let mut gd = GenerationDecoding::init(
                &inst.k,
                &inst.v,
                inst.d,
                f32::NEG_INFINITY, // report everything; top-r selects
                AttentionKind::Softmax,
                HsrBackend::BallTree,
            );
            gd.top_r = Some(r);
            let got = gd.inference(&inst.q);
            let err = linf(&got, &dense);
            assert!(err <= last_err * 1.25 + 1e-6, "r={r} err={err} last={last_err}");
            last_err = last_err.min(err);
        }
        assert!(last_err < 1e-5, "full r must be exact: {last_err}");
    }

    /// Appending keys (auto-regressive growth) stays consistent with a
    /// from-scratch build.
    #[test]
    fn append_matches_rebuild() {
        let mut rng = Rng::new(103);
        let d = 6;
        let inst = AttentionInstance::gaussian(&mut rng, 1, 200, d);
        let bias = 0.2f32;
        let kind = AttentionKind::Relu { alpha: 1, bias };
        let mut grown = GenerationDecoding::init(
            &inst.k[..100 * d],
            &inst.v[..100 * d],
            d,
            bias,
            kind,
            HsrBackend::BallTree,
        );
        for j in 100..200 {
            grown.append(&inst.k[j * d..(j + 1) * d], &inst.v[j * d..(j + 1) * d]);
        }
        let mut fresh =
            GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
        let mut out_a = vec![0f32; d];
        let mut out_b = vec![0f32; d];
        let q: Vec<f32> = inst.q[..d].to_vec();
        grown.inference_row(&q, &mut out_a);
        fresh.inference_row(&q, &mut out_b);
        assert!(linf(&out_a, &out_b) < 1e-5);
    }

    /// Batched decode must be **bit-identical** to the serial row loop:
    /// same output floats, same fired counts — across every HSR backend,
    /// both attention kinds, with and without top-r, for every thread
    /// count. The serial reference is `inference_row` (the B = 1 case of
    /// the same canonical evaluation). Per-point work counters also
    /// match; `nodes_visited` may only *drop* under the batch's shared
    /// traversal (the multi-query counting rule), and the whole stats
    /// aggregate is identical across thread counts.
    #[test]
    fn batched_matches_serial_bitwise() {
        let mut rng = Rng::new(105);
        let cases: Vec<(HsrBackend, usize)> = vec![
            (HsrBackend::Brute, 8),
            (HsrBackend::BallTree, 8),
            (HsrBackend::Projected, 8),
            (HsrBackend::Layers2d, 2),
        ];
        for (backend, d) in cases {
            let inst = AttentionInstance::gaussian(&mut rng, 13, 400, d);
            let bias = inst.params.practical_bias(inst.n) as f32;
            type Setup = (&'static str, AttentionKind, Option<usize>, f32, f64);
            let setups: Vec<Setup> = vec![
                ("relu", AttentionKind::Relu { alpha: 2, bias }, None, bias, 1.0),
                ("softmax", AttentionKind::Softmax, None, bias, 1.0),
                ("softmax-topr", AttentionKind::Softmax, Some(24), 0.0, 1.0),
                // σ_k ≫ 1 inflates the adaptive threshold so the report
                // under-fills and every row takes the full-scan fallback.
                ("softmax-topr-fallback", AttentionKind::Softmax, Some(24), 0.0, 50.0),
            ];
            for (name, kind, top_r, b, sigma_k) in setups {
                let build = || {
                    let mut gd = GenerationDecoding::init(
                        &inst.k, &inst.v, inst.d, b, kind, backend,
                    );
                    gd.top_r = top_r;
                    gd.sigma_k = sigma_k;
                    gd
                };
                // Serial reference: one row at a time.
                let mut serial = build();
                let mut want = vec![0f32; inst.m * inst.d];
                let mut want_fired = vec![0usize; inst.m];
                for i in 0..inst.m {
                    let (s, e) = (i * inst.d, (i + 1) * inst.d);
                    want_fired[i] = serial.inference_row(&inst.q[s..e], &mut want[s..e]);
                }
                let mut stats_at: Vec<QueryStats> = Vec::new();
                for threads in [1usize, 2, 3] {
                    let mut batched = build();
                    batched.threads = threads;
                    let mut got = vec![0f32; inst.m * inst.d];
                    let mut fired = vec![0usize; inst.m];
                    batched.inference_batch_into(&inst.q, &mut got, &mut fired);
                    assert_eq!(
                        want, got,
                        "{name} backend={backend:?} threads={threads}"
                    );
                    assert_eq!(want_fired, fired, "{name} backend={backend:?}");
                    // Per-(query, point) counters equal the serial loop;
                    // shared traversals may only reduce node visits.
                    assert_eq!(serial.stats.points_scanned, batched.stats.points_scanned);
                    assert_eq!(serial.stats.bulk_reported, batched.stats.bulk_reported);
                    assert_eq!(serial.stats.reported, batched.stats.reported);
                    assert!(
                        batched.stats.nodes_visited <= serial.stats.nodes_visited,
                        "{name} backend={backend:?}"
                    );
                    stats_at.push(batched.stats);
                }
                // The block partition is thread-count independent, so the
                // batched aggregate is too.
                assert!(
                    stats_at.windows(2).all(|w| w[0] == w[1]),
                    "{name} backend={backend:?}: stats vary across thread counts"
                );
            }
        }
    }

    /// `inference` is the batched path; it must agree with the serial row
    /// loop bit-for-bit (delegation sanity).
    #[test]
    fn inference_delegates_to_batch() {
        let mut rng = Rng::new(106);
        let inst = AttentionInstance::gaussian(&mut rng, 6, 300, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let kind = AttentionKind::Relu { alpha: 1, bias };
        let mut a =
            GenerationDecoding::init(&inst.k, &inst.v, inst.d, bias, kind, HsrBackend::BallTree);
        let mut b =
            GenerationDecoding::init(&inst.k, &inst.v, inst.d, bias, kind, HsrBackend::BallTree);
        let batched = a.inference(&inst.q);
        let mut serial = vec![0f32; inst.m * inst.d];
        for i in 0..inst.m {
            let (s, e) = (i * inst.d, (i + 1) * inst.d);
            b.inference_row(&inst.q[s..e], &mut serial[s..e]);
        }
        assert_eq!(batched, serial);
    }

    /// The activated-set size tracks Lemma 6.1: k̃ ≤ 2 n^{4/5}.
    #[test]
    fn activated_count_respects_lemma() {
        let mut rng = Rng::new(104);
        let inst = AttentionInstance::gaussian(&mut rng, 8, 4096, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let mut gd = GenerationDecoding::init(
            &inst.k,
            &inst.v,
            inst.d,
            bias,
            AttentionKind::Relu { alpha: 1, bias },
            HsrBackend::BallTree,
        );
        let bound = inst.params.row_bound(inst.n) as usize;
        let mut out = vec![0f32; inst.d];
        let mut any = 0usize;
        for i in 0..inst.m {
            let q: Vec<f32> = inst.query_row(i).to_vec();
            let fired = gd.inference_row(&q, &mut out);
            assert!(fired <= bound, "row {i}: fired {fired} > bound {bound}");
            any += fired;
        }
        assert!(any > 0, "nothing fired at the practical threshold");
    }

    #[test]
    fn group_by_chain_partitions_in_first_occurrence_order() {
        let a: &[u32] = &[1, 2];
        let b: &[u32] = &[1, 3];
        let none: &[u32] = &[];
        let groups = group_by_chain(&[a, none, b, a, none, b, a]);
        assert_eq!(groups, vec![vec![0, 3, 6], vec![1], vec![2, 5], vec![4]]);
        // Every index exactly once.
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
        assert!(group_by_chain(&[]).is_empty());
    }

    #[test]
    fn top_w_is_sorted_deterministic_and_normalized() {
        let logits = [0.0f32, 2.0, 2.0, -1.0];
        let top = top_w(&logits, 3);
        assert_eq!(top.len(), 3);
        // Ties (tokens 1 and 2 share a logit) break toward the smaller id.
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
        assert_eq!(top[2].0, 0);
        assert!(top[0].1 == top[1].1 && top[1].1 > top[2].1);
        // Log-probs exponentiate back to a distribution.
        let total: f64 = (0..logits.len())
            .map(|t| token_logprob(&logits, t as u32).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-12, "sum={total}");
        // Requesting more than the vocab just returns the vocab.
        assert_eq!(top_w(&logits, 10).len(), 4);
        // Best token agrees with argmax (greedy ↔ beam-1 consistency).
        assert_eq!(top_w(&logits, 1)[0].0, crate::model::transformer::argmax(&logits));
    }
}
