//! Shared-prefix KV store: a refcounted radix prefix cache over
//! block-paged KV segments with copy-on-write forks.
//!
//! The paper's HSR report-then-evaluate pipeline amortizes best when one
//! index answers many queries ([`crate::hsr::HalfSpaceReport::query_many_scored_into`]).
//! This subsystem makes that happen *across sequences*: serving
//! workloads with a common system prompt share one physical KV prefix —
//! one payload, one set of per-(layer, head) HSR indices — instead of
//! re-prefilling and re-indexing identical tokens per sequence.
//!
//! * [`pool`] — [`pool::PagePool`]: owns the float payload in
//!   block-sized pages, per-(layer, head) contiguous segment views, and
//!   the block allocator sequences draw their private tails from. One
//!   owner for capacity *and* payload.
//! * [`radix`] — [`radix::RadixIndex`]: token-prefix → segment chain,
//!   refcounts, LRU eviction under pool pressure.
//! * [`tier`] — the cold tier: a lossless-compressed spill store
//!   segments demote into under LRU pressure (instead of being
//!   destroyed) and refault from on the next prefix match, plus the
//!   content-hash machinery that dedups identical publishes onto one
//!   physical segment.
//! * [`shared`] — [`shared::SharedKvMut`]: the chain + private-tail view
//!   the transformer's attend path consumes; ONE
//!   [`crate::hsr::dynamic::DynamicHsr`] per shared segment serves every
//!   sequence holding it, and decode rows of sequences sharing a chain
//!   are answered as one multi-query traversal per segment.
//!
//! # Invariants (the short version — see each module's docs)
//!
//! 1. Segments are immutable after publish; sequence writes go to the
//!    private tail (COW fork semantics).
//! 2. A sequence holds one reference on every chain node it adopted;
//!    only unreferenced leaves are LRU-evicted, so adopted chains are
//!    never freed underneath a running sequence.
//! 3. The chain's HSR indices are owned by the segments (i.e. by the
//!    pool), never by sequences; the per-sequence calibration threshold
//!    stays private tail state (segments carry an advisory snapshot).
//!    Exactness never depends on calibration, so shared and unshared
//!    decode select identical top-r *sets* for every head size (ties
//!    break by global index — order-independent). Output floats are
//!    additionally bit-identical wherever the SIMD dot reduction is
//!    layout-independent — `d_head <= 8` or scalar dispatch, the regime
//!    `tests/prefix_cache.rs` asserts bitwise; for larger heads any
//!    difference is confined to last-ulp reduction order inside the
//!    dot kernels.

pub mod pool;
pub mod radix;
pub mod shared;
pub mod tier;

pub use pool::{Demoted, PagePool, Refault, Segment, SegmentId};
pub use radix::{NodeId, RadixIndex};
pub use shared::{PrefixView, SharedKvMut};
pub use tier::{SpillConfig, SpillPolicy, TierConfig, TierStats};

use crate::hsr::HsrBackend;
use crate::model::kv::KvState;

/// Prefix-cache policy knob (the CLI's `--prefix-cache <on|off|tokens>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixCacheMode {
    /// No prefix sharing: every sequence owns a private KV cache
    /// (the pre-kvstore behavior, and the bench baseline).
    Off,
    /// Prefix sharing on; a cached chain is only adopted when it covers
    /// at least this many tokens (`on` ≡ `Min(1)`).
    Min(usize),
}

impl Default for PrefixCacheMode {
    fn default() -> Self {
        PrefixCacheMode::Min(1)
    }
}

impl PrefixCacheMode {
    /// Parse a CLI value: `on`/`off` or a minimum-token count. The error
    /// lists the valid forms so CLI callers can surface it verbatim
    /// (`util::cli::Args::parse_or_exit` does exactly that).
    pub fn parse(s: &str) -> Result<PrefixCacheMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "on" | "true" | "yes" => Ok(PrefixCacheMode::Min(1)),
            "off" | "false" | "no" | "none" => Ok(PrefixCacheMode::Off),
            other => match other.replace('_', "").parse::<usize>() {
                Ok(n) => Ok(PrefixCacheMode::Min(n.max(1))),
                Err(_) => Err(format!(
                    "unknown prefix-cache mode '{other}'; valid values: \
                     on|off|<min-tokens> (e.g. --prefix-cache 64)"
                )),
            },
        }
    }

    /// Minimum matched tokens required to adopt a chain; `usize::MAX`
    /// when the cache is off (nothing ever adopts).
    pub fn min_tokens(&self) -> usize {
        match *self {
            PrefixCacheMode::Off => usize::MAX,
            PrefixCacheMode::Min(n) => n,
        }
    }

    pub fn enabled(&self) -> bool {
        !matches!(self, PrefixCacheMode::Off)
    }
}

/// The engine-facing façade bundling the pool, the radix index and the
/// policy knob. All serving-side prefix-cache operations go through
/// this type so the pool/radix pair can never drift out of sync.
pub struct PrefixStore {
    pub pool: PagePool,
    pub radix: RadixIndex,
    pub mode: PrefixCacheMode,
    /// Evictions (demotions/removals) performed to make room for
    /// refaults inside [`PrefixStore::lookup_budgeted`]; the engine
    /// drains this into its `prefix_segments_evicted` metric.
    refault_evictions: usize,
}

impl PrefixStore {
    pub fn new(
        capacity_tokens: usize,
        block_tokens: usize,
        hsr_backend: Option<HsrBackend>,
        mode: PrefixCacheMode,
    ) -> PrefixStore {
        PrefixStore::with_tier(
            capacity_tokens,
            block_tokens,
            hsr_backend,
            mode,
            &TierConfig::default(),
        )
    }

    /// Store with a cold spill tier per `tier` (see [`tier`]).
    pub fn with_tier(
        capacity_tokens: usize,
        block_tokens: usize,
        hsr_backend: Option<HsrBackend>,
        mode: PrefixCacheMode,
        tier: &TierConfig,
    ) -> PrefixStore {
        PrefixStore {
            pool: PagePool::with_tier(capacity_tokens, block_tokens, hsr_backend, tier),
            radix: RadixIndex::new(),
            mode,
            refault_evictions: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.mode.enabled()
    }

    /// Evictions performed on behalf of refaults since the last drain.
    pub fn take_refault_evictions(&mut self) -> usize {
        std::mem::take(&mut self.refault_evictions)
    }

    /// Longest adoptable chain for `prompt` with an unbounded refault
    /// budget — see [`PrefixStore::lookup_budgeted`].
    pub fn lookup(&mut self, prompt: &[u32]) -> (Vec<NodeId>, usize) {
        self.lookup_budgeted(prompt, usize::MAX)
    }

    /// Longest adoptable chain for `prompt`: matching is capped at
    /// `prompt.len() - 1` (the last prompt token is always recomputed so
    /// its logits can seed generation) and gated on the mode's minimum.
    ///
    /// A matched chain may contain **cold** nodes (demoted under LRU
    /// pressure). Those are transparently refaulted front-to-back here —
    /// decompress, re-reserve blocks, reattach HSR — before the chain is
    /// handed out, evicting other unreferenced prefixes if blocks are
    /// short. `refault_token_budget` caps how many tokens one lookup
    /// will promote (bounding admission-path latency); the chain is
    /// truncated at the first node that exceeds the budget or fails to
    /// refault. Returns `(chain, matched_tokens)` — every returned node
    /// is hot; empty when nothing qualifies.
    pub fn lookup_budgeted(
        &mut self,
        prompt: &[u32],
        refault_token_budget: usize,
    ) -> (Vec<NodeId>, usize) {
        if !self.enabled() || prompt.len() < 2 {
            return (Vec::new(), 0);
        }
        let (mut chain, mut matched) =
            self.radix.match_chain(&self.pool, prompt, prompt.len() - 1);
        if chain
            .iter()
            .any(|&n| self.pool.is_cold(self.radix.segment_of(n)))
        {
            // Protect the chain while room-making eviction runs below —
            // referenced nodes are never victims.
            self.radix.ref_chain(&chain);
            let mut keep = chain.len();
            let mut budget = refault_token_budget;
            for (i, &nid) in chain.iter().enumerate() {
                let seg = self.radix.segment_of(nid);
                if !self.pool.is_cold(seg) {
                    continue;
                }
                let len = self.pool.len_of(seg);
                if len > budget {
                    keep = i;
                    break;
                }
                let need = self.pool.blocks_for(len);
                if self.pool.free_blocks() < need {
                    self.refault_evictions += self.radix.evict_lru(&mut self.pool, need);
                }
                match self.pool.refault_segment(seg) {
                    Refault::Refaulted => budget -= len,
                    Refault::NoRoom | Refault::Failed => {
                        keep = i;
                        break;
                    }
                }
            }
            self.radix.deref_chain(&chain);
            chain.truncate(keep);
            matched = chain
                .iter()
                .map(|&n| self.pool.len_of(self.radix.segment_of(n)))
                .sum();
        }
        if matched < self.mode.min_tokens() {
            return (Vec::new(), 0);
        }
        (chain, matched)
    }

    /// Borrowed chain view for the attend path. The ids must be a chain
    /// this store handed out (and still referenced — eviction never
    /// touches referenced nodes, so the view cannot dangle).
    pub fn chain_view(&self, chain: &[NodeId]) -> PrefixView<'_> {
        let mut segments = Vec::with_capacity(chain.len());
        let mut len = 0usize;
        for &nid in chain {
            let seg = self.pool.segment(self.radix.segment_of(nid));
            debug_assert_eq!(seg.start, len, "chain must be contiguous from 0");
            segments.push((&seg.kv, seg.start));
            len = seg.end();
        }
        PrefixView { segments, len }
    }

    /// Seed a freshly created tail's per-(layer, head) calibration
    /// thresholds from the last chain segment's snapshot. Purely
    /// advisory (exactness never depends on calibration): it just spares
    /// the first decode steps a round of full-scan fallbacks.
    pub fn seed_calib(&self, chain: &[NodeId], tail: &mut KvState) {
        let Some(&last) = chain.last() else { return };
        let seg = self.pool.segment(self.radix.segment_of(last));
        for (dst, src) in tail.heads.iter_mut().zip(seg.kv.heads.iter()) {
            dst.calib_threshold = src.calib_threshold;
        }
    }

    /// Try to bring the pool to `want_free` free blocks by LRU-evicting
    /// unreferenced cached prefixes. Returns the number evicted.
    pub fn make_room(&mut self, want_free: usize) -> usize {
        self.radix.evict_lru(&mut self.pool, want_free)
    }

    /// Publish `tokens[start..end)` (copied from `source` rows
    /// `[src_offset, src_offset + end - start)`) as a new chain node
    /// under `parent`. Best-effort and **non-evicting**: returns `None`
    /// without side effects if the pool cannot hold the segment while
    /// keeping `headroom_blocks` free — the caller decides whether to
    /// [`PrefixStore::make_room`] first (and accounts the evictions),
    /// so eviction policy and metrics live in exactly one place.
    pub fn publish_segment(
        &mut self,
        parent: Option<NodeId>,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
        headroom_blocks: usize,
    ) -> Option<NodeId> {
        // Content-dedup probe first: adopting an identical resident
        // segment allocates zero blocks, so the headroom gate does not
        // apply — a dedup hit can never increase pressure.
        if let Some(seg) = self.pool.adopt_identical(tokens, start, source, src_offset) {
            return Some(self.radix.insert_child(parent, seg));
        }
        let need = self.pool.blocks_for(tokens.len()) + headroom_blocks;
        if self.pool.free_blocks() < need {
            return None;
        }
        let seg = self
            .pool
            .create_segment_fresh(tokens, start, source, src_offset)?;
        Some(self.radix.insert_child(parent, seg))
    }

    /// Publish-on-fork path: like [`PrefixStore::publish_segment`] but
    /// willing to LRU-evict unreferenced cached prefixes to make room
    /// (a fork *must* freeze the parent's tail to share it, so it gets
    /// first claim on cold cache, never on live sequences). The caller
    /// must hold references on every chain node it needs alive —
    /// eviction never touches referenced nodes. Returns the node (None
    /// if the pool is too small even after eviction) and the number of
    /// segments evicted, for the caller's metrics.
    pub fn publish_evicting(
        &mut self,
        parent: Option<NodeId>,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
    ) -> (Option<NodeId>, usize) {
        let mut evicted = 0;
        let need = self.pool.blocks_for(tokens.len());
        if self.pool.free_blocks() < need {
            evicted = self.radix.evict_lru(&mut self.pool, need);
        }
        (
            self.publish_segment(parent, tokens, start, source, src_offset, 0),
            evicted,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse() {
        assert_eq!(PrefixCacheMode::parse("on"), Ok(PrefixCacheMode::Min(1)));
        assert_eq!(PrefixCacheMode::parse("OFF"), Ok(PrefixCacheMode::Off));
        assert_eq!(PrefixCacheMode::parse("64"), Ok(PrefixCacheMode::Min(64)));
        assert_eq!(PrefixCacheMode::parse("1_024"), Ok(PrefixCacheMode::Min(1024)));
        assert_eq!(PrefixCacheMode::parse("0"), Ok(PrefixCacheMode::Min(1)));
        let err = PrefixCacheMode::parse("maybe").unwrap_err();
        assert!(err.contains("on|off|<min-tokens>"), "{err}");
        assert!(err.contains("maybe"), "{err}");
        assert!(!PrefixCacheMode::Off.enabled());
        assert_eq!(PrefixCacheMode::Off.min_tokens(), usize::MAX);
        assert_eq!(PrefixCacheMode::default(), PrefixCacheMode::Min(1));
    }

    #[test]
    fn store_lookup_respects_min_tokens() {
        use crate::hsr::HsrBackend;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let mut kv = KvState::new(1, 1, 4, Some(HsrBackend::BallTree));
        for _ in 0..32 {
            let k = rng.gaussian_vec_f32(4, 1.0);
            kv.head_mut(0, 0).append(&k.clone(), &k);
        }
        let prompt: Vec<u32> = (0..32).collect();
        let mut store = PrefixStore::new(
            1024,
            16,
            Some(HsrBackend::BallTree),
            PrefixCacheMode::Min(20),
        );
        let node = store
            .publish_segment(None, &prompt[..16], 0, &kv, 0, 0)
            .expect("fits");
        // 16 matched < 20 minimum → no adoption.
        let (chain, matched) = store.lookup(&prompt);
        assert!(chain.is_empty());
        assert_eq!(matched, 0);
        // Extend the chain past the minimum and look up again.
        store
            .publish_segment(Some(node), &prompt[16..24], 16, &kv, 16, 0)
            .expect("fits");
        let (chain, matched) = store.lookup(&prompt);
        assert_eq!(chain.len(), 2);
        assert_eq!(matched, 24);
        let view = store.chain_view(&chain);
        assert_eq!(view.len, 24);
        assert_eq!(view.segments.len(), 2);
        assert_eq!(view.segments[1].1, 16);
    }
}
