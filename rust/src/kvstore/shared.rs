//! Shared-prefix KV views — what the transformer's attend path sees.
//!
//! A sequence's effective KV cache is a **chain of immutable shared
//! segments** (held in the [`crate::kvstore::pool::PagePool`], one HSR
//! index per (layer, head) per segment, reused by every sequence holding
//! the segment) followed by a **private copy-on-write tail** (the
//! sequence's own [`KvState`], exactly the pre-kvstore per-sequence
//! state). "Copy-on-write fork" here means: forking N sequences off a
//! cached prompt copies *nothing* — each fork takes references on the
//! chain and appends its divergent tokens to its own tail; the shared
//! prefix is never mutated after it is published.
//!
//! Global key index `j` of a sequence resolves as: `j < prefix.len` →
//! the chain segment with `start <= j < end` (row `j - start`);
//! otherwise the private tail (row `j - prefix.len`). The attention
//! planner queries each segment's index plus the tail and remaps local
//! report ids by these offsets, so the reported (index, score) **set**
//! is exactly what a single private index over the concatenated rows
//! would report — which is what makes shared-prefix decode bit-identical
//! to unshared decode (selection and evaluation are canonicalized to
//! ascending global index downstream).

use crate::model::kv::KvState;

/// Borrowed view of a sequence's adopted segment chain.
pub struct PrefixView<'a> {
    /// `(segment payload, global start offset)` in chain order; starts
    /// are strictly increasing and contiguous from 0.
    pub segments: Vec<(&'a KvState, usize)>,
    /// Total prefix tokens = the last segment's `end()` (0 if empty).
    pub len: usize,
}

impl PrefixView<'_> {
    /// A view with no shared prefix (the unshared / pre-kvstore case).
    pub fn empty() -> PrefixView<'static> {
        PrefixView { segments: Vec::new(), len: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }
}

/// A sequence's full KV state for one model step: shared prefix chain
/// (read-only) plus private tail (mutable — this step's keys/values are
/// appended here).
pub struct SharedKvMut<'p, 't> {
    pub prefix: PrefixView<'p>,
    pub tail: &'t mut KvState,
}

impl<'t> SharedKvMut<'static, 't> {
    /// Wrap a plain per-sequence [`KvState`] with no shared prefix; the
    /// model paths treat this exactly like the pre-kvstore layout.
    pub fn unshared(tail: &'t mut KvState) -> SharedKvMut<'static, 't> {
        SharedKvMut { prefix: PrefixView::empty(), tail }
    }
}

impl SharedKvMut<'_, '_> {
    /// Total cached tokens: shared prefix + private tail.
    pub fn len(&self) -> usize {
        self.prefix.len + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
