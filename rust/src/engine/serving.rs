//! The continuous-batching serving engine: Algorithm 1 integrated with a
//! paged KV cache, chunked prefill, preemption, a shared-prefix radix
//! cache and metrics — the L3 system the paper's decoding/prefilling
//! scenarios live inside.
//!
//! One `Engine` drives one model replica single-threaded (the router in
//! `router.rs` shards requests across engines/threads). Each `step()`:
//!
//! 1. **Admit** waiting requests while the batch and the block pool have
//!    room. Admission first matches the prompt against the radix prefix
//!    cache ([`crate::kvstore`]): matched tokens are *adopted* — never
//!    prefilled — and the sequence only reserves pool blocks for its
//!    private tail, so N clones of a cached prompt cost O(tail) each
//!    instead of O(prompt).
//! 2. **Prefill** admitted sequences in chunks (budgeted per step so long
//!    prompts cannot starve decodes — "chunked prefill"). Every chunk is
//!    bracketed by the adopt/publish hooks in `prefill.rs`: freshly
//!    computed prompt ranges are published into the radix cache and
//!    sibling sequences leapfrog onto them at their next chunk boundary,
//!    so each shared token is prefilled exactly once fleet-wide.
//! 3. **Decode** one token for every running sequence whose prompt is
//!    done, via the HSR-sparse attention policy. Sequences sharing a
//!    prefix chain decode as ONE query block — a single multi-query HSR
//!    traversal per chain segment per head.
//! 4. **Preempt** (release blocks, drop KV, requeue) when the pool is
//!    exhausted, per the configured victim policy — after first
//!    reclaiming unreferenced cached prefixes (LRU).

use super::metrics::Metrics;
use super::request::{
    Choice, FinishReason, GenerationParams, Request, RequestId, Response, Sequence,
};
use super::scheduler::SchedulerConfig;
use crate::attention::session::AttentionConfig;
use crate::hsr::HsrBackend;
use crate::kvstore::{
    PrefixCacheMode, PrefixStore, SharedKvMut, SpillConfig, SpillPolicy, TierConfig,
};
use crate::model::kv::KvState;
use crate::model::transformer::RSpec;
use crate::obs::clock;
use crate::obs::trace::{FlightRecorder, SpanKind, TraceConfig};
use crate::model::transformer::{
    argmax, sample, AttentionPolicy, BatchWorkspace, StepStats, Workspace,
};
use crate::model::Model;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker thread mid-step (exercises `catch_unwind`
    /// supervision in the router).
    Panic,
    /// One-shot sleep of `ms` milliseconds at the trigger step.
    Delay { ms: u32 },
    /// Sleep `ms` milliseconds at the trigger step **and every step
    /// after** — a wedged-but-alive worker.
    Stall { ms: u32 },
}

/// One deterministic fault: fire `kind` on worker `worker` when its
/// engine reaches step `step` (1-based; `Engine::step` counts calls).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub worker: u32,
    pub step: u64,
    pub kind: FaultKind,
}

/// Max faults a plan can hold (fixed array keeps `FaultPlan: Copy`).
pub const MAX_FAULTS: usize = 4;

/// Deterministic fault-injection plan, carried in [`EngineConfig`] so
/// supervision is testable: the router filters the plan per worker, and
/// clears it on the replacement engine after a caught panic so each
/// fault fires exactly once.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: [Option<Fault>; MAX_FAULTS],
}

impl FaultPlan {
    /// The empty plan (also `Default`).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a fault (builder style). Panics past [`MAX_FAULTS`] entries.
    pub fn with(mut self, f: Fault) -> FaultPlan {
        for slot in self.entries.iter_mut() {
            if slot.is_none() {
                *slot = Some(f);
                return self;
            }
        }
        panic!("FaultPlan holds at most {MAX_FAULTS} faults");
    }

    /// The sub-plan targeting one worker.
    pub fn for_worker(&self, worker: usize) -> FaultPlan {
        let mut out = FaultPlan::default();
        for f in self.entries.into_iter().flatten() {
            if f.worker as usize == worker {
                out = out.with(f);
            }
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }

    /// The fault firing at `step`, if any (plan already filtered to this
    /// worker). `Panic`/`Delay` fire at their exact step; `Stall` fires
    /// at its step and every later one.
    pub fn fire_at(&self, step: u64) -> Option<FaultKind> {
        self.entries.into_iter().flatten().find_map(|f| match f.kind {
            FaultKind::Panic | FaultKind::Delay { .. } if step == f.step => Some(f.kind),
            FaultKind::Stall { .. } if step >= f.step => Some(f.kind),
            _ => None,
        })
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: AttentionPolicy,
    /// HSR backend for per-head indices; None → brute scans inside the
    /// sparse policy (ablation) — ignored under `AttentionPolicy::Dense`.
    pub hsr_backend: Option<HsrBackend>,
    /// Total KV-cache capacity in tokens (across all sequences *and* the
    /// shared-prefix cache — one physical pool).
    pub cache_capacity_tokens: usize,
    /// Block granularity of the pool.
    pub block_tokens: usize,
    /// Shared-prefix KV cache policy (`on`, `off`, or a minimum matched
    /// token count). Adoption always selects the exact same top-r index
    /// sets as unshared decode (set-exactness is layout-independent);
    /// outputs are additionally bit-identical wherever the SIMD dot
    /// reduction is layout-independent (`d_head <= 8` or the scalar
    /// dispatch tier — see README "Prefix cache"). For larger heads the
    /// difference is confined to last-ulp dot-reduction order.
    pub prefix_cache: PrefixCacheMode,
    /// Cold-tier spill store for the prefix cache: where LRU-evicted,
    /// unreferenced segments demote to (lossless-compressed) instead of
    /// being destroyed, to be refaulted on a later prefix match. `Off`
    /// keeps the pre-tier destroy-on-evict behavior.
    pub spill: SpillConfig,
    /// What happens to a demoted segment's HSR indices: serialized into
    /// the cold record, or rebuilt from the keys at refault (see
    /// [`SpillPolicy`]).
    pub spill_policy: SpillPolicy,
    pub scheduler: SchedulerConfig,
    /// Sampling seed (deterministic engines → reproducible serving runs).
    pub seed: u64,
    /// Base of the request-id space (routers give each worker a disjoint
    /// range so ids are globally unique).
    pub id_offset: u64,
    /// Worker threads for the batched per-(layer, head) decode sweep:
    /// 0 → one per available core, 1 → serial. Outputs are identical
    /// either way (deterministic shard merge).
    pub decode_threads: usize,
    /// Deterministic fault injection (empty in production). The engine
    /// consults the plan at the top of every `step`; the router filters
    /// it per worker.
    pub faults: FaultPlan,
    /// Flight-recorder tracing (ring size, trace dir, on/off). Each
    /// engine owns one [`FlightRecorder`] built from this.
    pub trace: TraceConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AttentionPolicy::Dense,
            hsr_backend: Some(HsrBackend::BallTree),
            cache_capacity_tokens: 1 << 20,
            block_tokens: 64,
            prefix_cache: PrefixCacheMode::default(),
            spill: SpillConfig::Off,
            spill_policy: SpillPolicy::default(),
            scheduler: SchedulerConfig::default(),
            seed: 0,
            id_offset: 0,
            decode_threads: 0,
            faults: FaultPlan::none(),
            trace: TraceConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Build a serving config from the unified [`AttentionConfig`]. The
    /// serving engine consumes exactly three of its knobs: `backend`
    /// feeds the per-head dynamic indices, `threads` drives the batched
    /// per-(layer, head) decode sweep, and `top_r` (if set) becomes a
    /// fixed-r sparse policy — otherwise the paper's r = n^{4/5}
    /// scaling. `kind`, `threshold` and `adaptive_sigma_k` do **not**
    /// apply here: the transformer path is softmax-only and calibrates
    /// its per-head thresholds at runtime from observed score quantiles
    /// (see `model/transformer.rs`), so those fields are ignored.
    pub fn from_attention(att: AttentionConfig) -> EngineConfig {
        EngineConfig {
            policy: match att.top_r {
                Some(r) => AttentionPolicy::TopR(RSpec::Fixed(r)),
                None => AttentionPolicy::TopR(RSpec::paper()),
            },
            hsr_backend: Some(att.backend),
            decode_threads: att.threads,
            ..EngineConfig::default()
        }
    }
}

/// A single-replica serving engine.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: EngineConfig,
    /// Shared-prefix KV store: block pool (capacity + payload owner in
    /// one place) plus the refcounted radix prefix index.
    store: PrefixStore,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    finished: Vec<Response>,
    ws: Workspace,
    bws: BatchWorkspace,
    /// Aggregation state for grouped requests (parallel sampling /
    /// beam search), keyed by the submitted request id; every sibling
    /// sequence carries `group == Some(gid)` pointing here.
    groups: HashMap<RequestId, Group>,
    pub metrics: Metrics,
    /// Flight recorder: bounded ring of span events on the shared
    /// engine clock (see [`crate::obs::trace`]). The router dumps it on
    /// worker panic; terminal outcomes dump per-request timelines when
    /// a trace dir is configured.
    pub recorder: FlightRecorder,
    /// Submission timestamp (shared clock, µs) per queued request,
    /// consumed at admission for the queue-wait span.
    arrivals: HashMap<RequestId, u64>,
    next_id: RequestId,
    /// `step()` calls so far (drives deterministic fault injection).
    steps: u64,
}

/// One grouped request's aggregation state: parallel sampling
/// (`n`/`best_of`) or beam search (`beam_width`). The primary sequence
/// and every sibling forked from it record their terminal [`Choice`]
/// here; when the last live sibling lands, the group emits exactly ONE
/// multi-choice [`Response`] under the submitted request id.
struct Group {
    /// Beam search (joint ranking + pruning) vs independent sampling.
    beam: bool,
    /// Choices returned to the caller (`n`, or the beam width).
    keep: usize,
    /// Candidates decoded (`max(n, best_of)`, or the beam width) —
    /// clamped to [`SchedulerConfig::max_group_width`] at admission.
    spawn: usize,
    /// Siblings still running or waiting.
    live: usize,
    /// Next sibling index to hand out at fork.
    next_sibling: u32,
    /// Initial fan-out happened (sampling) / beam seeded its first
    /// expansion. Until then only the primary exists.
    forked: bool,
    /// Terminal choices recorded so far (unranked until emission).
    results: Vec<Choice>,
    /// The submitted prompt: sibling prompts mutate under preemption
    /// folds, but the response and panic salvage need the original.
    prompt: Vec<u32>,
    submitted: Instant,
    /// Earliest first-token instant across siblings (group TTFT).
    first_token_at: Option<Instant>,
}

impl Engine {
    pub fn new(model: Arc<Model>, cfg: EngineConfig) -> Engine {
        let ws = Workspace::new(&model);
        let mut bws = BatchWorkspace::new(&model);
        bws.threads = cfg.decode_threads;
        // Segments only carry HSR indices a sparse policy will query.
        let seg_backend = match cfg.policy {
            AttentionPolicy::Dense => None,
            AttentionPolicy::TopR(_) => cfg.hsr_backend,
        };
        Engine {
            store: PrefixStore::with_tier(
                cfg.cache_capacity_tokens,
                cfg.block_tokens,
                seg_backend,
                cfg.prefix_cache,
                &TierConfig { spill: cfg.spill.clone(), policy: cfg.spill_policy },
            ),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            ws,
            bws,
            groups: HashMap::new(),
            metrics: Metrics::default(),
            recorder: FlightRecorder::new(&cfg.trace),
            arrivals: HashMap::new(),
            next_id: cfg.id_offset + 1,
            steps: 0,
            model,
            cfg,
        }
    }

    fn new_sequence(&self, req: Request) -> Sequence {
        let c = &self.model.cfg;
        Sequence {
            id: req.id,
            priority: req.id, // submission order
            kv: KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend),
            prompt: req.prompt,
            params: req.params,
            generated: Vec::new(),
            submitted: Instant::now(),
            first_token_at: None,
            blocks: Vec::new(),
            prefilled: 0,
            folded: 0,
            prefix: Vec::new(),
            prefix_len: 0,
            attempts: req.attempts,
            stream: req.stream,
            // Sampling draws come from a per-sequence stream so forked
            // siblings diverge deterministically (the child's rng forks
            // from the parent's) without perturbing anyone else's draws.
            rng: crate::util::rng::Rng::new(
                self.cfg.seed ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
            group: None,
            sibling: 0,
            score: 0.0,
            seed_logits: None,
        }
    }

    /// Submit a request; returns its id. Engine-assigned ids start at
    /// `cfg.id_offset + 1`; this path never rejects (the bounded-queue
    /// entry point is [`Engine::submit_request`]).
    pub fn submit(&mut self, prompt: Vec<u32>, params: GenerationParams) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.enqueue_request(Request { id, prompt, params, attempts: 0, stream: None });
        id
    }

    /// Submit a caller-assigned request, rejecting (and returning it)
    /// when the waiting queue is at `scheduler.max_waiting` — the
    /// per-worker bound behind the router's admission control.
    pub fn submit_request(&mut self, req: Request) -> Result<RequestId, Request> {
        if self.waiting.len() >= self.cfg.scheduler.max_waiting {
            return Err(req);
        }
        let id = req.id;
        self.enqueue_request(req);
        Ok(id)
    }

    fn enqueue_request(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        self.metrics.prompt_tokens += req.prompt.len() as u64;
        if self.recorder.enabled() {
            self.arrivals.insert(req.id, clock::now_us());
        }
        let mut seq = self.new_sequence(req);
        let width = seq.params.group_width() as usize;
        if width >= 2 {
            let spawn = width.min(self.cfg.scheduler.max_group_width.max(1));
            let keep = if seq.params.is_beam() {
                spawn
            } else {
                (seq.params.n.max(1) as usize).min(spawn)
            };
            self.groups.insert(
                seq.id,
                Group {
                    beam: seq.params.is_beam(),
                    keep,
                    spawn,
                    live: 1,
                    next_sibling: 1,
                    forked: false,
                    results: Vec::new(),
                    prompt: seq.prompt.clone(),
                    submitted: seq.submitted,
                    first_token_at: None,
                },
            );
            seq.group = Some(seq.id);
            self.metrics.group_requests += 1;
        }
        self.waiting.push_back(seq);
    }

    /// Whether any work remains.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Sequences currently decoding/prefilling.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// The shared-prefix store (diagnostics / tests).
    pub fn prefix_store(&self) -> &PrefixStore {
        &self.store
    }

    /// Drain completed responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// One scheduler iteration; returns the number of tokens processed.
    ///
    /// Sequences are served strictly in priority (submission) order and a
    /// sequence may only preempt strictly-younger ones, so the oldest
    /// running sequence always makes progress — no preemption livelock.
    ///
    /// Prefill chunks run inline during the priority walk (bracketed by
    /// the radix adopt/publish hooks); decode-ready sequences are
    /// *collected* and then decoded as **one batched model step** —
    /// every sequence's row flows through the per-(layer, head)
    /// attention sweep together, grouped by shared prefix chain.
    pub fn step(&mut self) -> usize {
        let t0 = Instant::now();
        self.steps += 1;
        if let Some(kind) = self.cfg.faults.fire_at(self.steps) {
            match kind {
                FaultKind::Panic => panic!(
                    "injected fault: worker panic at engine step {}",
                    self.steps
                ),
                FaultKind::Delay { ms } | FaultKind::Stall { ms } => {
                    std::thread::sleep(std::time::Duration::from_millis(ms as u64));
                }
            }
        }
        self.abort_expired();
        self.abort_severed();
        self.admit();
        // Fork grouped primaries that finished prefill last step, before
        // the walk runs stop/length checks — a group must fan out even
        // when its very first token already terminates each sibling.
        self.fan_out_groups();
        let model = Arc::clone(&self.model);
        let mut tokens = 0usize;
        let budget = self.cfg.scheduler.step_token_budget.max(1);
        let mut stats = StepStats::default();
        let mut decode_ids: Vec<RequestId> = Vec::new();

        // Serve in priority order; `running` mutates during the loop, so
        // look sequences up by id.
        let mut order: Vec<(u64, RequestId)> =
            self.running.iter().map(|s| (s.priority, s.id)).collect();
        order.sort_unstable();
        for (_, sid) in order {
            if tokens >= budget {
                break;
            }
            let Some(i) = self.running.iter().position(|s| s.id == sid) else {
                continue; // finished or preempted earlier in this step
            };
            // Adopt a longer cached prefix before sizing the reservation
            // — adoption shrinks the tail this sequence needs blocks for
            // (and releases the blocks its dropped tail held).
            {
                let seq = &mut self.running[i];
                if seq.prefilled < seq.prompt.len() {
                    super::prefill::adopt_cached_prefix(
                        &mut self.store,
                        seq,
                        &mut self.metrics,
                        &model.cfg,
                        self.cfg.hsr_backend,
                        self.cfg.scheduler.refault_token_budget,
                    );
                }
            }
            // Reserve capacity for this sequence's next chunk (private
            // tail only — the shared chain holds its own pages); preempt
            // younger sequences if the pool is exhausted.
            let needed_now = {
                let seq = &self.running[i];
                if seq.prefilled < seq.prompt.len() {
                    let chunk = self
                        .cfg
                        .scheduler
                        .prefill_chunk
                        .min(seq.prompt.len() - seq.prefilled)
                        .min(budget - tokens)
                        .max(1);
                    seq.tail_tokens() + chunk
                } else {
                    seq.tail_tokens() + 1
                }
            };
            if !self.reserve_for(i, needed_now) {
                continue; // cannot make room without evicting elders: wait
            }
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("sequence survives its own reservation");
            let seq = &mut self.running[i];
            if seq.prefilled < seq.prompt.len() {
                // --- chunked prefill ---
                let chunk = self
                    .cfg
                    .scheduler
                    .prefill_chunk
                    .min(seq.prompt.len() - seq.prefilled)
                    .min(budget - tokens)
                    .max(1);
                {
                    // The chain cannot change inside the chunk, so the
                    // view is built once per chunk, not per token.
                    let mut skv = SharedKvMut {
                        prefix: self.store.chain_view(&seq.prefix),
                        tail: &mut seq.kv,
                    };
                    for t in 0..chunk {
                        let tok = seq.prompt[seq.prefilled + t];
                        let logits = model.decode_step_shared(
                            tok,
                            &mut skv,
                            self.cfg.policy,
                            &mut self.ws,
                            &mut stats,
                        );
                        // Logits of the last prompt token seed the first
                        // generated token.
                        if seq.prefilled + t + 1 == seq.prompt.len() {
                            // Beam groups seed greedily: the seed must
                            // equal the rank-0 beam candidate (argmax and
                            // `top_w` break ties the same way, smallest
                            // token id) so the token already streamed
                            // stays the primary's hypothesis at fan-out.
                            let next = if seq.params.is_beam() {
                                argmax(&logits)
                            } else {
                                sample(&logits, seq.params.temperature, &mut seq.rng)
                            };
                            if let Some(gid) = seq.group {
                                seq.score +=
                                    super::decode::token_logprob(&logits, next);
                                // Fan-out replaces this pending token per
                                // sibling from the same distribution.
                                if self.groups.get(&gid).is_some_and(|g| !g.forked)
                                {
                                    seq.seed_logits = Some(logits.clone());
                                }
                            }
                            seq.generated.push(next);
                            seq.first_token_at = Some(Instant::now());
                            // Folded tokens re-fed after a preemption go
                            // through prefill, not this sample — only the
                            // genuinely new token is streamed, so the wire
                            // sequence stays contiguous across preemptions.
                            if let Some(sink) = &seq.stream {
                                if sink.push_token(next, seq.sibling) {
                                    self.metrics.tokens_streamed += 1;
                                    self.recorder.record(
                                        sid,
                                        SpanKind::StreamSend,
                                        seq.sibling as u64,
                                        next as u64,
                                    );
                                }
                            }
                        }
                    }
                }
                seq.prefilled += chunk;
                tokens += chunk;
                self.recorder.record(
                    sid,
                    SpanKind::PrefillChunk,
                    chunk as u64,
                    (seq.prompt.len() - seq.prefilled.min(seq.prompt.len())) as u64,
                );
                // Publish the freshly computed range so siblings (and
                // future identical prompts) can adopt it.
                let headroom = self.cfg.scheduler.prefix_headroom_blocks;
                super::prefill::publish_prefix(
                    &mut self.store,
                    seq,
                    &mut self.metrics,
                    headroom,
                );
            } else {
                // --- decode-ready: defer into the batched model step ---
                let last = *seq
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token");
                let finished_by_stop = seq.params.stop_token == Some(last);
                if finished_by_stop || seq.done() {
                    self.finish(i, if finished_by_stop { FinishReason::StopToken } else { FinishReason::Length });
                    continue; // running[i] replaced by swap_remove
                }
                // Safe to defer: the walk visits oldest-first and
                // reservations only ever preempt strictly-younger
                // sequences, so a collected member is never evicted
                // before the batch runs.
                decode_ids.push(sid);
                tokens += 1;
            }
        }
        self.decode_batch(&decode_ids, &mut stats);
        if self.recorder.enabled() {
            // Engine-wide spans (request id 0): one decode-step event
            // and one HSR-traversal rollup per step, not per row — the
            // per-request rings stay dominated by request-scoped spans.
            if !decode_ids.is_empty() {
                self.recorder.record(
                    0,
                    SpanKind::DecodeStep,
                    decode_ids.len() as u64,
                    t0.elapsed().as_micros() as u64,
                );
            }
            if stats.dense_equivalent > 0 {
                self.recorder.record(
                    0,
                    SpanKind::HsrTraversal,
                    stats.attended as u64,
                    stats.dense_equivalent as u64,
                );
            }
        }
        self.metrics.record_step_stats(&stats);
        self.sync_tier_metrics();
        if tokens > 0 {
            self.metrics.step_latency.record(t0.elapsed());
        }
        tokens
    }

    /// Copy the pool's cumulative tier counters onto the metrics (the
    /// events happen deep inside the pool, far from any `&mut Metrics`,
    /// so the pool accumulates and the engine syncs once per step).
    /// Set-style, not additive: both sides are totals for this engine.
    fn sync_tier_metrics(&mut self) {
        let s = self.store.pool.tier_stats();
        if self.recorder.enabled() {
            // The pool counters are cumulative totals; the difference
            // against the last sync is this step's tier activity.
            let spilled =
                s.segments_spilled.saturating_sub(self.metrics.segments_spilled);
            if spilled > 0 {
                self.recorder.record(0, SpanKind::Spill, spilled, s.spill_bytes);
            }
            let refaulted = s
                .segments_refaulted
                .saturating_sub(self.metrics.segments_refaulted);
            if refaulted > 0 {
                self.recorder.record(
                    0,
                    SpanKind::Refault,
                    refaulted,
                    s.segments_refaulted,
                );
            }
        }
        self.metrics.segments_spilled = s.segments_spilled;
        self.metrics.segments_refaulted = s.segments_refaulted;
        self.metrics.spill_bytes = s.spill_bytes;
        self.metrics.refault_rebuild_ms = s.refault_rebuild_ns as f64 * 1e-6;
        self.metrics.dedup_hits = s.dedup_hits;
        self.metrics.dedup_bytes_saved = s.dedup_bytes_saved;
    }

    /// Decode one token for each collected sequence as a single batched
    /// model step, with the batch partitioned into shared-prefix groups:
    /// members of one group (identical segment chains) flow through the
    /// per-(layer, head) sweep as ONE query block per chain segment.
    /// Sampling stays in priority order so the RNG stream is
    /// deterministic regardless of grouping.
    fn decode_batch(&mut self, ids: &[RequestId], stats: &mut StepStats) {
        if ids.is_empty() {
            return;
        }
        // Batch members in running-vector order (for borrow splitting);
        // each entry is (running index, id).
        let mut members: Vec<(usize, RequestId)> = ids
            .iter()
            .map(|&sid| {
                let i = self
                    .running
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("batch members survive the walk");
                (i, sid)
            })
            .collect();
        members.sort_unstable();
        let tokens: Vec<u32> = members
            .iter()
            .map(|&(i, _)| {
                *self.running[i]
                    .generated
                    .last()
                    .expect("prefill always seeds one generated token")
            })
            .collect();
        // Shared-prefix grouping over the batch (chains are radix node
        // id vectors; equal chain ⇒ identical shared segments).
        let chains: Vec<&[u32]> = members
            .iter()
            .map(|&(i, _)| self.running[i].prefix.as_slice())
            .collect();
        let groups = super::decode::group_by_chain(&chains);
        for g in &groups {
            if g.len() > 1 {
                self.metrics.grouped_decode_rows += g.len() as u64;
            }
        }
        drop(chains);
        let model = Arc::clone(&self.model);
        let policy = self.cfg.policy;
        let store = &self.store;
        let bws = &mut self.bws;
        let mut views: Vec<SharedKvMut> = Vec::with_capacity(members.len());
        let mut next_member = 0usize;
        for (i, seq) in self.running.iter_mut().enumerate() {
            if next_member < members.len() && members[next_member].0 == i {
                views.push(SharedKvMut {
                    prefix: store.chain_view(&seq.prefix),
                    tail: &mut seq.kv,
                });
                next_member += 1;
            }
        }
        debug_assert_eq!(views.len(), members.len());
        let att0 = stats.attended;
        let den0 = stats.dense_equivalent;
        let logits =
            model.decode_step_batch_shared(&tokens, &mut views, &groups, policy, bws, stats);
        drop(views);
        // Fired-fraction telemetry: this batch's attended/dense deltas,
        // apportioned per member by its context length. The batch shares
        // one traversal, so per-row splits are an estimate — but the
        // fraction (attended / dense-equivalent) is exact in aggregate
        // and is what the n^{-1/5} envelope check consumes.
        let d_att = (stats.attended - att0) as u64;
        let d_den = (stats.dense_equivalent - den0) as u64;
        if d_den > 0 {
            for &(i, _) in &members {
                let seq = &self.running[i];
                let ctx = (seq.prefix_len + seq.kv.len()) as u64;
                if ctx == 0 {
                    continue;
                }
                let fired = ((d_att as u128 * ctx as u128) / d_den as u128) as u64;
                self.metrics.fired_fraction.record(
                    ctx as usize,
                    fired.min(ctx),
                    ctx,
                );
            }
        }
        // Beam-group members don't sample: their continuations are
        // ranked jointly per group below (forking the winners, pruning
        // the losers). Everyone else samples from their own rng stream.
        let beam_rows: Vec<(RequestId, usize)> = ids
            .iter()
            .filter_map(|&sid| {
                let i = self.running.iter().position(|s| s.id == sid)?;
                let beam = self.running[i]
                    .group
                    .is_some_and(|g| self.groups.get(&g).is_some_and(|gr| gr.beam));
                let bpos = members
                    .iter()
                    .position(|&(_, s)| s == sid)
                    .expect("member list covers ids");
                beam.then_some((sid, bpos))
            })
            .collect();
        // Sample in submission-priority order (the `ids` order).
        for &sid in ids {
            if beam_rows.iter().any(|&(s, _)| s == sid) {
                continue;
            }
            let bpos = members
                .iter()
                .position(|&(_, s)| s == sid)
                .expect("member list covers ids");
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("no sequence finishes during the batch");
            let seq = &mut self.running[i];
            let next = sample(&logits[bpos], seq.params.temperature, &mut seq.rng);
            if seq.group.is_some() {
                seq.score += super::decode::token_logprob(&logits[bpos], next);
            }
            seq.generated.push(next);
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(Instant::now());
            }
            self.metrics.generated_tokens += 1;
            if let Some(sink) = &seq.stream {
                // A refused push means the consumer overran the buffer;
                // the sink is now severed and abort_severed() sheds this
                // sequence at the top of the next step.
                if sink.push_token(next, seq.sibling) {
                    self.metrics.tokens_streamed += 1;
                    self.recorder.record(
                        sid,
                        SpanKind::StreamSend,
                        seq.sibling as u64,
                        next as u64,
                    );
                }
            }
        }
        // Beam expansion: one joint ranking per group.
        if !beam_rows.is_empty() {
            let mut beam_gids: Vec<RequestId> = beam_rows
                .iter()
                .filter_map(|&(sid, _)| {
                    self.running.iter().find(|s| s.id == sid)?.group
                })
                .collect();
            beam_gids.sort_unstable();
            beam_gids.dedup();
            for gid in beam_gids {
                self.beam_step(gid, &beam_rows, &logits);
            }
        }
    }

    /// One beam-search step for group `gid`: every live member's top-w
    /// continuations are ranked together by cumulative log-probability;
    /// the best `spawn` survive. A member's first selection continues it
    /// in place; extra selections fork it (COW — the just-fed tail row
    /// is frozen into the shared chain first); a member with no
    /// selection is pruned, releasing its blocks and chain references
    /// without emitting a response. Fully deterministic: ties break by
    /// sibling order, then token id.
    fn beam_step(
        &mut self,
        gid: RequestId,
        rows: &[(RequestId, usize)],
        logits: &[Vec<f32>],
    ) {
        let spawn = match self.groups.get(&gid) {
            Some(g) => g.spawn,
            None => return,
        };
        // Group members present in this batch, in sibling order.
        let mut mem: Vec<(u32, RequestId, usize, f64)> = rows
            .iter()
            .filter_map(|&(sid, bpos)| {
                let s = self.running.iter().find(|s| s.id == sid)?;
                (s.group == Some(gid)).then_some((s.sibling, sid, bpos, s.score))
            })
            .collect();
        mem.sort_unstable_by_key(|&(sib, ..)| sib);
        if mem.is_empty() {
            return;
        }
        // Globally ranked candidates: (cumulative score, member, token).
        let mut cands: Vec<(f64, usize, u32)> = Vec::new();
        for (mi, &(_, _, bpos, score)) in mem.iter().enumerate() {
            for (tok, lp) in super::decode::top_w(&logits[bpos], spawn) {
                cands.push((score + lp, mi, tok));
            }
        }
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        cands.truncate(spawn);
        let mut assigned: Vec<Vec<(u32, f64)>> = vec![Vec::new(); mem.len()];
        for &(score, mi, tok) in &cands {
            assigned[mi].push((tok, score));
        }
        // Survivors first: forks clone the member BEFORE its own
        // continuation is pushed, so every fork shares the exact fed
        // state. Pruning is deferred so ids stay resolvable throughout.
        for (mi, &(_, sid, _, _)) in mem.iter().enumerate() {
            if assigned[mi].is_empty() {
                continue;
            }
            for &(tok, score) in &assigned[mi][1..] {
                let new_id = self.next_id;
                self.next_id += 1;
                let idx = self
                    .running
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("beam member lives until pruned");
                let loc = self.fork_running(idx, new_id, |child| {
                    child.generated.push(tok);
                    child.score = score;
                });
                self.metrics.generated_tokens += 1;
                self.stream_child_token(new_id, loc);
            }
            let idx = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("forking never removes the parent");
            let (tok, score) = assigned[mi][0];
            let seq = &mut self.running[idx];
            seq.generated.push(tok);
            seq.score = score;
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(Instant::now());
            }
            self.metrics.generated_tokens += 1;
            if let Some(sink) = &seq.stream {
                if sink.push_token(tok, seq.sibling) {
                    self.metrics.tokens_streamed += 1;
                    self.recorder.record(
                        sid,
                        SpanKind::StreamSend,
                        seq.sibling as u64,
                        tok as u64,
                    );
                }
            }
        }
        for (mi, &(_, sid, _, _)) in mem.iter().enumerate() {
            if !assigned[mi].is_empty() {
                continue;
            }
            if let Some(idx) = self.running.iter().position(|s| s.id == sid) {
                self.prune_sibling(idx);
            }
        }
    }

    /// Fan each unforked grouped primary out into its siblings, once it
    /// is decode-ready (prefill done, seed token pending). Sampling
    /// children redraw the pending token from the stashed seed
    /// distribution with their own forked rng; beam children take the
    /// rank-1.. candidates (the primary keeps rank 0 == its greedy
    /// seed). All siblings share the full prefix chain — including the
    /// prompt rows just computed — via publish-on-fork.
    fn fan_out_groups(&mut self) {
        let gids: Vec<RequestId> = self
            .running
            .iter()
            .filter(|s| {
                s.sibling == 0
                    && s.prefilled >= s.prompt.len()
                    && !s.generated.is_empty()
                    && s.group.is_some_and(|g| {
                        self.groups.get(&g).is_some_and(|gr| !gr.forked)
                    })
            })
            .map(|s| s.group.expect("filtered on group"))
            .collect();
        for gid in gids {
            self.fan_out_one(gid);
        }
    }

    fn fan_out_one(&mut self, gid: RequestId) {
        let Some(pidx) = self.running.iter().position(|s| s.id == gid) else {
            return;
        };
        let (spawn, beam) = match self.groups.get(&gid) {
            Some(g) => (g.spawn, g.beam),
            None => return,
        };
        let seed_logits = self.running[pidx].seed_logits.take();
        let beam_cands = match (&seed_logits, beam) {
            (Some(l), true) => super::decode::top_w(l, spawn),
            _ => Vec::new(),
        };
        let n_children = if beam { beam_cands.len().min(spawn) } else { spawn };
        let seed_ref = seed_logits.as_deref();
        for rank in 1..n_children {
            let new_id = self.next_id;
            self.next_id += 1;
            let pidx = self
                .running
                .iter()
                .position(|s| s.id == gid)
                .expect("primary stays running across fan-out");
            let cand = beam_cands.get(rank).copied();
            let loc = self.fork_running(pidx, new_id, |child| {
                // Replace the pending seed token with this sibling's own
                // draw / beam candidate; the score swaps accordingly.
                // (If the stash was lost — cannot happen in the current
                // flow — the child keeps the parent's token and diverges
                // through its forked rng on later steps.)
                if let Some(l) = seed_ref {
                    let (tok, lp) = match cand {
                        Some(c) => c,
                        None => {
                            let t =
                                sample(l, child.params.temperature, &mut child.rng);
                            (t, super::decode::token_logprob(l, t))
                        }
                    };
                    let replaced =
                        child.generated.last_mut().expect("primary was seeded");
                    child.score +=
                        lp - super::decode::token_logprob(l, *replaced);
                    *replaced = tok;
                }
            });
            self.stream_child_token(new_id, loc);
        }
        if let Some(g) = self.groups.get_mut(&gid) {
            g.forked = true;
        }
    }

    /// COW-fork `running[idx]`: freeze its private tail into the shared
    /// chain (publish-on-fork) so parent and child both reference every
    /// row computed so far — prompt AND generated — then clone the
    /// sequence with a fresh empty tail and a forked rng. `mutate` runs
    /// on the child before it is scheduled (sibling token replacement /
    /// beam candidate assignment). Returns the child's running index,
    /// or `None` when pool pressure forced the recompute fallback: the
    /// child folds its tokens into the prompt and re-prefills privately
    /// from the waiting queue — deterministic model, so still
    /// bit-identical, just without sharing.
    fn fork_running(
        &mut self,
        idx: usize,
        new_id: RequestId,
        mutate: impl FnOnce(&mut Sequence),
    ) -> Option<usize> {
        let published = self.publish_tail(idx);
        let parent = &mut self.running[idx];
        let mut child = parent.fork(new_id, self.cfg.hsr_backend);
        if let Some(gid) = parent.group {
            if let Some(g) = self.groups.get_mut(&gid) {
                child.sibling = g.next_sibling;
                g.next_sibling += 1;
                g.live += 1;
            }
        }
        mutate(&mut child);
        self.metrics.sequence_forks += 1;
        if published {
            self.metrics.fork_shared_tokens += child.prefix_len as u64;
            self.store.radix.ref_chain(&child.prefix);
            self.store.seed_calib(&child.prefix, &mut child.kv);
            self.running.push(child);
            Some(self.running.len() - 1)
        } else {
            self.metrics.fork_recompute_fallbacks += 1;
            // No refs were taken for the child; drop its chain view and
            // fold everything into its prompt for private recompute.
            child.prefix.clear();
            child.prefix_len = 0;
            child.prefilled = 0;
            let mut prompt = std::mem::take(&mut child.prompt);
            prompt.extend(child.generated[child.folded..].iter().copied());
            child.folded = child.generated.len();
            child.prompt = prompt;
            self.waiting.push_front(child);
            None
        }
    }

    /// Freeze `running[idx]`'s private tail — the prompt remainder plus
    /// every generated token already fed to the model — into a
    /// refcounted chain segment: publish, take the parent's reference
    /// on the new node, release the tail blocks and restart with a
    /// fresh calibrated tail. No-op (true) when the tail is already
    /// empty; false when the pool cannot hold the segment even after
    /// LRU-evicting unreferenced prefixes (the caller falls back to
    /// recompute-fork).
    fn publish_tail(&mut self, idx: usize) -> bool {
        let seq = &self.running[idx];
        debug_assert!(
            seq.prefilled >= seq.prompt.len(),
            "fork requires a decode-ready sequence"
        );
        let tail_len = seq.kv.len();
        if tail_len == 0 {
            return true;
        }
        // Tail rows cover prompt[prefix_len..] then generated[..fed].
        let fed = tail_len - (seq.prompt.len() - seq.prefix_len);
        let tail_tokens: Vec<u32> = seq.prompt[seq.prefix_len..]
            .iter()
            .chain(seq.generated[..fed].iter())
            .copied()
            .collect();
        let (node, evicted) = self.store.publish_evicting(
            seq.prefix.last().copied(),
            &tail_tokens,
            seq.prefix_len,
            &seq.kv,
            0,
        );
        self.metrics.prefix_segments_evicted += evicted as u64;
        let Some(node) = node else { return false };
        self.metrics.prefix_tokens_inserted += tail_tokens.len() as u64;
        let seq = &mut self.running[idx];
        self.store.radix.ref_chain(std::slice::from_ref(&node));
        seq.prefix.push(node);
        seq.prefix_len += tail_tokens.len();
        self.store.pool.release(&mut seq.blocks);
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        self.store.seed_calib(&seq.prefix, &mut seq.kv);
        true
    }

    /// Remove a beam loser: blocks and chain references released, no
    /// response emitted — the group's surviving hypotheses carry its
    /// outcome. (Defensively aggregates if this was somehow the last
    /// live sibling; the top-ranked candidate always continues some
    /// member, so that cannot happen in the normal flow.)
    fn prune_sibling(&mut self, idx: usize) {
        let mut seq = self.running.swap_remove(idx);
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        self.metrics.beam_prunes += 1;
        if let Some(gid) = seq.group {
            let empty = match self.groups.get_mut(&gid) {
                Some(g) => {
                    g.live -= 1;
                    g.live == 0
                }
                None => false,
            };
            if empty {
                self.emit_group_response(gid);
            }
        }
    }

    /// Stream a freshly forked child's newest token. The child sits in
    /// `running` (COW fork) or at the waiting front (recompute
    /// fallback); either way its pending token was just assigned and
    /// must reach the wire exactly once.
    fn stream_child_token(&mut self, id: RequestId, loc: Option<usize>) {
        let seq = match loc {
            Some(i) => &self.running[i],
            None => match self.waiting.iter().find(|s| s.id == id) {
                Some(s) => s,
                None => return,
            },
        };
        let tok = match (&seq.stream, seq.generated.last()) {
            (Some(_), Some(&t)) => t,
            _ => return,
        };
        let sink = seq.stream.as_ref().expect("matched above");
        let sibling = seq.sibling;
        if sink.push_token(tok, sibling) {
            self.metrics.tokens_streamed += 1;
            self.recorder.record(id, SpanKind::StreamSend, sibling as u64, tok as u64);
        }
    }

    /// Fork a running, decode-ready sequence mid-decode — the external
    /// face of publish-on-fork (tests, benches, agentic fork/join
    /// traces). The child gets the next engine id, shares the full
    /// chain — prompt AND generated rows — and continues independently:
    /// a standalone fork is its own request with its own terminal
    /// response; forking a grouped sibling adds a sibling to its group.
    /// Returns the child's id, or `None` if `id` isn't a running,
    /// decode-ready sequence.
    pub fn fork_request(&mut self, id: RequestId) -> Option<RequestId> {
        let idx = self.running.iter().position(|s| s.id == id)?;
        {
            let s = &self.running[idx];
            if s.prefilled < s.prompt.len() || s.generated.is_empty() {
                return None;
            }
        }
        let new_id = self.next_id;
        self.next_id += 1;
        if self.running[idx].group.is_none() {
            self.metrics.requests_submitted += 1;
        }
        self.fork_running(idx, new_id, |_| {});
        Some(new_id)
    }

    /// Generated-token count of an in-flight request (running or
    /// waiting); `None` once finished. Lets tests and the scenario
    /// bench trigger forks at a precise generation depth.
    pub fn generated_len(&self, id: RequestId) -> Option<usize> {
        self.running
            .iter()
            .chain(self.waiting.iter())
            .find(|s| s.id == id)
            .map(|s| s.generated.len())
    }

    /// (physical, logical) KV payload bytes. Physical counts each pool
    /// block in use once — a chain segment shared by many siblings
    /// lands once, however many reference it. Logical sums every
    /// in-flight sequence's attended coverage (shared chain + private
    /// tail) — what an engine without sharing would hold. Their ratio
    /// is the fork/prefix sharing factor the scenario bench reports.
    pub fn kv_bytes(&self) -> (u64, u64) {
        let c = &self.model.cfg;
        let bpt = (c.n_layers * c.n_heads * c.d_head * 2 * std::mem::size_of::<f32>())
            as u64;
        let used = (self.store.pool.total_blocks() - self.store.pool.free_blocks())
            as u64;
        let physical = used * self.cfg.block_tokens as u64 * bpt;
        let logical = self
            .running
            .iter()
            .chain(self.waiting.iter())
            .map(|s| (s.prefix_len + s.kv.len()) as u64)
            .sum::<u64>()
            * bpt;
        (physical, logical)
    }

    /// True once every admitted prompt is fully prefilled and nothing is
    /// waiting — the steady decode phase the serving bench reports
    /// separately from time-to-first-token.
    pub fn steady_state(&self) -> bool {
        self.waiting.is_empty()
            && self.running.iter().all(|s| s.prefilled >= s.prompt.len())
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.has_work() {
            let processed = self.step();
            if processed > 0 {
                continue;
            }
            // No progress anywhere. Transient contention never reaches
            // this point (any served token counts as progress), so what
            // follows are genuine-stall fallbacks, tried mildest-first.
            //
            // (0) The pool may be wedged by adopted chain segments whose
            // only references belong to the stalled sequences themselves
            // — self-reference makes them unevictable. Shed the oldest
            // holder's chain (deref + targeted evict + private
            // recompute): its pages return to the pool and the classic
            // guarantee that the oldest sequence can claim the whole
            // pool is restored. Repeated stalls shed the remaining
            // holders one per iteration, so this terminates.
            let holder = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.prefix.is_empty())
                .min_by_key(|(_, s)| s.priority)
                .map(|(i, _)| i);
            if let Some(idx) = holder {
                self.shed_prefix(idx);
                continue;
            }
            // (a) A running sequence larger than the whole pool.
            let seq_too_big = self.running.iter().position(|s| {
                self.store.pool.blocks_for(s.prompt.len() + s.params.max_new_tokens)
                    > self.store.pool.total_blocks()
            });
            if let Some(idx) = seq_too_big {
                self.finish(idx, FinishReason::Aborted);
                continue;
            }
            // (b) Nothing running and the head-of-line waiting request can
            // never be admitted (prompt exceeds the pool).
            if self.running.is_empty() {
                if let Some(seq) = self.waiting.front() {
                    if self.store.pool.blocks_for(seq.prompt.len() + 1)
                        > self.store.pool.total_blocks()
                    {
                        let mut seq = self.waiting.pop_front().unwrap();
                        self.store.pool.release(&mut seq.blocks);
                        self.emit_response(seq, FinishReason::Aborted);
                        continue;
                    }
                }
            }
        }
    }

    /// Remove waiting[j], release anything it holds, and emit a terminal
    /// response. (Waiting sequences normally hold no blocks or chain
    /// refs; releasing is defensive.)
    fn drop_waiting(&mut self, j: usize, reason: FinishReason) {
        let mut seq = self.waiting.remove(j).expect("index in bounds");
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        self.emit_response(seq, reason);
    }

    /// Abort every sequence — running or waiting — past its deadline,
    /// releasing its KV blocks and chain references. Runs at the top of
    /// each step, so an expired sequence never burns another decode.
    fn abort_expired(&mut self) {
        let now = Instant::now();
        let expired = |p: &GenerationParams| p.deadline.is_some_and(|d| now >= d);
        let mut i = 0;
        while i < self.running.len() {
            if expired(&self.running[i].params) {
                self.metrics.deadline_aborts += 1;
                self.finish(i, FinishReason::DeadlineExceeded);
                // finish() swap_removes: recheck index i.
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.waiting.len() {
            if expired(&self.waiting[j].params) {
                self.metrics.deadline_aborts += 1;
                self.drop_waiting(j, FinishReason::DeadlineExceeded);
            } else {
                j += 1;
            }
        }
    }

    /// Shed every sequence whose stream sink was severed (the consumer
    /// fell a full send-buffer behind). Runs at the top of each step so
    /// a severed stream stops consuming decode budget immediately; the
    /// sequence still reaches exactly one terminal outcome (`Cancelled`
    /// here — the router maps a severed sink to a `slow_consumer`
    /// terminal error frame). Waiting sequences are swept too: a
    /// preempted sequence keeps its sink and can sever while requeued.
    fn abort_severed(&mut self) {
        let severed =
            |s: &Sequence| s.stream.as_ref().is_some_and(|k| k.is_severed());
        let mut i = 0;
        while i < self.running.len() {
            if severed(&self.running[i]) {
                self.metrics.slow_consumer_sheds += 1;
                self.finish(i, FinishReason::Cancelled);
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.waiting.len() {
            if severed(&self.waiting[j]) {
                self.metrics.slow_consumer_sheds += 1;
                self.drop_waiting(j, FinishReason::Cancelled);
            } else {
                j += 1;
            }
        }
    }

    /// Cancel a request wherever it lives (running or waiting); returns
    /// true if found. The request still reaches exactly one terminal
    /// outcome: a `Cancelled` response carrying whatever was generated.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if self.groups.contains_key(&id) {
            self.metrics.disconnect_aborts += 1;
            // Fan the cancel out to every sibling; the group aggregates
            // into its single terminal response as the last one lands.
            loop {
                if let Some(i) =
                    self.running.iter().position(|s| s.group == Some(id))
                {
                    self.finish(i, FinishReason::Cancelled);
                    continue;
                }
                if let Some(j) =
                    self.waiting.iter().position(|s| s.group == Some(id))
                {
                    self.drop_waiting(j, FinishReason::Cancelled);
                    continue;
                }
                break;
            }
            return true;
        }
        if let Some(i) = self.running.iter().position(|s| s.id == id) {
            self.metrics.disconnect_aborts += 1;
            self.finish(i, FinishReason::Cancelled);
            return true;
        }
        if let Some(j) = self.waiting.iter().position(|s| s.id == id) {
            self.metrics.disconnect_aborts += 1;
            self.drop_waiting(j, FinishReason::Cancelled);
            return true;
        }
        false
    }

    /// Abort everything in flight (forced shutdown after the drain
    /// window expires). Every sequence gets an `Aborted` response.
    pub fn abort_all(&mut self) {
        while !self.waiting.is_empty() {
            self.drop_waiting(0, FinishReason::Aborted);
        }
        while !self.running.is_empty() {
            self.finish(0, FinishReason::Aborted);
        }
    }

    /// Drain every in-flight request after a caught panic. Returns
    /// `(retryable, failed)`: retryable requests never produced a
    /// visible token — and, since tokens are streamed at sample time,
    /// never streamed one either — so they are safe to re-dispatch
    /// verbatim to a survivor. The rest had progress a replay could not
    /// reproduce and must be answered with a structured error; each
    /// carries its emitted-token count (tokens streamed for streaming
    /// requests, tokens generated otherwise) for that error's
    /// truncation report. Pool/radix state is *not* released — the
    /// caller discards the whole engine.
    pub fn salvage(&mut self) -> (Vec<Request>, Vec<(Request, u64)>) {
        let mut retry = Vec::new();
        let mut dead = Vec::new();
        let drained: Vec<Sequence> =
            self.waiting.drain(..).chain(self.running.drain(..)).collect();
        // Group siblings collapse back to ONE request under the
        // submitted id — the router owes exactly one terminal outcome
        // per accepted request, never one per sibling.
        let mut grouped: Vec<(RequestId, Vec<Sequence>)> = Vec::new();
        for seq in drained {
            if let Some(gid) = seq.group {
                match grouped.iter_mut().find(|(g, _)| *g == gid) {
                    Some((_, v)) => v.push(seq),
                    None => grouped.push((gid, vec![seq])),
                }
                continue;
            }
            let fresh = seq.generated.is_empty() && seq.folded == 0;
            let emitted = seq
                .stream
                .as_ref()
                .map(|s| s.tokens_pushed())
                .unwrap_or(seq.generated.len() as u64);
            let req = Request {
                id: seq.id,
                prompt: seq.prompt,
                params: seq.params,
                attempts: seq.attempts,
                stream: seq.stream,
            };
            if fresh {
                retry.push(req);
            } else {
                dead.push((req, emitted));
            }
        }
        for (gid, sibs) in grouped {
            let g = self.groups.remove(&gid);
            // Retryable only if the group never fanned out, recorded no
            // choices, and its lone sequence made no visible progress.
            let fresh = sibs.len() == 1
                && sibs[0].generated.is_empty()
                && sibs[0].folded == 0
                && g.as_ref().is_none_or(|g| g.results.is_empty() && !g.forked);
            let prompt =
                g.map(|g| g.prompt).unwrap_or_else(|| sibs[0].prompt.clone());
            let emitted = sibs[0]
                .stream
                .as_ref()
                .map(|s| s.tokens_pushed())
                .unwrap_or_else(|| {
                    sibs.iter().map(|s| s.generated.len() as u64).sum()
                });
            let req = Request {
                id: gid,
                prompt,
                params: sibs[0].params,
                attempts: sibs[0].attempts,
                stream: sibs[0].stream.clone(),
            };
            if fresh {
                retry.push(req);
            } else {
                dead.push((req, emitted));
            }
        }
        self.groups.clear();
        (retry, dead)
    }

    /// After a full drain: evict every cached prefix and report KV
    /// blocks still held — the leak count (0 in a correct engine),
    /// cross-checked against the allocator's debug ledger.
    pub fn reclaim_and_count_leaks(&mut self) -> usize {
        assert!(!self.has_work(), "leak check requires a drained engine");
        // Full teardown reclaims the cold tier too (spill extents are
        // released alongside hot blocks; see `RadixIndex::evict_lru`).
        let evicted = self.store.make_room(usize::MAX);
        self.metrics.prefix_segments_evicted += evicted as u64;
        self.sync_tier_metrics();
        let leaked =
            self.store.pool.total_blocks() - self.store.pool.free_blocks();
        if leaked == 0 {
            self.store.pool.debug_assert_all_free();
        }
        leaked
    }

    /// Admit waiting sequences while there is batch room and pool room
    /// for their prompts. Admission matches the prompt against the radix
    /// cache first: matched tokens are adopted outright (never
    /// prefilled) and only the unmatched remainder reserves pool blocks.
    fn admit(&mut self) {
        while self.running.len() < self.cfg.scheduler.max_batch {
            let Some(front) = self.waiting.front() else { break };
            // A matched chain may hold cold (spilled) nodes; the lookup
            // refaults them within the scheduler's token budget before
            // handing the chain out, LRU-evicting other unreferenced
            // prefixes if blocks are short.
            let (chain, matched) = self.store.lookup_budgeted(
                &front.prompt,
                self.cfg.scheduler.refault_token_budget,
            );
            self.metrics.prefix_segments_evicted +=
                self.store.take_refault_evictions() as u64;
            if self.store.enabled() {
                self.metrics.prefix_lookups += 1;
            }
            // Reserve the unmatched prompt remainder + one decode token.
            let need = self
                .store
                .pool
                .blocks_for(front.prompt.len() - matched + 1);
            if need > self.store.pool.free_blocks() {
                // Keep the candidate chain alive while LRU eviction of
                // other unreferenced prefixes makes room.
                self.store.radix.ref_chain(&chain);
                let evicted = self.store.make_room(need);
                self.metrics.prefix_segments_evicted += evicted as u64;
                self.store.radix.deref_chain(&chain);
            }
            if need > self.store.pool.free_blocks() {
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            // Every admission demands a full-prompt prefill (preempted
            // re-admissions included) — the skip-rate denominator.
            self.metrics.prefill_tokens_demanded += seq.prompt.len() as u64;
            if self.recorder.enabled() {
                // Queue-wait covers submission → first admission only
                // (the arrival stamp is consumed here; re-admissions
                // after preemption record just the admit span).
                if let Some(t0) = self.arrivals.remove(&seq.id) {
                    self.recorder.record(
                        seq.id,
                        SpanKind::QueueWait,
                        clock::now_us().saturating_sub(t0),
                        self.waiting.len() as u64,
                    );
                }
                self.recorder.record(
                    seq.id,
                    SpanKind::Admit,
                    seq.prompt.len() as u64,
                    matched as u64,
                );
            }
            if matched > 0 {
                self.store.radix.ref_chain(&chain);
                seq.prefix = chain;
                seq.prefix_len = matched;
                seq.prefilled = matched;
                self.store.seed_calib(&seq.prefix, &mut seq.kv);
                self.metrics.prefix_hits += 1;
                self.metrics.prefill_tokens_skipped += matched as u64;
            }
            let mut blocks = self.store.pool.alloc(need).expect("checked free_blocks");
            seq.blocks.append(&mut blocks);
            self.running.push(seq);
        }
    }

    /// Ensure sequence `idx` holds blocks for `needed_tail_tokens` of
    /// private tail, first LRU-evicting unreferenced cached prefixes,
    /// then preempting strictly-younger sequences. Returns false if room
    /// could not be made. The requesting sequence is never evicted here.
    fn reserve_for(&mut self, idx: usize, needed_tail_tokens: usize) -> bool {
        let sid = self.running[idx].id;
        loop {
            let i = self
                .running
                .iter()
                .position(|s| s.id == sid)
                .expect("requester is never preempted by reserve_for");
            let my_priority = self.running[i].priority;
            let seq = &mut self.running[i];
            if self.store.pool.ensure(&mut seq.blocks, needed_tail_tokens) {
                return true;
            }
            // Reclaim unreferenced cached prefixes before touching any
            // live sequence.
            let deficit = self
                .store
                .pool
                .blocks_for(needed_tail_tokens)
                .saturating_sub(seq.blocks.len());
            let evicted = self.store.make_room(deficit);
            if evicted > 0 {
                self.metrics.prefix_segments_evicted += evicted as u64;
                continue;
            }
            // Evict a strictly-younger sequence, if any. Victim size is
            // its private tail — that is what preemption frees (its
            // chain refs drop too, making those segments evictable).
            let candidates: Vec<(usize, usize, u64)> = self
                .running
                .iter()
                .enumerate()
                .filter(|&(_, s)| s.priority > my_priority)
                .map(|(j, s)| (j, s.tail_tokens(), s.priority))
                .collect();
            match self.cfg.scheduler.pick_victim(&candidates) {
                Some(victim) => self.preempt(victim),
                None => return false, // only elders left: wait our turn
            }
        }
    }

    /// Shed an adopted chain without leaving the running set: drop the
    /// chain references, release the tail, and fold generated tokens
    /// back into the prompt for private recompute (exactly preemption's
    /// recompute semantics, minus the requeue — requeueing would just
    /// re-adopt the same cached chain and stall again). Once shed, the
    /// old chain's segments are unreferenced and this sequence's next
    /// reservation can evict them.
    fn shed_prefix(&mut self, idx: usize) {
        let seq = &mut self.running[idx];
        let chain = std::mem::take(&mut seq.prefix);
        self.store.radix.deref_chain(&chain);
        // Evict what we just released (leaf-first, stopping at nodes
        // other sequences still share) so the next lookup cannot simply
        // re-adopt the chain and wedge again.
        let evicted = self.store.radix.evict_chain(&mut self.store.pool, &chain);
        self.metrics.prefix_segments_evicted += evicted as u64;
        seq.prefix_len = 0;
        self.store.pool.release(&mut seq.blocks);
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        seq.prefilled = 0;
        let mut prompt = std::mem::take(&mut seq.prompt);
        prompt.extend(seq.generated[seq.folded..].iter().copied());
        seq.folded = seq.generated.len();
        seq.prompt = prompt;
        self.metrics.prefix_sheds += 1;
    }

    /// Preempt: release tail blocks, drop the chain references and the
    /// private KV, requeue for full recompute. A re-admitted sequence
    /// typically refaults straight onto its own published prefix — the
    /// radix cache turns preemption recompute into a lookup.
    fn preempt(&mut self, idx: usize) {
        let mut seq = self.running.swap_remove(idx);
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        let c = &self.model.cfg;
        seq.kv = KvState::new(c.n_layers, c.n_heads, c.d_head, self.cfg.hsr_backend);
        seq.prefilled = 0;
        // Generated tokens so far are preserved: they are re-fed as part
        // of the (extended) prompt on re-admission. Only the suffix not
        // folded by an earlier preemption/shed is appended — folding all
        // of `generated` twice would duplicate early generations in the
        // prompt.
        let mut prompt = std::mem::take(&mut seq.prompt);
        prompt.extend(seq.generated[seq.folded..].iter().copied());
        seq.folded = seq.generated.len();
        seq.prompt = prompt;
        self.metrics.requests_preempted += 1;
        self.waiting.push_front(seq);
    }

    /// Finish running[idx] with the given reason.
    fn finish(&mut self, idx: usize, reason: FinishReason) {
        let mut seq = self.running.swap_remove(idx);
        self.store.pool.release(&mut seq.blocks);
        self.store.radix.deref_chain(&seq.prefix);
        seq.prefix.clear();
        seq.prefix_len = 0;
        self.emit_response(seq, reason);
    }

    fn emit_response(&mut self, seq: Sequence, reason: FinishReason) {
        if seq.group.is_some() {
            return self.record_group_choice(seq, reason);
        }
        let latency = seq.submitted.elapsed();
        let ttft = seq
            .first_token_at
            .map(|t| t.duration_since(seq.submitted))
            .unwrap_or(latency);
        self.metrics.requests_completed += 1;
        self.metrics.request_latency.record(latency);
        self.metrics.ttft.record(ttft);
        self.arrivals.remove(&seq.id);
        if self.recorder.enabled() {
            let clean = matches!(
                reason,
                FinishReason::Length | FinishReason::StopToken
            );
            self.recorder.record(
                seq.id,
                SpanKind::Outcome,
                seq.generated.len() as u64,
                u64::from(!clean),
            );
            self.recorder.dump_request(seq.id);
        }
        self.finished.push(Response {
            id: seq.id,
            tokens: seq.generated,
            finish: reason,
            latency_ms: latency.as_secs_f64() * 1e3,
            ttft_ms: ttft.as_secs_f64() * 1e3,
            prompt_len: seq.prompt.len(),
            choices: Vec::new(),
        });
    }

    /// A grouped sibling finished: record its [`Choice`]; when it was
    /// the last live sibling, aggregate and emit the group's single
    /// response under the submitted request id.
    fn record_group_choice(&mut self, mut seq: Sequence, reason: FinishReason) {
        let gid = seq.group.expect("caller checked");
        let Some(g) = self.groups.get_mut(&gid) else {
            // Group already aggregated — a double-finish would be a bug,
            // but never drop an outcome on the floor: emit standalone.
            seq.group = None;
            return self.emit_response(seq, reason);
        };
        if let Some(t) = seq.first_token_at {
            g.first_token_at = Some(match g.first_token_at {
                Some(prev) if prev <= t => prev,
                _ => t,
            });
        }
        g.results.push(Choice {
            index: seq.sibling,
            tokens: seq.generated,
            finish: reason,
            logprob: seq.score,
        });
        g.live -= 1;
        let done = g.live == 0;
        if done {
            self.emit_group_response(gid);
        }
    }

    /// Rank and emit the single multi-choice response of a completed
    /// group: clean finishes (Length/StopToken) first, then cumulative
    /// log-probability descending, then sibling index — truncated to
    /// `keep` (a `best_of > n` run drops its extra candidates here).
    /// The best choice mirrors into the response's flat `tokens` /
    /// `finish` fields so plain single-answer consumers keep working.
    fn emit_group_response(&mut self, gid: RequestId) {
        let Some(mut g) = self.groups.remove(&gid) else { return };
        let clean = |f: FinishReason| {
            matches!(f, FinishReason::Length | FinishReason::StopToken)
        };
        g.results.sort_by(|a, b| {
            clean(b.finish)
                .cmp(&clean(a.finish))
                .then(
                    b.logprob
                        .partial_cmp(&a.logprob)
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
                .then(a.index.cmp(&b.index))
        });
        g.results.truncate(g.keep.max(1));
        let latency = g.submitted.elapsed();
        let ttft = g
            .first_token_at
            .map(|t| t.duration_since(g.submitted))
            .unwrap_or(latency);
        self.metrics.requests_completed += 1;
        self.metrics.request_latency.record(latency);
        self.metrics.ttft.record(ttft);
        self.arrivals.remove(&gid);
        let best = g.results.first();
        if self.recorder.enabled() {
            let cleanly = best.is_some_and(|c| clean(c.finish));
            self.recorder.record(
                gid,
                SpanKind::Outcome,
                best.map(|c| c.tokens.len()).unwrap_or(0) as u64,
                u64::from(!cleanly),
            );
            self.recorder.dump_request(gid);
        }
        self.finished.push(Response {
            id: gid,
            tokens: best.map(|c| c.tokens.clone()).unwrap_or_default(),
            finish: best.map(|c| c.finish).unwrap_or(FinishReason::Aborted),
            latency_ms: latency.as_secs_f64() * 1e3,
            ttft_ms: ttft.as_secs_f64() * 1e3,
            prompt_len: g.prompt.len(),
            choices: g.results,
        });
    }

    /// Pool utilization (diagnostics).
    pub fn cache_utilization(&self) -> f64 {
        self.store.pool.utilization()
    }
}
