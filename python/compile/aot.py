"""AOT exporter: train the tiny LMs, dump weight bundles + golden vectors,
and lower the decode/prefill/kernel computations to HLO **text** for the
rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published `xla` crate) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.

Outputs under --out (default ../artifacts):
  manifest.json                 shapes/configs for every artifact
  model_<name>.{json,bin}       weight bundles (rust tensor_io format)
  golden_<name>.{json,bin}      parity vectors: tokens + expected logits
  decode_step_<name>.hlo.txt    dense decode step (token, pos, caches)
  prefill_<name>.hlo.txt        prompt prefill (tokens -> logits + caches)
  masked_softmax_attn.hlo.txt   L1 pallas masked softmax (gathered layout)
  masked_relu_attn.hlo.txt      L1 pallas masked ReLU^alpha
  train_log.json                loss curves of the build-time training

Idempotent: `make artifacts` skips everything if the manifest exists and
is newer than the python sources (the Makefile handles staleness).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .kernels import hsr_attn

# Context length the dense decode-step artifact is compiled for.
DECODE_N_CTX = 512
PREFILL_T = 256
# Gathered-block capacity of the exported masked-attention kernels.
KERNEL_R_MAX = 256
KERNEL_D_HEAD = 32
KERNEL_HEADS = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the model weights are baked into the
    # HLO as constants; the default printer elides them to "{...}" which
    # parses back as garbage on the rust side.
    return comp.as_hlo_text(True)


def save_bundle(stem: str, tensors: dict[str, np.ndarray], meta: dict) -> None:
    """Write the rust `tensor_io` format: <stem>.json + <stem>.bin."""
    blob = bytearray()
    manifest_tensors = {}
    for name in sorted(tensors):
        arr = np.asarray(tensors[name], dtype=np.float32)
        manifest_tensors[name] = {
            "offset": len(blob) // 4,
            "shape": list(arr.shape),
        }
        blob.extend(arr.astype("<f4").tobytes())
    manifest = {"dtype": "f32", "byte_len": len(blob), "tensors": manifest_tensors}
    manifest.update(meta)
    with open(stem + ".json", "w") as f:
        json.dump(manifest, f)
    with open(stem + ".bin", "wb") as f:
        f.write(bytes(blob))


def export_model(cfg, params, losses, out_dir: str) -> dict:
    """Weights + golden vectors + HLO artifacts for one model size."""
    name = cfg.name
    np_params = {k: np.asarray(v) for k, v in params.items()}
    meta = {
        "config": {
            "name": cfg.name,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
            "d_ffn": cfg.d_ffn,
            "vocab": model_mod.VOCAB_SIZE,
            "rope_theta": model_mod.ROPE_THETA,
            "rms_eps": model_mod.RMS_EPS,
        },
        "final_loss": losses[-1] if losses else None,
    }
    save_bundle(os.path.join(out_dir, f"model_{name}"), np_params, meta)

    # Golden vectors: two fixed token sequences and their logits, plus a
    # decode-step check (prefill 31 tokens, decode the 32nd).
    golden_tokens = data_mod.eval_document(seed=7, length=64).astype(np.int32)
    seq_a = golden_tokens[:32]
    seq_b = golden_tokens[32:64]
    logits_a = np.asarray(model_mod.forward(params, cfg, jnp.asarray(seq_a)))
    logits_b = np.asarray(model_mod.forward(params, cfg, jnp.asarray(seq_b)))
    # Decode-step golden: cache from prefill of seq_a[:31], then step.
    _, k_cache, v_cache = model_mod.prefill(params, cfg, jnp.asarray(seq_a[:31]))
    pad = DECODE_N_CTX - 31
    k_pad = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v_pad = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    step_logits, _, _ = model_mod.decode_step(
        params, cfg, jnp.asarray(seq_a[31]), jnp.asarray(31), k_pad, v_pad
    )
    save_bundle(
        os.path.join(out_dir, f"golden_{name}"),
        {
            "tokens_a": seq_a.astype(np.float32),
            "tokens_b": seq_b.astype(np.float32),
            "logits_a": logits_a,
            "logits_b": logits_b,
            "decode_logits": np.asarray(step_logits),
        },
        {"decode_pos": 31, "n_ctx": DECODE_N_CTX},
    )
    return meta["config"]


def export_hlo(cfg, params, out_dir: str) -> dict:
    """Lower decode-step and prefill for this model to HLO text. Weights
    are baked in as constants (closure capture) so the rust side only
    feeds activations — one compiled executable per model, like a real
    serving deployment."""
    name = cfg.name
    entries = {}

    def decode_fn(token, pos, k_cache, v_cache):
        return model_mod.decode_step(params, cfg, token, pos, k_cache, v_cache)

    cache_shape = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_heads, DECODE_N_CTX, cfg.d_head), jnp.float32
    )
    lowered = jax.jit(decode_fn).lower(
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        cache_shape,
        cache_shape,
    )
    path = os.path.join(out_dir, f"decode_step_{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    entries[f"decode_step_{name}"] = {
        "file": os.path.basename(path),
        "inputs": [
            {"name": "token", "shape": [], "dtype": "s32"},
            {"name": "pos", "shape": [], "dtype": "s32"},
            {"name": "k_cache", "shape": list(cache_shape.shape), "dtype": "f32"},
            {"name": "v_cache", "shape": list(cache_shape.shape), "dtype": "f32"},
        ],
        "outputs": [
            {"name": "logits", "shape": [model_mod.VOCAB_SIZE], "dtype": "f32"},
            {"name": "new_k", "shape": [cfg.n_layers, cfg.n_heads, cfg.d_head], "dtype": "f32"},
            {"name": "new_v", "shape": [cfg.n_layers, cfg.n_heads, cfg.d_head], "dtype": "f32"},
        ],
        "n_ctx": DECODE_N_CTX,
    }

    def prefill_fn(tokens):
        return model_mod.prefill(params, cfg, tokens)

    lowered = jax.jit(prefill_fn).lower(
        jax.ShapeDtypeStruct((PREFILL_T,), jnp.int32)
    )
    path = os.path.join(out_dir, f"prefill_{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    entries[f"prefill_{name}"] = {
        "file": os.path.basename(path),
        "inputs": [{"name": "tokens", "shape": [PREFILL_T], "dtype": "s32"}],
        "outputs": [
            {"name": "logits", "shape": [PREFILL_T, model_mod.VOCAB_SIZE], "dtype": "f32"},
            {
                "name": "k_cache",
                "shape": [cfg.n_layers, cfg.n_heads, PREFILL_T, cfg.d_head],
                "dtype": "f32",
            },
            {
                "name": "v_cache",
                "shape": [cfg.n_layers, cfg.n_heads, PREFILL_T, cfg.d_head],
                "dtype": "f32",
            },
        ],
    }
    return entries


def export_kernels(out_dir: str) -> dict:
    """Standalone L1 pallas kernels in the gathered layout (DESIGN.md
    §Hardware-Adaptation): the rust engine can execute the paper's hot
    spot through PJRT directly."""
    entries = {}
    h, r, dh = KERNEL_HEADS, KERNEL_R_MAX, KERNEL_D_HEAD
    q_s = jax.ShapeDtypeStruct((h, dh), jnp.float32)
    g_s = jax.ShapeDtypeStruct((h, r, dh), jnp.float32)
    c_s = jax.ShapeDtypeStruct((h,), jnp.int32)

    def softmax_fn(q, kg, vg, count):
        return (hsr_attn.masked_softmax_attention(q, kg, vg, count),)

    lowered = jax.jit(softmax_fn).lower(q_s, g_s, g_s, c_s)
    path = os.path.join(out_dir, "masked_softmax_attn.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    entries["masked_softmax_attn"] = {
        "file": os.path.basename(path),
        "heads": h,
        "r_max": r,
        "d_head": dh,
    }

    def relu_fn(q, kg, vg, count):
        return (hsr_attn.masked_relu_attention(q, kg, vg, count, bias=0.0, alpha=2),)

    lowered = jax.jit(relu_fn).lower(q_s, g_s, g_s, c_s)
    path = os.path.join(out_dir, "masked_relu_attn.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    entries["masked_relu_attn"] = {
        "file": os.path.basename(path),
        "heads": h,
        "r_max": r,
        "d_head": dh,
        "alpha": 2,
        "bias": 0.0,
    }
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default="mini,small,base")
    ap.add_argument("--hlo-model", default="small", help="model whose decode/prefill HLO is exported")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fast", action="store_true", help="tiny training run for CI/tests")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    manifest: dict = {"models": {}, "hlo": {}, "kernel_r_max": KERNEL_R_MAX}
    train_log: dict = {}
    for name in args.models.split(","):
        cfg = model_mod.CONFIGS[name]
        steps = 30 if args.fast else args.steps
        corpus = 60_000 if args.fast else 400_000
        print(f"=== training {name} ({cfg.param_count():,} params, {steps} steps)", flush=True)
        params, losses = train_mod.train(
            cfg, seed=42, steps=steps, corpus_bytes=corpus,
            seq_len=96 if args.fast else 192,
            batch_size=8 if args.fast else 12,
        )
        manifest["models"][name] = export_model(cfg, params, losses, out_dir)
        train_log[name] = losses
        if name == args.hlo_model:
            print(f"=== lowering HLO for {name}", flush=True)
            manifest["hlo"].update(export_hlo(cfg, params, out_dir))

    manifest["hlo"].update(export_kernels(out_dir))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(train_log, f)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts written to {out_dir}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
