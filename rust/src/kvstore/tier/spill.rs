//! [`SpillStore`] — the cold tier's backing store: an extent-allocated
//! byte arena that is either in-memory (hermetic tests, benches) or a
//! file (positioned reads/writes via `FileExt`, the no-new-deps stand-in
//! for an mmap; the kernel's page cache gives the same warm-read
//! behavior).
//!
//! Records are opaque byte blobs. The store hands out [`Extent`]s from a
//! first-fit free list with neighbor coalescing, so a refault→re-spill
//! churn cycle reuses space instead of growing the arena forever. One
//! store per [`super::super::PagePool`]; spill files are uniquely named
//! per (process, store) and unlinked on drop, so multi-worker engines
//! can all point `--spill` at the same directory.

use super::SpillConfig;
use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// A byte range inside the spill arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub offset: u64,
    pub len: u64,
}

enum Backing {
    Memory(Vec<u8>),
    File { file: File, path: PathBuf },
}

/// Extent-allocated spill arena.
pub struct SpillStore {
    backing: Backing,
    /// High-water mark: fresh extents bump this when the free list
    /// has no fit.
    end: u64,
    /// Free extents, sorted by offset, adjacent ranges coalesced.
    free: Vec<Extent>,
    live_bytes: u64,
    /// Cumulative bytes ever written (the `spill_bytes` counter feed).
    written_bytes: u64,
}

/// Per-process store counter, so several pools spilling into one
/// directory never collide on a file name.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

impl SpillStore {
    /// Open the backing named by `cfg`; `Ok(None)` when spill is off.
    pub fn open(cfg: &SpillConfig) -> io::Result<Option<SpillStore>> {
        let backing = match cfg {
            SpillConfig::Off => return Ok(None),
            SpillConfig::Memory => Backing::Memory(Vec::new()),
            SpillConfig::Dir(dir) => {
                std::fs::create_dir_all(dir)?;
                let path = dir.join(format!(
                    "kv-spill-{}-{}.bin",
                    std::process::id(),
                    STORE_SEQ.fetch_add(1, Ordering::Relaxed)
                ));
                let file = OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create_new(true)
                    .open(&path)?;
                Backing::File { file, path }
            }
        };
        Ok(Some(SpillStore {
            backing,
            end: 0,
            free: Vec::new(),
            live_bytes: 0,
            written_bytes: 0,
        }))
    }

    /// Carve an extent for `len` bytes: first-fit from the free list
    /// (splitting any remainder back), else bump the high-water mark.
    fn carve(&mut self, len: u64) -> Extent {
        for i in 0..self.free.len() {
            if self.free[i].len >= len {
                let ext = Extent { offset: self.free[i].offset, len };
                if self.free[i].len == len {
                    self.free.remove(i);
                } else {
                    self.free[i].offset += len;
                    self.free[i].len -= len;
                }
                return ext;
            }
        }
        let ext = Extent { offset: self.end, len };
        self.end += len;
        ext
    }

    /// Write `data` into a fresh extent.
    pub fn write(&mut self, data: &[u8]) -> io::Result<Extent> {
        let ext = self.carve(data.len() as u64);
        let res = match &mut self.backing {
            Backing::Memory(buf) => {
                let need = (ext.offset + ext.len) as usize;
                if buf.len() < need {
                    buf.resize(need, 0);
                }
                buf[ext.offset as usize..need].copy_from_slice(data);
                Ok(())
            }
            Backing::File { file, .. } => file.write_all_at(data, ext.offset),
        };
        match res {
            Ok(()) => {
                self.live_bytes += ext.len;
                self.written_bytes += ext.len;
                Ok(ext)
            }
            Err(e) => {
                // A failed write must not leak its extent.
                self.release_extent(ext, false);
                Err(e)
            }
        }
    }

    /// Read an extent back.
    pub fn read(&self, ext: Extent) -> io::Result<Vec<u8>> {
        let mut out = vec![0u8; ext.len as usize];
        match &self.backing {
            Backing::Memory(buf) => {
                let lo = ext.offset as usize;
                let hi = lo + ext.len as usize;
                let src = buf.get(lo..hi).ok_or_else(|| {
                    io::Error::new(io::ErrorKind::UnexpectedEof, "extent out of arena")
                })?;
                out.copy_from_slice(src);
            }
            Backing::File { file, .. } => file.read_exact_at(&mut out, ext.offset)?,
        }
        Ok(out)
    }

    /// Return an extent to the free list (coalescing neighbors).
    pub fn release(&mut self, ext: Extent) {
        self.release_extent(ext, true);
    }

    fn release_extent(&mut self, ext: Extent, was_live: bool) {
        if ext.len == 0 {
            return;
        }
        if was_live {
            self.live_bytes -= ext.len;
        }
        let pos = self
            .free
            .partition_point(|e| e.offset < ext.offset);
        self.free.insert(pos, ext);
        // Coalesce with the next extent, then the previous one.
        if pos + 1 < self.free.len()
            && self.free[pos].offset + self.free[pos].len == self.free[pos + 1].offset
        {
            self.free[pos].len += self.free[pos + 1].len;
            self.free.remove(pos + 1);
        }
        if pos > 0
            && self.free[pos - 1].offset + self.free[pos - 1].len == self.free[pos].offset
        {
            self.free[pos - 1].len += self.free[pos].len;
            self.free.remove(pos);
        }
    }

    /// Bytes currently held by live extents.
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Cumulative bytes ever written.
    pub fn written_bytes(&self) -> u64 {
        self.written_bytes
    }

    /// Arena high-water mark (file size / memory footprint upper bound).
    pub fn arena_bytes(&self) -> u64 {
        self.end
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        if let Backing::File { path, .. } = &self.backing {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unique_tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "hsr-attn-spill-{tag}-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn exercise(store: &mut SpillStore) {
        let a = store.write(&[1u8; 100]).unwrap();
        let b = store.write(&[2u8; 50]).unwrap();
        let c = store.write(&[3u8; 10]).unwrap();
        assert_eq!(store.live_bytes(), 160);
        assert_eq!(store.read(b).unwrap(), vec![2u8; 50]);
        // Free the middle extent; a smaller write must reuse it.
        store.release(b);
        assert_eq!(store.live_bytes(), 110);
        let d = store.write(&[4u8; 40]).unwrap();
        assert_eq!(d.offset, a.len, "first-fit reuses the freed hole");
        assert_eq!(store.read(a).unwrap(), vec![1u8; 100]);
        assert_eq!(store.read(c).unwrap(), vec![3u8; 10]);
        assert_eq!(store.read(d).unwrap(), vec![4u8; 40]);
        // Release everything: free list coalesces back to one extent
        // and the next write lands at offset 0.
        store.release(a);
        store.release(c);
        store.release(d);
        assert_eq!(store.live_bytes(), 0);
        assert_eq!(store.free.len(), 1);
        let e = store.write(&[5u8; 8]).unwrap();
        assert_eq!(e.offset, 0);
        assert_eq!(store.read(e).unwrap(), vec![5u8; 8]);
    }

    #[test]
    fn memory_backing_extent_reuse_and_coalescing() {
        let mut store = SpillStore::open(&SpillConfig::Memory).unwrap().unwrap();
        exercise(&mut store);
        assert!(store.written_bytes() >= 208);
    }

    #[test]
    fn dir_backing_roundtrip_and_cleanup() {
        let dir = unique_tmp_dir("dir");
        let mut store = SpillStore::open(&SpillConfig::Dir(dir.clone())).unwrap().unwrap();
        let spill_file = match &store.backing {
            Backing::File { path, .. } => path.clone(),
            _ => unreachable!(),
        };
        assert!(spill_file.exists());
        exercise(&mut store);
        drop(store);
        assert!(!spill_file.exists(), "spill file unlinked on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn off_config_opens_nothing() {
        assert!(SpillStore::open(&SpillConfig::Off).unwrap().is_none());
    }

    #[test]
    fn two_stores_in_one_dir_do_not_collide() {
        let dir = unique_tmp_dir("multi");
        let mut s1 = SpillStore::open(&SpillConfig::Dir(dir.clone())).unwrap().unwrap();
        let mut s2 = SpillStore::open(&SpillConfig::Dir(dir.clone())).unwrap().unwrap();
        let e1 = s1.write(b"worker-one").unwrap();
        let e2 = s2.write(b"worker-two").unwrap();
        assert_eq!(s1.read(e1).unwrap(), b"worker-one");
        assert_eq!(s2.read(e2).unwrap(), b"worker-two");
        drop(s1);
        drop(s2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
