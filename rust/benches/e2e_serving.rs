//! Bench/reproduction: **headline claims** — end-to-end serving
//! throughput/latency with HSR-sparse attention vs the dense baseline,
//! plus the shared-prefix KV store on a common-prompt workload
//! (BENCH_serving.json: prefix-hit rate, prefill tokens skipped, and
//! steady-state tok/s shared vs unshared).
//!
//! The sparse-vs-dense section needs the trained artifacts (`make
//! artifacts`) and skips without them; the shared-prefix, streaming,
//! and overload sections fall back to a deterministic synthetic model
//! so their numbers are always reproducible.
//!
//! Flags: --shared-only (skip the artifact section), --overload-only
//! (run just the admission-control section), --streaming-only (run just
//! the streaming/affinity section), --tiered-only (run just the
//! tiered-KV cold-spill/dedup section), --model NAME,
//! --shared-requests N, --shared-prompt N, --shared-gen N,
//! --stream-requests N, --stream-prompt N, --stream-gen N,
//! --overload-requests N, --overload-prompt N, --overload-gen N,
//! --tiered-requests N, --tiered-prompt N, --tiered-gen N,
//! --tiered-hot-blocks N, --tiered-policy rebuild|serialize,
//! --tiered-tenants N, --scenarios-only (run just the fork/join
//! sampling + beam scenarios), --scenario-requests N,
//! --scenario-prompt N, --scenario-gen N, --obs-only (run just the
//! observability section: tracing overhead, fired-fraction telemetry,
//! live stats scrapes), --obs-requests N, --obs-prompt N, --obs-gen N,
//! --obs-reps N.

use hsr_attn::bench::banner;
use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{GenerationParams, Router, RouterConfig, SchedulerConfig};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::kvstore::{
    PrefixCacheMode, PrefixStore, SpillConfig, SpillPolicy, TierConfig,
};
use hsr_attn::model::kv::KvState;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::obs::TraceConfig;
use hsr_attn::server::{Client, Server, StreamFrame, WireRequest};
use hsr_attn::util::cli::Args;
use hsr_attn::util::json::Json;
use hsr_attn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct RunResult {
    wall_s: f64,
    gen_tokens: u64,
    /// Decode throughput measured only over steps that started in steady
    /// state (all admitted prompts prefilled, nothing waiting) — the
    /// batching win, undiluted by prefill.
    steady_tok_per_s: f64,
    /// Time to first token, p50 across requests.
    ttft_p50_ns: u64,
    attended_frac: f64,
    p50_step_ns: u64,
    /// Shared-prefix counters (zero with the cache off).
    prefill_tokens_skipped: u64,
    prefill_tokens_demanded: u64,
    prefix_hit_rate: f64,
    grouped_decode_rows: u64,
    segments_evicted: u64,
}

/// Drive `prompts` to completion, timing steady-state decode separately.
fn drive(mut eng: Engine, prompts: Vec<Vec<u32>>, gen: usize) -> RunResult {
    for p in prompts {
        eng.submit(
            p,
            GenerationParams { max_new_tokens: gen, ..Default::default() },
        );
    }
    let requests = eng.metrics.requests_submitted;
    let t0 = Instant::now();
    let mut steady_ns: u128 = 0;
    let mut steady_tok: u64 = 0;
    while eng.has_work() {
        let was_steady = eng.steady_state();
        let g0 = eng.metrics.generated_tokens;
        let ts = Instant::now();
        let processed = eng.step();
        if was_steady {
            steady_ns += ts.elapsed().as_nanos();
            steady_tok += eng.metrics.generated_tokens - g0;
        }
        if processed == 0 {
            eng.run_to_completion(); // stuck-work fallback (aborts)
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    RunResult {
        wall_s,
        gen_tokens: eng.metrics.generated_tokens + requests, // + seeded
        steady_tok_per_s: if steady_ns > 0 {
            steady_tok as f64 / (steady_ns as f64 * 1e-9)
        } else {
            0.0
        },
        ttft_p50_ns: eng.metrics.ttft.percentile_ns(50.0),
        attended_frac: eng.metrics.attended_fraction(),
        p50_step_ns: eng.metrics.step_latency.percentile_ns(50.0),
        prefill_tokens_skipped: eng.metrics.prefill_tokens_skipped,
        prefill_tokens_demanded: eng.metrics.prefill_tokens_demanded,
        prefix_hit_rate: eng.metrics.prefix_hit_rate(),
        grouped_decode_rows: eng.metrics.grouped_decode_rows,
        segments_evicted: eng.metrics.prefix_segments_evicted,
    }
}

fn corpus() -> Vec<u32> {
    "the merchant carries copper coins by the river. \
     remember: alder keeps the amber token. the alder token is amber. "
        .bytes()
        .cycle()
        .take(8192)
        .map(|b| b as u32)
        .collect()
}

fn run(
    model: Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    requests: usize,
    prompt_len: usize,
    gen: usize,
    max_batch: usize,
) -> RunResult {
    let mut rng = Rng::new(11);
    let eng = Engine::new(
        model,
        EngineConfig {
            policy,
            hsr_backend: backend,
            // The sparse-vs-dense table is the PR 0-3 baseline: keep the
            // prefix cache out of it so the numbers stay comparable
            // (the shared_prefix_section measures the cache explicitly).
            prefix_cache: PrefixCacheMode::Off,
            scheduler: SchedulerConfig { max_batch, ..Default::default() },
            ..Default::default()
        },
    );
    let corpus = corpus();
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| {
            let s = rng.below(corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    drive(eng, prompts, gen)
}

/// The shared-prompt workload: every request carries the SAME prompt
/// (the multi-turn / common-system-prompt serving setting), run once
/// with the prefix cache off and once on.
fn shared_prefix_section(args: &Args) {
    let requests = args.usize_or("shared-requests", 32);
    let prompt_len = args.usize_or("shared-prompt", 256);
    let gen = args.usize_or("shared-gen", 32);
    let model_name = args.str_or("model", "small");
    let (model, model_desc) = if artifacts_dir().join("manifest.json").exists() {
        (
            Arc::new(Model::load_named(&artifacts_dir(), model_name).unwrap()),
            model_name.to_string(),
        )
    } else {
        // Deterministic fallback so this section always runs.
        (Arc::new(Model::synthetic(90, 2, 4, 8)), "synthetic-90".to_string())
    };
    println!(
        "\n== shared-prefix serving: {requests} requests x (identical prompt {prompt_len} + gen {gen}), model '{model_desc}' =="
    );
    let corpus = corpus();
    let prompt = corpus[..prompt_len].to_vec();
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let backend = Some(HsrBackend::BallTree);
    let mut results: Vec<(&str, PrefixCacheMode, RunResult)> = Vec::new();
    for (name, mode) in [
        ("prefix-cache off (unshared baseline)", PrefixCacheMode::Off),
        ("prefix-cache on (radix + grouped decode)", PrefixCacheMode::default()),
    ] {
        let eng = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                policy,
                hsr_backend: backend,
                prefix_cache: mode,
                scheduler: SchedulerConfig { max_batch: requests, ..Default::default() },
                ..Default::default()
            },
        );
        let prompts = vec![prompt.clone(); requests];
        let r = drive(eng, prompts, gen);
        results.push((name, mode, r));
    }
    println!(
        "{:<42} {:>8} {:>13} {:>10} {:>14} {:>12}",
        "configuration", "wall s", "steady tok/s", "ttft p50", "prefill skip", "grouped rows"
    );
    for (name, _, r) in &results {
        println!(
            "{:<42} {:>8.2} {:>13.1} {:>10} {:>13.1}% {:>12}",
            name,
            r.wall_s,
            r.steady_tok_per_s,
            hsr_attn::util::stats::fmt_ns(r.ttft_p50_ns as f64),
            100.0 * r.prefill_tokens_skipped as f64 / r.prefill_tokens_demanded.max(1) as f64,
            r.grouped_decode_rows,
        );
    }
    let off = &results[0].2;
    let on = &results[1].2;
    let skip_pct =
        100.0 * on.prefill_tokens_skipped as f64 / on.prefill_tokens_demanded.max(1) as f64;
    let steady_speedup = if off.steady_tok_per_s > 0.0 {
        on.steady_tok_per_s / off.steady_tok_per_s
    } else {
        0.0
    };
    println!(
        "\nprefill tokens skipped: {:.1}%  |  steady-state speedup: {:.2}x  |  hit rate {:.0}%",
        skip_pct,
        steady_speedup,
        100.0 * on.prefix_hit_rate
    );

    // Machine-readable report at the repo root.
    let mut root = Json::obj();
    root.set("model", model_desc.as_str().into())
        .set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("backend", "balltree".into())
        .set("prefill_tokens_skipped_pct", skip_pct.into())
        .set("prefix_hit_rate", on.prefix_hit_rate.into())
        .set("steady_speedup", steady_speedup.into());
    for (key, r) in [("unshared", off), ("shared", on)] {
        let mut o = Json::obj();
        o.set("wall_s", r.wall_s.into())
            .set("gen_tokens", r.gen_tokens.into())
            .set("steady_tok_per_s", r.steady_tok_per_s.into())
            .set("ttft_p50_ns", r.ttft_p50_ns.into())
            .set("p50_step_ns", r.p50_step_ns.into())
            .set("prefill_tokens_skipped", r.prefill_tokens_skipped.into())
            .set("prefill_tokens_demanded", r.prefill_tokens_demanded.into())
            .set("grouped_decode_rows", r.grouped_decode_rows.into())
            .set("segments_evicted", r.segments_evicted.into());
        root.set(key, o);
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

struct StreamRun {
    wall_s: f64,
    /// TTFT as the client saw it: request line flushed → first `token`
    /// frame parsed (p50 across the cohort).
    ttft_wire_p50_ms: f64,
    tokens: u64,
    /// Streams that ended in a clean `done` frame.
    completed: usize,
    /// Streams that ended any other way (error/cancelled/refused).
    failed: usize,
    prefix_hit_rate: f64,
    prefill_skip_pct: f64,
    affinity_hits: u64,
    affinity_fallbacks: u64,
    streams_severed: u64,
}

/// One streaming cohort through the TCP front-end: `requests` parallel
/// clients all sending the same prompt with `"stream": true`, against a
/// 4-worker router with affinity on or off.
fn stream_cohort(
    model: Arc<Model>,
    affinity: bool,
    requests: usize,
    prompt: &str,
    gen: usize,
) -> StreamRun {
    let rcfg = RouterConfig { affinity, ..Default::default() };
    let router = Arc::new(Router::with_config(model, EngineConfig::default(), 4, rcfg));
    let server = Server::bind(router.clone(), "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());

    let t0 = Instant::now();
    let mut clients = Vec::new();
    for _ in 0..requests {
        let addr = addr.clone();
        let prompt = prompt.to_string();
        // (ttft_ms, token frames received, ended with a clean `done`)
        clients.push(std::thread::spawn(move || -> Option<(f64, u64, bool)> {
            let mut c = Client::connect(&addr).ok()?;
            let sent = Instant::now();
            c.send(&WireRequest {
                prompt,
                max_new_tokens: gen,
                stream: true,
                ..Default::default()
            })
            .ok()?;
            let mut ttft_ms: Option<f64> = None;
            let mut tokens = 0u64;
            loop {
                match c.read_frame().ok()? {
                    StreamFrame::Token { .. } => {
                        if ttft_ms.is_none() {
                            ttft_ms = Some(sent.elapsed().as_secs_f64() * 1e3);
                        }
                        tokens += 1;
                    }
                    StreamFrame::Keepalive { .. } => {}
                    StreamFrame::Done { .. } => {
                        return Some((ttft_ms.unwrap_or(0.0), tokens, true));
                    }
                    StreamFrame::Error { .. } | StreamFrame::Cancelled { .. } => {
                        return Some((ttft_ms.unwrap_or(0.0), tokens, false));
                    }
                }
            }
        }));
    }
    let mut ttfts: Vec<f64> = Vec::new();
    let mut tokens = 0u64;
    let (mut completed, mut failed) = (0usize, 0usize);
    for h in clients {
        match h.join().expect("client thread") {
            Some((t, n, clean)) => {
                ttfts.push(t);
                tokens += n;
                if clean {
                    completed += 1;
                } else {
                    failed += 1;
                }
            }
            None => failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let _ = srv.join().expect("server thread");
    let router = Arc::try_unwrap(router).ok().expect("server released router");
    let m = router.shutdown();
    StreamRun {
        wall_s,
        ttft_wire_p50_ms: if ttfts.is_empty() {
            0.0
        } else {
            hsr_attn::util::stats::percentile(&ttfts, 50.0)
        },
        tokens,
        completed,
        failed,
        prefix_hit_rate: m.prefix_hit_rate(),
        prefill_skip_pct: 100.0 * m.prefix_skip_rate(),
        affinity_hits: m.affinity_hits,
        affinity_fallbacks: m.affinity_fallbacks,
        streams_severed: m.streams_severed,
    }
}

/// Streaming + affinity section: a shared-prompt cohort streams through
/// the TCP front-end twice — prefix-affinity routing on, then off — on
/// a 4-worker pool. Reports wire TTFT (client-measured), per-run prefix
/// cache effectiveness, and the affinity counters; merged into
/// BENCH_serving.json under `"streaming_affinity"`. Synthetic model, so
/// it always runs.
fn streaming_affinity_section(args: &Args) {
    let requests = args.usize_or("stream-requests", 32);
    let prompt_len = args.usize_or("stream-prompt", 256);
    let gen = args.usize_or("stream-gen", 24);
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    let prompt_text = String::from_utf8(
        corpus[..prompt_len].iter().map(|&t| t as u8).collect(),
    )
    .expect("corpus is ASCII");
    println!(
        "\n== streaming: {requests}-way shared-prompt cohort over TCP (gen {gen}), \
         affinity on vs off (4 workers) =="
    );
    let on = stream_cohort(Arc::clone(&model), true, requests, &prompt_text, gen);
    let off = stream_cohort(Arc::clone(&model), false, requests, &prompt_text, gen);
    println!(
        "{:<14} {:>8} {:>14} {:>8} {:>12} {:>13} {:>10} {:>10}",
        "routing", "wall s", "ttft p50 ms", "tokens", "prefix hit", "prefill skip", "aff hits",
        "fallbacks"
    );
    for (name, r) in [("affinity on", &on), ("affinity off", &off)] {
        println!(
            "{:<14} {:>8.2} {:>14.2} {:>8} {:>11.0}% {:>12.1}% {:>10} {:>10}",
            name,
            r.wall_s,
            r.ttft_wire_p50_ms,
            r.tokens,
            100.0 * r.prefix_hit_rate,
            r.prefill_skip_pct,
            r.affinity_hits,
            r.affinity_fallbacks,
        );
    }
    println!(
        "\nprefix-hit rate: affinity {:.0}% vs least-loaded {:.0}%; \
         clean streams {}+{} of {}; severed {}",
        100.0 * on.prefix_hit_rate,
        100.0 * off.prefix_hit_rate,
        on.completed,
        off.completed,
        2 * requests,
        on.streams_severed + off.streams_severed,
    );

    // Read-modify-write: this section shares BENCH_serving.json with the
    // shared-prefix section, which may or may not have run this
    // invocation.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or_else(Json::obj);
    let mut sec = Json::obj();
    sec.set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("workers", 4usize.into());
    for (key, r) in [("affinity_on", &on), ("affinity_off", &off)] {
        let mut o = Json::obj();
        o.set("wall_s", r.wall_s.into())
            .set("ttft_wire_p50_ms", r.ttft_wire_p50_ms.into())
            .set("tokens_streamed", r.tokens.into())
            .set("completed", r.completed.into())
            .set("failed", r.failed.into())
            .set("prefix_hit_rate", r.prefix_hit_rate.into())
            .set("prefill_tokens_skipped_pct", r.prefill_skip_pct.into())
            .set("affinity_hits", r.affinity_hits.into())
            .set("affinity_fallbacks", r.affinity_fallbacks.into())
            .set("streams_severed", r.streams_severed.into());
        sec.set(key, o);
    }
    root.set("streaming_affinity", sec);
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// Overload section: calibrate the pool's sustainable completion rate
/// closed-loop, then offer 4x that rate through a tightly-capped router
/// and measure the shed rate plus the latency of the accepted requests
/// (BENCH_robustness.json). Always runs on the synthetic model, so the
/// admission-control numbers need no artifacts.
fn overload_section(args: &Args) {
    let requests = args.usize_or("overload-requests", 48);
    let gen = args.usize_or("overload-gen", 16);
    let prompt_len = args.usize_or("overload-prompt", 64);
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    let mut rng = Rng::new(23);
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| {
            let s = rng.below(corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    let params = GenerationParams { max_new_tokens: gen, ..Default::default() };
    println!("\n== overload: admission control at 4x the sustainable rate (2 workers) ==");

    // Calibrate closed-loop with the default (generous) caps.
    let cal_n = requests.min(24);
    let cal = Router::new(Arc::clone(&model), EngineConfig::default(), 2);
    let t0 = Instant::now();
    for p in prompts.iter().take(cal_n) {
        cal.submit(p.clone(), params).expect("calibration submit under default caps");
    }
    cal.wait_idle();
    let sustainable = cal_n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    cal.shutdown();

    // Offer 4x through tight queues; count sheds, time the accepted.
    let rcfg = RouterConfig {
        max_queue_per_worker: 6,
        max_in_flight: 16,
        ..Default::default()
    };
    let router = Router::with_config(Arc::clone(&model), EngineConfig::default(), 2, rcfg);
    let offered = sustainable * 4.0;
    let gap = std::time::Duration::from_secs_f64(1.0 / offered.max(1.0));
    let (mut accepted, mut shed) = (0usize, 0usize);
    for p in &prompts {
        match router.submit(p.clone(), params) {
            Ok(_) => accepted += 1,
            Err(_) => shed += 1,
        }
        std::thread::sleep(gap);
    }
    router.wait_idle();
    let responses = router.take_responses();
    let metrics = router.shutdown();
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let (p50, p99) = if latencies.is_empty() {
        (0.0, 0.0)
    } else {
        (
            hsr_attn::util::stats::percentile(&latencies, 50.0),
            hsr_attn::util::stats::percentile(&latencies, 99.0),
        )
    };
    let shed_rate = shed as f64 / requests.max(1) as f64;
    println!(
        "sustainable {sustainable:.1} req/s -> offered {offered:.1} req/s: \
         accepted {accepted} / shed {shed} ({:.0}% shed)",
        100.0 * shed_rate
    );
    println!("accepted-request latency: p50 {p50:.1} ms, p99 {p99:.1} ms");

    let mut root = Json::obj();
    root.set("requests_offered", requests.into())
        .set("sustainable_req_per_s", sustainable.into())
        .set("offered_req_per_s", offered.into())
        .set("accepted", accepted.into())
        .set("shed", shed.into())
        .set("shed_rate", shed_rate.into())
        .set("accepted_latency_p50_ms", p50.into())
        .set("accepted_latency_p99_ms", p99.into())
        .set("requests_rejected_metric", metrics.requests_rejected.into());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_robustness.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

struct TierPhase {
    wall_s: f64,
    gen_tok_per_s: f64,
    skipped: u64,
    demanded: u64,
}

impl TierPhase {
    fn skip_pct(&self) -> f64 {
        100.0 * self.skipped as f64 / self.demanded.max(1) as f64
    }
}

/// Drive one cohort through an existing engine (so segments published —
/// or demoted — by an earlier phase are visible), deltaing the prefill
/// counters across the phase.
fn drive_phase(eng: &mut Engine, prompts: &[Vec<u32>], gen: usize) -> TierPhase {
    let skip0 = eng.metrics.prefill_tokens_skipped;
    let dem0 = eng.metrics.prefill_tokens_demanded;
    let gen0 = eng.metrics.generated_tokens;
    for p in prompts {
        eng.submit(
            p.clone(),
            GenerationParams { max_new_tokens: gen, ..Default::default() },
        );
    }
    let t0 = Instant::now();
    eng.run_to_completion();
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = eng.take_finished();
    TierPhase {
        wall_s,
        gen_tok_per_s: (eng.metrics.generated_tokens - gen0) as f64 / wall_s.max(1e-9),
        skipped: eng.metrics.prefill_tokens_skipped - skip0,
        demanded: eng.metrics.prefill_tokens_demanded - dem0,
    }
}

/// Tiered-KV section (BENCH_kv_tiers.json): a working set 2-4x the hot
/// cap is driven twice through the same engine. With spill off, phase 2
/// re-prefills whatever LRU eviction destroyed; with the cold tier on,
/// demoted prefixes refault and phase 2 skips their prefill. Plus a
/// 32-tenant dedup sweep: the same document chunk under per-tenant
/// parents collapses to one physical segment. Synthetic model, so it
/// always runs.
fn tiered_kv_section(args: &Args) {
    let requests = args.usize_or("tiered-requests", 24);
    let prompt_len = args.usize_or("tiered-prompt", 96);
    let gen = args.usize_or("tiered-gen", 8);
    let hot_blocks = args.usize_or("tiered-hot-blocks", 48);
    let block_tokens = 16usize;
    let policy = SpillPolicy::parse(args.str_or("tiered-policy", "rebuild"))
        .unwrap_or_default();
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    // Non-overlapping corpus slices: distinct prompts, so the hot tier
    // genuinely overflows instead of deduping away.
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|i| {
            let s = (i * prompt_len) % (corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    let cap = hot_blocks * block_tokens;
    let working = requests * prompt_len;
    println!(
        "\n== tiered KV: working set {working} tokens vs hot cap {cap} ({:.1}x), \
         spill off vs mem ({policy:?}) ==",
        working as f64 / cap.max(1) as f64
    );

    let run_tiered = |spill: SpillConfig| {
        let mut eng = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                policy: AttentionPolicy::TopR(RSpec::paper()),
                hsr_backend: Some(HsrBackend::BallTree),
                prefix_cache: PrefixCacheMode::default(),
                cache_capacity_tokens: cap,
                block_tokens,
                spill,
                spill_policy: policy,
                ..Default::default()
            },
        );
        let p1 = drive_phase(&mut eng, &prompts, gen);
        let p2 = drive_phase(&mut eng, &prompts, gen);
        let stats = eng.prefix_store().pool.tier_stats();
        let leaked = eng.reclaim_and_count_leaks();
        (p1, p2, stats, leaked)
    };
    let (off1, off2, _, off_leak) = run_tiered(SpillConfig::Off);
    let (mem1, mem2, stats, mem_leak) = run_tiered(SpillConfig::Memory);
    println!(
        "{:<26} {:>9} {:>12} {:>13} {:>9} {:>12} {:>13}",
        "configuration", "p1 wall", "p1 tok/s", "p1 skip", "p2 wall", "p2 tok/s", "p2 skip"
    );
    for (name, p1, p2) in
        [("spill off (re-prefill)", &off1, &off2), ("spill mem (refault)", &mem1, &mem2)]
    {
        println!(
            "{:<26} {:>8.2}s {:>12.1} {:>12.1}% {:>8.2}s {:>12.1} {:>12.1}%",
            name,
            p1.wall_s,
            p1.gen_tok_per_s,
            p1.skip_pct(),
            p2.wall_s,
            p2.gen_tok_per_s,
            p2.skip_pct(),
        );
    }
    println!(
        "\nphase-2 prefill skip: {:.1}% (spill mem) vs {:.1}% (spill off)  |  \
         {} spilled / {} refaulted, {} spill bytes, {:.1} ms rebuild  |  leaks {}+{}",
        mem2.skip_pct(),
        off2.skip_pct(),
        stats.segments_spilled,
        stats.segments_refaulted,
        stats.spill_bytes,
        stats.refault_rebuild_ns as f64 * 1e-6,
        off_leak,
        mem_leak,
    );

    // Dedup sweep: `tenants` tenants each publish a unique 16-token
    // parent and then the SAME doc-chunk segment under it; content-hash
    // dedup collapses the chunks onto one physical payload.
    let tenants = args.usize_or("tiered-tenants", 32);
    let doc_len = 64usize;
    let backend = Some(HsrBackend::BallTree);
    let mut rng = Rng::new(31);
    let mut src = KvState::new(2, 4, 8, backend);
    for _ in 0..16 + doc_len {
        for l in 0..2 {
            for h in 0..4 {
                let k = rng.gaussian_vec_f32(8, 1.0);
                let v = rng.gaussian_vec_f32(8, 1.0);
                src.head_mut(l, h).append(&k, &v);
            }
        }
    }
    let doc: Vec<u32> = (0..doc_len as u32).map(|i| (i * 5 + 2) % 256).collect();
    let mut store = PrefixStore::with_tier(
        1 << 14,
        block_tokens,
        backend,
        PrefixCacheMode::Min(1),
        &TierConfig { spill: SpillConfig::Memory, policy },
    );
    for tenant in 0..tenants as u32 {
        let parent_toks: Vec<u32> = (0..16).map(|i| 1000 * (tenant + 1) + i).collect();
        let parent = store
            .publish_segment(None, &parent_toks, 0, &src, 0, 0)
            .expect("parent fits");
        store
            .publish_segment(Some(parent), &doc, 16, &src, 16, 0)
            .expect("doc fits or dedups");
    }
    let physical = store.pool.physical_payload_bytes();
    let logical = store.pool.logical_payload_bytes();
    let dstats = store.pool.tier_stats();
    println!(
        "\ndedup sweep: {tenants} tenants x identical {doc_len}-token doc -> \
         {} physical segments, {} dedup hits, {} bytes saved (logical {} / physical {} = {:.2}x)",
        store.pool.segment_count() - tenants,
        dstats.dedup_hits,
        dstats.dedup_bytes_saved,
        logical,
        physical,
        logical as f64 / physical.max(1) as f64,
    );
    store.make_room(usize::MAX);
    assert_eq!(store.pool.free_blocks(), store.pool.total_blocks(), "dedup sweep leaked");

    let mut root = Json::obj();
    root.set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("hot_cap_tokens", cap.into())
        .set("working_set_tokens", working.into())
        .set("spill_policy", format!("{policy:?}").as_str().into());
    for (key, p1, p2, leaked) in [
        ("spill_off", &off1, &off2, off_leak),
        ("spill_mem", &mem1, &mem2, mem_leak),
    ] {
        let mut o = Json::obj();
        o.set("phase1_wall_s", p1.wall_s.into())
            .set("phase1_tok_per_s", p1.gen_tok_per_s.into())
            .set("phase1_skip_pct", p1.skip_pct().into())
            .set("phase2_wall_s", p2.wall_s.into())
            .set("phase2_tok_per_s", p2.gen_tok_per_s.into())
            .set("phase2_skip_pct", p2.skip_pct().into())
            .set("kv_blocks_leaked", leaked.into());
        root.set(key, o);
    }
    let mut tier = Json::obj();
    tier.set("segments_spilled", stats.segments_spilled.into())
        .set("segments_refaulted", stats.segments_refaulted.into())
        .set("spill_bytes", stats.spill_bytes.into())
        .set("refault_rebuild_ms", (stats.refault_rebuild_ns as f64 * 1e-6).into());
    root.set("tier", tier);
    let mut dedup = Json::obj();
    dedup
        .set("tenants", tenants.into())
        .set("doc_len", doc_len.into())
        .set("dedup_hits", dstats.dedup_hits.into())
        .set("dedup_bytes_saved", dstats.dedup_bytes_saved.into())
        .set("physical_payload_bytes", physical.into())
        .set("logical_payload_bytes", logical.into())
        .set(
            "sharing_ratio",
            (logical as f64 / physical.max(1) as f64).into(),
        );
    root.set("dedup", dedup);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kv_tiers.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

struct ScenarioRun {
    wall_s: f64,
    steady_tok_per_s: f64,
    gen_tokens: u64,
    /// Peak over the run of `Engine::kv_bytes()` — physical is blocks
    /// actually allocated, logical is what every sibling would cost if
    /// nothing were shared. The gap is the COW-fork + prefix-cache win.
    peak_physical_kv: u64,
    peak_logical_kv: u64,
    prefill_skip_pct: f64,
    sequence_forks: u64,
    fork_shared_tokens: u64,
    beam_prunes: u64,
    choices: usize,
    leaked: usize,
}

impl ScenarioRun {
    fn sharing_ratio(&self) -> f64 {
        self.peak_logical_kv as f64 / self.peak_physical_kv.max(1) as f64
    }
}

/// One fork/join scenario: `requests` identical prompts (the shared
/// system-prompt setting) decoded with the given group shape, stepping
/// manually so peak physical-vs-logical KV bytes are sampled mid-run
/// while every sibling is live.
fn scenario(
    model: Arc<Model>,
    requests: usize,
    prompt: &[u32],
    params: GenerationParams,
) -> ScenarioRun {
    let width = params.beam_width.max(params.best_of).max(params.n).max(1) as usize;
    let mut eng = Engine::new(
        model,
        EngineConfig {
            policy: AttentionPolicy::TopR(RSpec::paper()),
            hsr_backend: Some(HsrBackend::BallTree),
            prefix_cache: PrefixCacheMode::default(),
            scheduler: SchedulerConfig {
                max_batch: requests * width,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    for _ in 0..requests {
        eng.submit(prompt.to_vec(), params);
    }
    let t0 = Instant::now();
    let (mut steady_ns, mut steady_tok) = (0u128, 0u64);
    let (mut peak_phys, mut peak_logical) = (0u64, 0u64);
    while eng.has_work() {
        let was_steady = eng.steady_state();
        let g0 = eng.metrics.generated_tokens;
        let ts = Instant::now();
        let processed = eng.step();
        if was_steady {
            steady_ns += ts.elapsed().as_nanos();
            steady_tok += eng.metrics.generated_tokens - g0;
        }
        let (phys, logical) = eng.kv_bytes();
        peak_phys = peak_phys.max(phys);
        peak_logical = peak_logical.max(logical);
        if processed == 0 {
            eng.run_to_completion();
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let choices: usize = eng.take_finished().iter().map(|r| r.choices.len().max(1)).sum();
    let m = eng.metrics.clone();
    let leaked = eng.reclaim_and_count_leaks();
    ScenarioRun {
        wall_s,
        steady_tok_per_s: if steady_ns > 0 {
            steady_tok as f64 / (steady_ns as f64 * 1e-9)
        } else {
            0.0
        },
        gen_tokens: m.generated_tokens,
        peak_physical_kv: peak_phys,
        peak_logical_kv: peak_logical,
        prefill_skip_pct: 100.0 * m.prefix_skip_rate(),
        sequence_forks: m.sequence_forks,
        fork_shared_tokens: m.fork_shared_tokens,
        beam_prunes: m.beam_prunes,
        choices,
        leaked,
    }
}

/// Fork/join scenarios section (BENCH_scenarios.json): parallel
/// sampling at n=1/4/16 plus width-4 beam search over COW-forked
/// chains, all on a shared prompt. Reports peak physical-vs-logical KV
/// bytes (the block-sharing win), prefill-skip %, and steady tok/s.
/// Synthetic model, so it always runs.
fn scenarios_section(args: &Args) {
    let requests = args.usize_or("scenario-requests", 8);
    let prompt_len = args.usize_or("scenario-prompt", 192);
    let gen = args.usize_or("scenario-gen", 24);
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    let prompt = &corpus[..prompt_len];
    println!(
        "\n== fork/join scenarios: {requests} requests x (shared prompt {prompt_len} + gen {gen}), \
         sampling n=1/4/16 + beam w=4 =="
    );
    let cases: Vec<(&str, GenerationParams)> = vec![
        (
            "sampling_n1",
            GenerationParams { max_new_tokens: gen, ..Default::default() },
        ),
        (
            "sampling_n4",
            GenerationParams {
                max_new_tokens: gen,
                temperature: 1.0,
                n: 4,
                ..Default::default()
            },
        ),
        (
            "sampling_n16",
            GenerationParams {
                max_new_tokens: gen,
                temperature: 1.0,
                n: 16,
                ..Default::default()
            },
        ),
        (
            "beam_w4",
            GenerationParams { max_new_tokens: gen, beam_width: 4, ..Default::default() },
        ),
    ];
    let mut results: Vec<(&str, ScenarioRun)> = Vec::new();
    for (name, params) in cases {
        let r = scenario(Arc::clone(&model), requests, prompt, params);
        results.push((name, r));
    }
    println!(
        "{:<14} {:>8} {:>13} {:>12} {:>12} {:>9} {:>13} {:>8}",
        "scenario", "wall s", "steady tok/s", "phys KV", "logical KV", "share x", "prefill skip",
        "choices"
    );
    for (name, r) in &results {
        println!(
            "{:<14} {:>8.2} {:>13.1} {:>12} {:>12} {:>8.1}x {:>12.1}% {:>8}",
            name,
            r.wall_s,
            r.steady_tok_per_s,
            r.peak_physical_kv,
            r.peak_logical_kv,
            r.sharing_ratio(),
            r.prefill_skip_pct,
            r.choices,
        );
        assert_eq!(r.leaked, 0, "scenario {name} leaked KV blocks");
    }
    let n16 = &results.iter().find(|(n, _)| *n == "sampling_n16").expect("n16 ran").1;
    println!(
        "\nn=16 sampling: {} forks share {} prompt tokens -> {:.1}x logical/physical KV; \
         beam prunes {}",
        n16.sequence_forks,
        n16.fork_shared_tokens,
        n16.sharing_ratio(),
        results.iter().find(|(n, _)| *n == "beam_w4").map_or(0, |(_, r)| r.beam_prunes),
    );

    let mut root = Json::obj();
    root.set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("backend", "balltree".into());
    for (name, r) in &results {
        let mut o = Json::obj();
        o.set("wall_s", r.wall_s.into())
            .set("steady_tok_per_s", r.steady_tok_per_s.into())
            .set("gen_tokens", r.gen_tokens.into())
            .set("peak_physical_kv_bytes", r.peak_physical_kv.into())
            .set("peak_logical_kv_bytes", r.peak_logical_kv.into())
            .set("kv_sharing_ratio", r.sharing_ratio().into())
            .set("prefill_tokens_skipped_pct", r.prefill_skip_pct.into())
            .set("sequence_forks", r.sequence_forks.into())
            .set("fork_shared_tokens", r.fork_shared_tokens.into())
            .set("beam_prunes", r.beam_prunes.into())
            .set("choices", r.choices.into())
            .set("kv_blocks_leaked", r.leaked.into());
        root.set(name, o);
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scenarios.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

struct ObsRun {
    wall_s: f64,
    steady_tok_per_s: f64,
    gen_tokens: u64,
    fired_overall: f64,
    fired_count: u64,
    fired_hist: Json,
}

/// One tracing-on-or-off run of the sparse serving workload, keeping
/// the engine long enough to read its sparsity telemetry afterwards.
fn obs_run(model: &Arc<Model>, trace: bool, prompts: &[Vec<u32>], gen: usize) -> ObsRun {
    let mut eng = Engine::new(
        Arc::clone(model),
        EngineConfig {
            policy: AttentionPolicy::TopR(RSpec::paper()),
            hsr_backend: Some(HsrBackend::BallTree),
            prefix_cache: PrefixCacheMode::Off,
            trace: TraceConfig { enabled: trace, ..Default::default() },
            scheduler: SchedulerConfig { max_batch: 8, ..Default::default() },
            ..Default::default()
        },
    );
    for p in prompts {
        eng.submit(
            p.clone(),
            GenerationParams { max_new_tokens: gen, ..Default::default() },
        );
    }
    let t0 = Instant::now();
    let (mut steady_ns, mut steady_tok) = (0u128, 0u64);
    while eng.has_work() {
        let was_steady = eng.steady_state();
        let g0 = eng.metrics.generated_tokens;
        let ts = Instant::now();
        let processed = eng.step();
        if was_steady {
            steady_ns += ts.elapsed().as_nanos();
            steady_tok += eng.metrics.generated_tokens - g0;
        }
        if processed == 0 {
            eng.run_to_completion();
            break;
        }
    }
    ObsRun {
        wall_s: t0.elapsed().as_secs_f64(),
        steady_tok_per_s: if steady_ns > 0 {
            steady_tok as f64 / (steady_ns as f64 * 1e-9)
        } else {
            0.0
        },
        gen_tokens: eng.metrics.generated_tokens,
        fired_overall: eng.metrics.fired_fraction.overall_fraction(),
        fired_count: eng.metrics.fired_fraction.count(),
        fired_hist: eng.metrics.fired_fraction.to_json(),
    }
}

/// Observability section (BENCH_obs.json): (1) tracing must be cheap —
/// the same sparse workload with the flight recorder on vs off, best
/// steady tok/s over `--obs-reps` repetitions each, reported as an
/// overhead percentage against the 3% budget; (2) the fired-fraction
/// telemetry per context-length bucket next to the paper's n^{-1/5}
/// envelope; (3) the live export surface — two `{"cmd":"stats"}`
/// scrapes around real traffic on a served pool, asserting the
/// snapshot contract (required keys present, counters monotone) plus a
/// Prometheus-text scrape. Synthetic model, so it always runs.
fn obs_section(args: &Args) {
    let requests = args.usize_or("obs-requests", 24);
    let prompt_len = args.usize_or("obs-prompt", 192);
    let gen = args.usize_or("obs-gen", 24);
    let reps = args.usize_or("obs-reps", 3).max(1);
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    let mut rng = Rng::new(41);
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| {
            let s = rng.below(corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    println!(
        "\n== observability: {requests} requests x (prompt {prompt_len} + gen {gen}), \
         flight recorder on vs off ({reps} reps, best) =="
    );

    // Interleave on/off repetitions so drift (cache warmup, CPU clocks)
    // hits both configurations alike; keep the best steady tok/s each.
    let (mut best_on, mut best_off): (Option<ObsRun>, Option<ObsRun>) = (None, None);
    for _ in 0..reps {
        for trace in [true, false] {
            let r = obs_run(&model, trace, &prompts, gen);
            let slot = if trace { &mut best_on } else { &mut best_off };
            if slot.as_ref().is_none_or(|b| r.steady_tok_per_s > b.steady_tok_per_s) {
                *slot = Some(r);
            }
        }
    }
    let on = best_on.expect("reps >= 1");
    let off = best_off.expect("reps >= 1");
    let overhead_pct = if off.steady_tok_per_s > 0.0 {
        100.0 * (1.0 - on.steady_tok_per_s / off.steady_tok_per_s)
    } else {
        0.0
    };
    println!(
        "{:<22} {:>8} {:>13} {:>10}",
        "tracing", "wall s", "steady tok/s", "gen tok"
    );
    for (name, r) in [("flight recorder on", &on), ("flight recorder off", &off)] {
        println!(
            "{:<22} {:>8.2} {:>13.1} {:>10}",
            name, r.wall_s, r.steady_tok_per_s, r.gen_tokens
        );
    }
    println!(
        "tracing overhead: {overhead_pct:+.2}% steady tok/s (budget 3%)  |  \
         fired fraction {:.4} over {} queries",
        on.fired_overall, on.fired_count
    );
    if let Some(rows) = on.fired_hist.as_arr() {
        println!(
            "{:>10} {:>8} {:>14} {:>12}",
            "ctx >=", "queries", "mean fired", "n^-1/5"
        );
        for row in rows {
            println!(
                "{:>10} {:>8} {:>13.4} {:>12.4}",
                row.get("ctx_lo").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                row.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                row.get("mean_fraction").and_then(Json::as_f64).unwrap_or(0.0),
                row.get("envelope").and_then(Json::as_f64).unwrap_or(0.0),
            );
        }
    }

    // Live export surface: scrape a served pool before and after real
    // traffic; the snapshot contract (keys, monotone counters) is
    // asserted, not just printed.
    let router = Arc::new(Router::with_config(
        Arc::clone(&model),
        EngineConfig::default(),
        2,
        RouterConfig::default(),
    ));
    let server = Server::bind(router.clone(), "127.0.0.1:0").expect("bind ephemeral");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());
    let mut client = Client::connect(&addr).expect("connect for stats");
    let snap1 = client.stats().expect("first stats scrape");
    for p in prompts.iter().take(8) {
        router
            .submit(
                p.clone(),
                GenerationParams { max_new_tokens: gen, ..Default::default() },
            )
            .expect("submit under default caps");
    }
    router.wait_idle();
    let _ = router.take_responses();
    let snap2 = client.stats().expect("second stats scrape");
    let prom = client.stats_prometheus().expect("prometheus scrape");
    drop(client);
    stop.store(true, Ordering::Relaxed);
    let _ = srv.join().expect("server thread");
    let router = Arc::try_unwrap(router).ok().expect("server released router");
    router.shutdown();

    for (which, snap) in [("first", &snap1), ("second", &snap2)] {
        for k in ["ts_us", "counters", "gauges", "histograms", "fired_fraction"] {
            assert!(snap.get(k).is_some(), "{which} stats snapshot missing key '{k}'");
        }
    }
    let counter = |s: &Json, name: &str| {
        s.get("counters").and_then(|c| c.get(name)).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let ts = |s: &Json| s.get("ts_us").and_then(Json::as_f64).unwrap_or(0.0);
    assert!(ts(&snap2) >= ts(&snap1), "snapshot clock went backwards");
    for name in ["requests_submitted", "requests_completed", "generated_tokens"] {
        assert!(
            counter(&snap2, name) >= counter(&snap1, name),
            "counter '{name}' not monotone across scrapes"
        );
    }
    let generated_delta =
        counter(&snap2, "generated_tokens") - counter(&snap1, "generated_tokens");
    assert!(generated_delta > 0.0, "second scrape saw none of the traffic");
    assert!(
        prom.contains("hsr_generated_tokens"),
        "prometheus exposition missing hsr_generated_tokens"
    );
    println!(
        "\nlive scrapes: 2 ok, counters monotone, +{generated_delta:.0} generated tokens \
         between scrapes; prometheus exposition {} lines",
        prom.lines().count()
    );

    let mut root = Json::obj();
    root.set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("reps", reps.into())
        .set("backend", "balltree".into());
    for (key, r) in [("trace_on", &on), ("trace_off", &off)] {
        let mut o = Json::obj();
        o.set("wall_s", r.wall_s.into())
            .set("steady_tok_per_s", r.steady_tok_per_s.into())
            .set("gen_tokens", r.gen_tokens.into());
        root.set(key, o);
    }
    root.set("tracing_overhead_pct", overhead_pct.into())
        .set("within_3pct", (overhead_pct <= 3.0).into())
        .set("fired_fraction_overall", on.fired_overall.into())
        .set("fired_fraction_queries", on.fired_count.into())
        .set("fired_fraction", on.fired_hist.clone());
    let mut scrape = Json::obj();
    scrape
        .set("scrapes", 2usize.into())
        .set("required_keys_ok", true.into())
        .set("counters_monotone", true.into())
        .set("generated_tokens_delta", generated_delta.into())
        .set("prometheus_lines", prom.lines().count().into());
    root.set("live_scrape", scrape);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    banner("e2e_serving", "headline: sparse vs dense serving + shared-prefix KV store");
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));

    if args.flag("overload-only") {
        overload_section(&args);
        return;
    }
    if args.flag("streaming-only") {
        streaming_affinity_section(&args);
        return;
    }
    if args.flag("tiered-only") {
        tiered_kv_section(&args);
        return;
    }
    if args.flag("scenarios-only") {
        scenarios_section(&args);
        return;
    }
    if args.flag("obs-only") {
        obs_section(&args);
        return;
    }
    shared_prefix_section(&args);
    if args.flag("shared-only") {
        return;
    }
    streaming_affinity_section(&args);
    overload_section(&args);
    tiered_kv_section(&args);
    scenarios_section(&args);
    obs_section(&args);

    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("\nartifacts missing — run `make artifacts`; skipping sparse-vs-dense section");
        return;
    }
    let model_name = args.str_or("model", "small");
    let requests = args.usize_or("requests", 12);
    let prompt_len = args.usize_or("prompt", 384);
    let gen = args.usize_or("gen", 96);
    let model = Arc::new(Model::load_named(&artifacts_dir(), model_name).unwrap());
    println!(
        "\nmodel '{}', {} requests x (prompt {} + gen {})\n",
        model_name, requests, prompt_len, gen
    );

    println!(
        "{:<44} {:>9} {:>12} {:>13} {:>10} {:>11} {:>10}",
        "configuration", "wall s", "gen tok/s", "steady tok/s", "ttft p50", "p50 step", "attended"
    );
    let cases: Vec<(String, AttentionPolicy, Option<HsrBackend>, usize)> = vec![
        ("dense baseline (batch 8)".into(), AttentionPolicy::Dense, None, 8),
        (
            "sparse top-r=n^0.8, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, brute scan (ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            None,
            8,
        ),
        (
            "sparse top-r=64 fixed, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::Fixed(64)),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, balltree (batch 1 ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            1,
        ),
    ];
    for (name, policy, backend, batch) in cases {
        let r = run(model.clone(), policy, backend, requests, prompt_len, gen, batch);
        println!(
            "{:<44} {:>9.2} {:>12.1} {:>13.1} {:>10} {:>11} {:>9.1}%",
            name,
            r.wall_s,
            r.gen_tokens as f64 / r.wall_s,
            r.steady_tok_per_s,
            hsr_attn::util::stats::fmt_ns(r.ttft_p50_ns as f64),
            hsr_attn::util::stats::fmt_ns(r.p50_step_ns as f64),
            r.attended_frac * 100.0
        );
    }
    println!("\nexpected: sparse attends a small fraction of entries; steady tok/s");
    println!("isolates the batched decode win from prefill (ttft reported apart);");
    println!("wall-clock gains grow with context (see decode_time for scaling).");
}
