//! The serving engine — the L3 coordination layer.
//!
//! * [`decode`] / [`prefill`] — the paper's Algorithm 1 and Algorithm 2 as
//!   standalone data structures over raw Q/K/V (what the theorem-level
//!   benches exercise). Both are thin shims over the unified
//!   [`crate::attention::AttentionSession`] plan→execute API.
//! * [`serving`] — the continuous-batching engine integrating Algorithm 1
//!   into real LM serving: paged KV cache ([`kv_cache`]), chunked
//!   prefill, preemption ([`scheduler`]), per-(layer, head) dynamic HSR
//!   indices, and [`metrics`].
//! * [`router`] — multi-worker request routing.

pub mod decode;
pub mod kv_cache;
pub mod metrics;
pub mod prefill;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod serving;
pub mod stream;

pub use decode::GenerationDecoding;
pub use prefill::{PrefillResult, PromptPrefilling};
pub use request::{Choice, FinishReason, GenerationParams, Request, RequestId, Response};
pub use router::{Outcome, RequestError, Router, RouterConfig, SubmitError};
pub use scheduler::{PreemptPolicy, SchedulerConfig};
pub use serving::{Engine, EngineConfig, Fault, FaultKind, FaultPlan};
pub use stream::{StreamEvent, StreamRecv, StreamSink};
