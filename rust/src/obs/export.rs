//! Metrics export surface: a snapshot/delta registry over
//! [`Metrics`](crate::engine::metrics::Metrics) with a Prometheus-style
//! text exposition and a JSON form.
//!
//! The registry is one static table of `(name, kind, help, accessor)`
//! rows — the metric-name catalog the README documents — so the JSON
//! snapshot, the Prometheus text, the periodic stderr line, and the
//! bench validators all agree on names by construction. Snapshots are
//! cheap value copies; [`Snapshot::delta_line`] renders rates between
//! two of them for the `--metrics-interval` reporter.

use super::clock;
use super::telemetry::ratio_or;
use crate::engine::metrics::Metrics;
use crate::util::json::Json;
use crate::util::stats::Histogram;
use std::fmt::Write as _;

/// Exposition kind of one registry row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone non-decreasing over an engine's lifetime.
    Counter,
    /// Point-in-time value (peaks, ratios).
    Gauge,
}

/// One registry row: a named scalar over [`Metrics`].
pub struct MetricDef {
    pub name: &'static str,
    pub kind: MetricKind,
    pub help: &'static str,
    get: fn(&Metrics) -> f64,
}

macro_rules! counters {
    ($(($name:ident, $help:expr)),* $(,)?) => {
        &[$(MetricDef {
            name: stringify!($name),
            kind: MetricKind::Counter,
            help: $help,
            get: |m: &Metrics| m.$name as f64,
        }),*]
    };
}

/// Counter rows (field name == metric name).
static COUNTERS: &[MetricDef] = counters![
    (requests_submitted, "Requests accepted by an engine"),
    (requests_completed, "Requests that reached a terminal response"),
    (requests_preempted, "Sequences preempted under memory pressure"),
    (requests_rejected, "Requests shed by admission control"),
    (requests_failed, "Requests answered with a terminal structured error"),
    (prompt_tokens, "Prompt tokens admitted"),
    (generated_tokens, "Tokens decoded"),
    (hsr_points_scanned, "Keys scanned by HSR traversals"),
    (hsr_nodes_visited, "HSR tree nodes visited by traversals"),
    (hsr_reported, "Keys reported (fired) by HSR traversals"),
    (attended_entries, "Attention entries actually computed"),
    (dense_equivalent_entries, "Entries dense attention would compute"),
    (calibration_fallbacks, "Top-r calibration fallbacks to dense scan"),
    (prefix_lookups, "Radix prefix-cache probes"),
    (prefix_hits, "Probes that adopted a cached chain"),
    (prefill_tokens_skipped, "Prompt tokens skipped via adopted prefixes"),
    (prefill_tokens_demanded, "Prompt tokens demanded of prefill"),
    (prefix_tokens_inserted, "Prompt tokens published as shared segments"),
    (prefix_segments_evicted, "Cached segments LRU-evicted"),
    (prefix_sheds, "Adopted chains shed by wedged sequences"),
    (grouped_decode_rows, "Decode rows answered in shared-prefix groups"),
    (segments_spilled, "Segments demoted to the compressed cold tier"),
    (segments_refaulted, "Cold segments promoted back on prefix match"),
    (spill_bytes, "Compressed bytes written to the spill store"),
    (dedup_hits, "Publishes deduplicated against resident segments"),
    (dedup_bytes_saved, "Payload bytes dedup hits did not duplicate"),
    (deadline_aborts, "Sequences aborted past their deadline"),
    (disconnect_aborts, "Sequences cancelled by client disconnect"),
    (worker_panics, "Worker threads that panicked"),
    (worker_restarts, "Panicked workers restarted in place"),
    (kv_blocks_leaked, "KV blocks unreturned after drain (0 when correct)"),
    (tokens_streamed, "Tokens accepted into stream sinks"),
    (streams_severed, "Streams truncated before a clean finish"),
    (slow_consumer_sheds, "Streams shed for slow consumers"),
    (affinity_hits, "Dispatches that followed the prefix-affinity sketch"),
    (affinity_fallbacks, "Sketch hints degraded to least-loaded"),
    (group_requests, "Grouped (sampling/beam) requests admitted"),
    (sequence_forks, "Mid-decode sequence forks"),
    (fork_shared_tokens, "KV tokens shared by forked siblings"),
    (fork_recompute_fallbacks, "Forks that fell back to recompute"),
    (beam_prunes, "Beam hypotheses pruned"),
];

/// Gauge rows (ratios and peaks; not monotone).
static GAUGES: &[MetricDef] = &[
    MetricDef {
        name: "queue_depth_peak",
        kind: MetricKind::Gauge,
        help: "Peak queued+running requests across the pool",
        get: |m| m.queue_depth_peak as f64,
    },
    MetricDef {
        name: "refault_rebuild_ms",
        kind: MetricKind::Gauge,
        help: "Milliseconds spent rebuilding refaulted segments",
        get: |m| m.refault_rebuild_ms,
    },
    MetricDef {
        name: "prefix_skip_rate",
        kind: MetricKind::Gauge,
        help: "Fraction of demanded prefill tokens skipped",
        get: |m| m.prefix_skip_rate(),
    },
    MetricDef {
        name: "prefix_hit_rate",
        kind: MetricKind::Gauge,
        help: "Fraction of radix lookups that hit",
        get: |m| m.prefix_hit_rate(),
    },
    MetricDef {
        name: "attended_fraction",
        kind: MetricKind::Gauge,
        help: "Attention entries computed vs dense equivalent",
        get: |m| m.attended_fraction(),
    },
    MetricDef {
        name: "dedup_hit_rate",
        kind: MetricKind::Gauge,
        help: "Segment publishes resolved by content dedup",
        get: |m| {
            ratio_or(
                m.dedup_hits as f64,
                (m.dedup_hits + m.prefix_tokens_inserted.min(u64::MAX)) as f64,
                0.0,
            )
        },
    },
];

/// The latency histograms exported alongside the scalars.
static HISTOGRAMS: &[(&str, fn(&Metrics) -> &Histogram)] = &[
    ("step_latency_ns", |m| &m.step_latency),
    ("request_latency_ns", |m| &m.request_latency),
    ("ttft_ns", |m| &m.ttft),
    ("ttft_wire_ns", |m| &m.ttft_wire),
];

/// Every scalar row, counters first (iteration order is the catalog
/// order the README documents).
pub fn registry() -> impl Iterator<Item = &'static MetricDef> {
    COUNTERS.iter().chain(GAUGES.iter())
}

/// Names of the counter rows (the monotone set scrape validators
/// check).
pub fn counter_names() -> Vec<&'static str> {
    COUNTERS.iter().map(|d| d.name).collect()
}

/// Value snapshot of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnap {
    pub name: &'static str,
    pub count: u64,
    pub sum_ns: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    /// `(upper_bound_ns, cumulative_count)`; the final bound is `None`
    /// (+Inf).
    pub buckets: Vec<(Option<u64>, u64)>,
}

impl HistSnap {
    fn of(name: &'static str, h: &Histogram) -> HistSnap {
        let mut cum = 0u64;
        let buckets = h
            .buckets()
            .map(|(bound, count)| {
                cum += count;
                (bound, cum)
            })
            .collect();
        HistSnap {
            name,
            count: h.count(),
            sum_ns: h.sum_ns(),
            mean_ns: h.mean_ns(),
            p50_ns: h.percentile_ns(50.0),
            p99_ns: h.percentile_ns(99.0),
            max_ns: h.max_ns(),
            buckets,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count.into())
            .set("sum_ns", self.sum_ns.into())
            .set("mean_ns", self.mean_ns.into())
            .set("p50_ns", self.p50_ns.into())
            .set("p99_ns", self.p99_ns.into())
            .set("max_ns", self.max_ns.into());
        // Only non-empty cumulative buckets; the full ladder is 28 rows
        // of mostly zeros.
        let arr: Vec<Json> = self
            .buckets
            .iter()
            .filter(|(_, cum)| *cum > 0)
            .map(|(bound, cum)| {
                let mut b = Json::obj();
                match bound {
                    Some(ns) => b.set("le_ns", (*ns).into()),
                    None => b.set("le_ns", "+Inf".into()),
                };
                b.set("count", (*cum).into());
                b
            })
            .collect();
        o.set("buckets", Json::Arr(arr));
        o
    }
}

/// A point-in-time copy of every exported value.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Microseconds on the shared engine clock when the snapshot was
    /// taken.
    pub ts_us: u64,
    /// `(name, kind, value)` in registry order.
    pub values: Vec<(&'static str, MetricKind, f64)>,
    pub histograms: Vec<HistSnap>,
    /// Fired-fraction histogram summary (per context-length bucket).
    pub fired_fraction: Json,
    pub fired_fraction_overall: f64,
    pub fired_fraction_count: u64,
}

impl Snapshot {
    /// Snapshot a merged [`Metrics`] value.
    pub fn of(m: &Metrics) -> Snapshot {
        Snapshot {
            ts_us: clock::now_us(),
            values: registry().map(|d| (d.name, d.kind, (d.get)(m))).collect(),
            histograms: HISTOGRAMS
                .iter()
                .map(|(name, get)| HistSnap::of(name, get(m)))
                .collect(),
            fired_fraction: m.fired_fraction.to_json(),
            fired_fraction_overall: m.fired_fraction.overall_fraction(),
            fired_fraction_count: m.fired_fraction.count(),
        }
    }

    /// Value of a named scalar row.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.iter().find(|(n, _, _)| *n == name).map(|(_, _, v)| *v)
    }

    /// JSON form: `{"ts_us":..,"counters":{..},"gauges":{..},
    /// "histograms":{..},"fired_fraction":[..]}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        let mut gauges = Json::obj();
        for (name, kind, v) in &self.values {
            match kind {
                MetricKind::Counter => counters.set(name, (*v).into()),
                MetricKind::Gauge => gauges.set(name, (*v).into()),
            };
        }
        let mut hists = Json::obj();
        for h in &self.histograms {
            hists.set(h.name, h.to_json());
        }
        let mut o = Json::obj();
        o.set("ts_us", self.ts_us.into())
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("fired_fraction", self.fired_fraction.clone())
            .set("fired_fraction_overall", self.fired_fraction_overall.into())
            .set("fired_fraction_count", self.fired_fraction_count.into());
        o
    }

    /// Prometheus-style text exposition: `# HELP`/`# TYPE` pairs per
    /// scalar, cumulative `_bucket{le=..}` ladders plus `_sum`/`_count`
    /// per histogram, all under the `hsr_` namespace.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for d in registry() {
            let v = self.get(d.name).unwrap_or(0.0);
            let kind = match d.kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
            };
            let _ = writeln!(out, "# HELP hsr_{} {}", d.name, d.help);
            let _ = writeln!(out, "# TYPE hsr_{} {}", d.name, kind);
            let _ = writeln!(out, "hsr_{} {}", d.name, fmt_value(v));
        }
        for h in &self.histograms {
            let _ = writeln!(out, "# TYPE hsr_{} histogram", h.name);
            for (bound, cum) in &h.buckets {
                match bound {
                    Some(ns) => {
                        let _ = writeln!(
                            out,
                            "hsr_{}_bucket{{le=\"{ns}\"}} {cum}",
                            h.name
                        );
                    }
                    None => {
                        let _ = writeln!(
                            out,
                            "hsr_{}_bucket{{le=\"+Inf\"}} {cum}",
                            h.name
                        );
                    }
                }
            }
            let _ = writeln!(out, "hsr_{}_sum {}", h.name, h.sum_ns);
            let _ = writeln!(out, "hsr_{}_count {}", h.name, h.count);
        }
        let _ = writeln!(out, "# TYPE hsr_fired_fraction_overall gauge");
        let _ = writeln!(
            out,
            "hsr_fired_fraction_overall {}",
            fmt_value(self.fired_fraction_overall)
        );
        out
    }

    /// One compact stderr line for the `--metrics-interval` reporter:
    /// absolute totals plus per-second rates against `prev`.
    pub fn delta_line(&self, prev: Option<&Snapshot>) -> String {
        let get = |name: &str| self.get(name).unwrap_or(0.0);
        let mut line = format!(
            "metrics ts_us={} completed={} generated={} rejected={} \
             panics={} attended={:.2}%",
            self.ts_us,
            get("requests_completed") as u64,
            get("generated_tokens") as u64,
            get("requests_rejected") as u64,
            get("worker_panics") as u64,
            100.0 * get("attended_fraction"),
        );
        if let Some(p) = prev {
            let dt_s = (self.ts_us.saturating_sub(p.ts_us)) as f64 / 1e6;
            let rate = |name: &str| {
                ratio_or(get(name) - p.get(name).unwrap_or(0.0), dt_s, 0.0)
            };
            let _ = write!(
                line,
                " tok_per_s={:.1} req_per_s={:.2}",
                rate("generated_tokens"),
                rate("requests_completed"),
            );
        }
        line
    }
}

/// Plain decimal rendering (Prometheus has no use for `1e6` noise on
/// integral counters).
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_metrics() -> Metrics {
        let mut m = Metrics::default();
        m.requests_submitted = 5;
        m.requests_completed = 4;
        m.generated_tokens = 128;
        m.attended_entries = 25;
        m.dense_equivalent_entries = 100;
        m.prefill_tokens_demanded = 200;
        m.prefill_tokens_skipped = 50;
        m.step_latency.record_ns(2_000_000);
        m.step_latency.record_ns(4_000_000);
        m.fired_fraction.record(1024, 128, 1024);
        m
    }

    #[test]
    fn snapshot_json_has_catalog_and_histograms() {
        let snap = Snapshot::of(&sample_metrics());
        let js = snap.to_json();
        let counters = js.get("counters").unwrap();
        for name in counter_names() {
            assert!(counters.get(name).is_some(), "missing counter {name}");
        }
        assert_eq!(counters.req_usize("generated_tokens").unwrap(), 128);
        let gauges = js.get("gauges").unwrap();
        assert!((gauges.req_f64("attended_fraction").unwrap() - 0.25).abs() < 1e-12);
        assert!((gauges.req_f64("prefix_skip_rate").unwrap() - 0.25).abs() < 1e-12);
        let hists = js.get("histograms").unwrap();
        let step = hists.get("step_latency_ns").unwrap();
        assert_eq!(step.req_usize("count").unwrap(), 2);
        assert!(step.req_f64("mean_ns").unwrap() > 0.0);
        let ff = js.get("fired_fraction").unwrap().as_arr().unwrap();
        assert_eq!(ff.len(), 1);
        assert_eq!(ff[0].req_usize("ctx_log2").unwrap(), 10);
        // The whole snapshot survives a JSON round trip.
        let rt = Json::parse(&js.to_string()).unwrap();
        assert_eq!(rt, js);
    }

    #[test]
    fn empty_engine_snapshot_is_finite() {
        // Satellite: zero-denominator guards — a snapshot of a fresh
        // engine must emit finite numbers everywhere, never NaN/inf.
        let snap = Snapshot::of(&Metrics::default());
        for (name, _, v) in &snap.values {
            assert!(v.is_finite(), "{name} must be finite on empty metrics");
        }
        assert_eq!(snap.get("prefix_skip_rate"), Some(0.0));
        assert_eq!(snap.get("prefix_hit_rate"), Some(0.0));
        assert_eq!(snap.get("attended_fraction"), Some(1.0));
        assert_eq!(snap.get("dedup_hit_rate"), Some(0.0));
        assert!(snap.fired_fraction_overall.is_finite());
        let text = snap.to_prometheus();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
    }

    #[test]
    fn prometheus_exposition_shape() {
        let snap = Snapshot::of(&sample_metrics());
        let text = snap.to_prometheus();
        assert!(text.contains("# TYPE hsr_requests_completed counter"));
        assert!(text.contains("hsr_requests_completed 4"));
        assert!(text.contains("# TYPE hsr_queue_depth_peak gauge"));
        assert!(text.contains("# TYPE hsr_step_latency_ns histogram"));
        assert!(text.contains("hsr_step_latency_ns_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("hsr_step_latency_ns_count 2"));
        // Cumulative ladder is non-decreasing.
        let step = &snap.histograms[0];
        assert!(step.buckets.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(step.buckets.last().unwrap().1, 2);
    }

    #[test]
    fn delta_line_reports_rates() {
        let prev = Snapshot::of(&sample_metrics());
        let mut m2 = sample_metrics();
        m2.generated_tokens += 100;
        let mut cur = Snapshot::of(&m2);
        cur.ts_us = prev.ts_us + 2_000_000; // +2s
        let line = cur.delta_line(Some(&prev));
        assert!(line.starts_with("metrics ts_us="), "{line}");
        assert!(line.contains("tok_per_s=50.0"), "{line}");
        // Without a previous snapshot: totals only, no rates.
        assert!(!cur.delta_line(None).contains("tok_per_s"));
    }
}
