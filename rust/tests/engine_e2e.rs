//! End-to-end engine tests: continuous batching over the trained model,
//! sparse-vs-dense consistency, preemption under cache pressure, and
//! failure injection.

use hsr_attn::engine::serving::Engine;
use hsr_attn::engine::{
    EngineConfig, FinishReason, GenerationParams, PreemptPolicy, SchedulerConfig,
};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn mini() -> Arc<Model> {
    Arc::new(Model::load_named(&artifacts_dir(), "mini").expect("model"))
}

fn prompt(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

#[test]
fn single_request_greedy_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let model = mini();
    let run = |policy| {
        let mut eng = Engine::new(
            model.clone(),
            EngineConfig { policy, ..Default::default() },
        );
        eng.submit(
            prompt("the merchant carries "),
            GenerationParams { max_new_tokens: 24, ..Default::default() },
        );
        eng.run_to_completion();
        let mut done = eng.take_finished();
        assert_eq!(done.len(), 1);
        let r = done.pop().unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 24);
        r.tokens
    };
    let a = run(AttentionPolicy::Dense);
    let b = run(AttentionPolicy::Dense);
    assert_eq!(a, b, "greedy decoding must be deterministic");
    assert!(a.iter().all(|&t| t < 256));
}

#[test]
fn sparse_policy_matches_dense_when_r_covers_cache() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let gen = |policy| {
        let mut eng = Engine::new(model.clone(), EngineConfig { policy, ..Default::default() });
        eng.submit(
            prompt("remember: alder keeps the "),
            GenerationParams { max_new_tokens: 16, ..Default::default() },
        );
        eng.run_to_completion();
        eng.take_finished().pop().unwrap().tokens
    };
    let dense = gen(AttentionPolicy::Dense);
    let covering = gen(AttentionPolicy::TopR(RSpec::Fixed(1 << 20)));
    assert_eq!(dense, covering, "covering top-r must equal dense");
}

#[test]
fn sparse_topr_paper_spec_generates_and_accounts() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let mut eng = Engine::new(
        model,
        EngineConfig {
            policy: AttentionPolicy::TopR(RSpec::paper()),
            hsr_backend: Some(HsrBackend::BallTree),
            ..Default::default()
        },
    );
    eng.submit(
        prompt("the gardener sells dried herbs "),
        GenerationParams { max_new_tokens: 32, ..Default::default() },
    );
    eng.run_to_completion();
    let r = eng.take_finished().pop().unwrap();
    assert_eq!(r.tokens.len(), 32);
    assert!(eng.metrics.attended_entries > 0);
    assert!(eng.metrics.attended_entries <= eng.metrics.dense_equivalent_entries);
}

#[test]
fn batch_of_requests_all_complete() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let mut eng = Engine::new(model, EngineConfig::default());
    let texts = [
        "a courier guards sealed letters ",
        "the archivist studies star charts ",
        "our captain repairs oak barrels ",
        "that piper paints silk banners ",
        "the warden hides iron keys ",
    ];
    let mut ids = Vec::new();
    for t in texts {
        ids.push(eng.submit(
            prompt(t),
            GenerationParams { max_new_tokens: 12, ..Default::default() },
        ));
    }
    eng.run_to_completion();
    let done = eng.take_finished();
    assert_eq!(done.len(), texts.len());
    let mut got: Vec<u64> = done.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    assert_eq!(eng.metrics.requests_completed, texts.len() as u64);
}

#[test]
fn preemption_under_cache_pressure_still_completes() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    // Tiny pool: forces preemption with several concurrent sequences.
    let mut eng = Engine::new(
        model,
        EngineConfig {
            cache_capacity_tokens: 256,
            block_tokens: 16,
            scheduler: SchedulerConfig {
                max_batch: 4,
                prefill_chunk: 16,
                step_token_budget: 64,
                preempt: PreemptPolicy::Youngest,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    for i in 0..4 {
        eng.submit(
            prompt(&format!(
                "request number {i} with a moderately long prompt text here "
            )),
            GenerationParams { max_new_tokens: 40, ..Default::default() },
        );
    }
    eng.run_to_completion();
    let done = eng.take_finished();
    assert_eq!(done.len(), 4, "all requests must complete despite preemption");
    for r in &done {
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 40);
    }
    assert!(
        eng.metrics.requests_preempted > 0,
        "expected preemption under a 256-token pool"
    );
}

#[test]
fn oversized_request_is_aborted_not_deadlocked() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let mut eng = Engine::new(
        model,
        EngineConfig { cache_capacity_tokens: 64, block_tokens: 16, ..Default::default() },
    );
    eng.submit(
        prompt(&"x".repeat(100)),
        GenerationParams { max_new_tokens: 8, ..Default::default() },
    );
    eng.run_to_completion(); // must not hang
    let done = eng.take_finished();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish, FinishReason::Aborted);
}

#[test]
fn stop_token_halts_generation() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let mut eng = Engine::new(model, EngineConfig::default());
    eng.submit(
        prompt("the mason forges wax seals by the "),
        GenerationParams {
            max_new_tokens: 200,
            temperature: 0.0,
            stop_token: Some(b'.' as u32),
            deadline: None,
            ..Default::default()
        },
    );
    eng.run_to_completion();
    let r = eng.take_finished().pop().unwrap();
    if r.finish == FinishReason::StopToken {
        assert_eq!(*r.tokens.last().unwrap(), b'.' as u32);
        assert!(r.tokens.len() < 200);
    } else {
        assert_eq!(r.tokens.len(), 200);
    }
}

#[test]
fn router_distributes_across_workers() {
    if !have_artifacts() {
        return;
    }
    let model = mini();
    let router = hsr_attn::engine::Router::new(model, EngineConfig::default(), 3);
    for i in 0..9 {
        router
            .submit(
                prompt(&format!("parallel request {i} ")),
                GenerationParams { max_new_tokens: 8, ..Default::default() },
            )
            .expect("router accepts within default caps");
    }
    router.wait_idle();
    let responses = router.take_responses();
    assert_eq!(responses.len(), 9);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 9, "request ids must be globally unique");
    let metrics = router.shutdown();
    assert_eq!(metrics.requests_completed, 9);
}
