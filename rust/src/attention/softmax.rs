//! Softmax attention (Definition 1.1) and its index-set restriction
//! (Definitions B.1/B.2 — "top-r nearest-neighbors Softmax attention").
//!
//! The dense path is the O(mn) naive baseline of Theorems 4.2/5.2; the
//! index-set path computes `Softmax(q Ĥ)V̂` over only the selected rows,
//! which is what Algorithm 1/2 evaluate after the HSR report.
//! All softmaxes are computed in the numerically stable max-subtracted
//! form; restricted and dense paths therefore agree exactly on full index
//! sets (tested below).

use super::{axpy_row, scores_into, scores_subset_into};

/// Dense softmax attention for a single query row: out = Softmax(qK^T/√d)V.
/// `out` must be zeroed, length d.
pub fn softmax_attention_row(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores_into(q, keys, d, scores_buf);
    softmax_weighted_sum(scores_buf, None, values, d, out);
}

/// Dense softmax attention for a single query row over **segmented**
/// K/V storage (a shared-prefix chain plus a private tail): `parts` are
/// `(keys, values)` row-major `[len, d]` pairs in global key order.
/// Scores are computed per part into one contiguous buffer (each row's
/// dot is the same kernel call either way), then a single fused softmax
/// and one ascending-order accumulation run over the concatenation —
/// float-for-float the computation [`softmax_attention_row`] performs on
/// the concatenated rows, which is what keeps shared-prefix dense decode
/// bit-identical to unshared decode.
pub fn softmax_attention_row_segmented(
    q: &[f32],
    parts: &[(&[f32], &[f32])],
    d: usize,
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    let n: usize = parts.iter().map(|(k, _)| k.len() / d).sum();
    let buf = crate::attention::sized_scores(scores_buf, n);
    let mut at = 0usize;
    for (keys, _) in parts {
        let len = keys.len() / d;
        crate::kernel::simd::scaled_dots_into(
            q,
            keys,
            d,
            1.0 / (d as f32).sqrt(),
            &mut buf[at..at + len],
        );
        at += len;
    }
    out.fill(0.0);
    if buf.is_empty() {
        return;
    }
    let denom = crate::kernel::simd::softmax_exp_in_place(buf);
    if denom == 0.0 || !denom.is_finite() {
        return;
    }
    let inv = 1.0 / denom;
    let mut at = 0usize;
    for (_, values) in parts {
        let len = values.len() / d;
        for t in 0..len {
            let e = buf[at + t];
            crate::kernel::simd::axpy(out, &values[t * d..(t + 1) * d], e * inv);
        }
        at += len;
    }
}

/// Softmax attention restricted to `idx` (Definition B.2):
/// out = Softmax(q K̂^T/√d) V̂ where K̂, V̂ are the selected rows.
pub fn softmax_attention_row_subset(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    idx: &[u32],
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores_subset_into(q, keys, d, idx, scores_buf);
    softmax_weighted_sum(scores_buf, Some(idx), values, d, out);
}

/// Shared stable-softmax weighted sum. When `idx` is None the weights map
/// to value rows 0..n; otherwise to the given indices. `scores` is
/// consumed: the fused max/sum-exp kernel rewrites it in place to
/// exp(s − max), so the accumulation pass reads cached exps instead of
/// recomputing them (the pre-kernel version paid a second exp pass).
fn softmax_weighted_sum(
    scores: &mut [f32],
    idx: Option<&[u32]>,
    values: &[f32],
    d: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    if scores.is_empty() {
        return;
    }
    let denom = crate::kernel::simd::softmax_exp_in_place(scores);
    if denom == 0.0 || !denom.is_finite() {
        return;
    }
    let inv = 1.0 / denom;
    for (t, &e) in scores.iter().enumerate() {
        let row = match idx {
            Some(ix) => ix[t] as usize,
            None => t,
        };
        axpy_row(out, values, d, row, e * inv);
    }
}

/// Softmax attention over an index set whose **scaled scores are already
/// known** (e.g. carried out of a score-reporting HSR query): no inner
/// product is recomputed. `scaled_scores[t]` must be `<q, K_{idx_t}>/√d`;
/// the buffer is consumed (rewritten to exps in place).
pub fn softmax_attention_row_scored(
    idx: &[u32],
    scaled_scores: &mut [f32],
    values: &[f32],
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(idx.len(), scaled_scores.len());
    softmax_weighted_sum(scaled_scores, Some(idx), values, d, out);
}

/// Dense softmax attention for a full Q (m×d): the naive O(mnd) baseline.
pub fn softmax_attention(q: &[f32], keys: &[f32], values: &[f32], d: usize) -> Vec<f32> {
    let m = q.len() / d;
    let mut out = vec![0f32; m * d];
    let mut buf = Vec::new();
    for i in 0..m {
        softmax_attention_row(
            &q[i * d..(i + 1) * d],
            keys,
            values,
            d,
            &mut buf,
            &mut out[i * d..(i + 1) * d],
        );
    }
    out
}

/// Softmax probabilities of a score row (stable). Used by the model's
/// sampling head and by tests. The max-subtract/exp/sum runs through the
/// fused [`crate::kernel::simd::softmax_exp_in_place`] kernel — a single
/// vectorized pass instead of the old scalar exp-collect + sum.
pub fn softmax(scores: &[f32]) -> Vec<f32> {
    if scores.is_empty() {
        return Vec::new();
    }
    let mut exps = scores.to_vec();
    let denom = crate::kernel::simd::softmax_exp_in_place(&mut exps);
    if denom > 0.0 && denom.is_finite() {
        let inv = 1.0 / denom;
        for e in exps.iter_mut() {
            *e *= inv;
        }
    }
    exps
}

/// log(Σ exp(scores)) computed stably; the building block for perplexity.
/// Shares the single-pass vectorized exp with the softmax kernels (the
/// non-storing [`crate::kernel::simd::exp_sum`] twin — no allocation).
pub fn log_sum_exp(scores: &[f32]) -> f32 {
    if scores.is_empty() {
        return f32::NEG_INFINITY;
    }
    let max = crate::kernel::simd::max(scores);
    if !max.is_finite() {
        return max;
    }
    max + crate::kernel::simd::exp_sum(scores, max).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::linf;
    use crate::util::rng::Rng;

    fn rand_qkv(rng: &mut Rng, m: usize, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            rng.gaussian_vec_f32(m * d, 1.0),
            rng.gaussian_vec_f32(n * d, 1.0),
            rng.gaussian_vec_f32(n * d, 1.0),
        )
    }

    #[test]
    fn weights_sum_to_one() {
        let p = softmax(&[0.1, 2.0, -3.0, 0.7]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[101.0, 102.0, 103.0]);
        assert!(linf(&a, &b) < 1e-6);
    }

    #[test]
    fn full_subset_equals_dense() {
        let mut rng = Rng::new(8);
        let (m, n, d) = (3usize, 40usize, 8usize);
        let (q, k, v) = rand_qkv(&mut rng, m, n, d);
        let dense = softmax_attention(&q, &k, &v, d);
        let idx: Vec<u32> = (0..n as u32).collect();
        let mut buf = Vec::new();
        for i in 0..m {
            let mut out = vec![0f32; d];
            softmax_attention_row_subset(&q[i * d..(i + 1) * d], &k, &v, d, &idx, &mut buf, &mut out);
            assert!(linf(&out, &dense[i * d..(i + 1) * d]) < 1e-5);
        }
    }

    #[test]
    fn subset_is_permutation_invariant() {
        let mut rng = Rng::new(9);
        let (_, n, d) = (1usize, 30usize, 4usize);
        let (q, k, v) = rand_qkv(&mut rng, 1, n, d);
        let mut idx: Vec<u32> = (0..n as u32).step_by(3).collect();
        let mut buf = Vec::new();
        let mut out1 = vec![0f32; d];
        softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut out1);
        idx.reverse();
        let mut out2 = vec![0f32; d];
        softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut out2);
        assert!(linf(&out1, &out2) < 1e-5);
    }

    #[test]
    fn scored_path_matches_subset_path() {
        let mut rng = Rng::new(10);
        let (n, d) = (60usize, 8usize);
        let (q, k, v) = rand_qkv(&mut rng, 1, n, d);
        let idx: Vec<u32> = (0..n as u32).step_by(4).collect();
        let mut buf = Vec::new();
        let mut want = vec![0f32; d];
        softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut want);
        // Pre-compute the scaled scores, then use the scored entry point.
        let mut scores = Vec::new();
        crate::attention::scores_subset_into(&q, &k, d, &idx, &mut scores);
        let mut got = vec![0f32; d];
        softmax_attention_row_scored(&idx, &mut scores, &v, d, &mut got);
        assert!(linf(&got, &want) < 1e-6);
    }

    #[test]
    fn single_key_attends_fully() {
        let q = [1.0f32, 0.0];
        let k = [5.0f32, 5.0];
        let v = [7.0f32, -3.0];
        let mut buf = Vec::new();
        let mut out = vec![0f32; 2];
        softmax_attention_row(&q, &k, &v, 2, &mut buf, &mut out);
        assert!(linf(&out, &v) < 1e-6);
    }

    #[test]
    fn empty_index_set_gives_zero() {
        let q = [1.0f32, 0.0];
        let k = [5.0f32, 5.0];
        let v = [7.0f32, -3.0];
        let mut buf = Vec::new();
        let mut out = vec![1f32; 2];
        softmax_attention_row_subset(&q, &k, &v, 2, &[], &mut buf, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn extreme_scores_are_stable() {
        // Large-magnitude q/k would overflow naive exp.
        let q = [100.0f32, 100.0];
        let k = [100.0f32, 100.0, -100.0, -100.0];
        let v = [1.0f32, 0.0, 0.0, 1.0];
        let mut buf = Vec::new();
        let mut out = vec![0f32; 2];
        softmax_attention_row(&q, &k, &v, 2, &mut buf, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        assert!((out[0] - 1.0).abs() < 1e-6); // all mass on key 0
    }

    #[test]
    fn log_sum_exp_matches_naive_in_safe_range() {
        let s = [0.3f32, -1.2, 2.0];
        let naive = (s.iter().map(|&x| x.exp()).sum::<f32>()).ln();
        assert!((log_sum_exp(&s) - naive).abs() < 1e-5);
        assert_eq!(log_sum_exp(&[]), f32::NEG_INFINITY);
    }
}
