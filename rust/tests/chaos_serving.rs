//! Robustness tests for the fault-tolerant serving front-end: deadline
//! aborts, cancellation, salvage partitioning, supervised worker
//! restarts, per-token streaming under mid-stream faults (panic, client
//! disconnect, slow consumer), prefix-affinity routing degradation, and
//! a full chaos run (injected panics + early client disconnects +
//! overload through the TCP server).
//!
//! Everything here runs on the synthetic model — no artifacts needed.

use hsr_attn::engine::serving::Engine;
use hsr_attn::engine::{
    EngineConfig, Fault, FaultKind, FaultPlan, FinishReason, GenerationParams,
    Outcome, Router, RouterConfig, SchedulerConfig, StreamRecv,
};
use hsr_attn::model::Model;
use hsr_attn::obs::TraceConfig;
use hsr_attn::server::{Client, Server, ServerConfig, StreamFrame, WireRequest};
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn model() -> Arc<Model> {
    Arc::new(Model::synthetic(90, 2, 4, 8))
}

fn prompt(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

fn params(gen: usize) -> GenerationParams {
    GenerationParams { max_new_tokens: gen, ..Default::default() }
}

/// Run `f` on a helper thread and fail loudly if it exceeds `secs` —
/// a hang here means a lost terminal outcome, which is exactly the bug
/// class this suite guards against.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().expect("test body panicked"),
        Err(_) => panic!("watchdog: test exceeded {secs}s — probable lost outcome / deadlock"),
    }
}

#[test]
fn fault_plan_filters_and_fires() {
    let plan = FaultPlan::none()
        .with(Fault { worker: 0, step: 3, kind: FaultKind::Panic })
        .with(Fault { worker: 1, step: 2, kind: FaultKind::Delay { ms: 1 } })
        .with(Fault { worker: 0, step: 5, kind: FaultKind::Stall { ms: 1 } });
    assert!(FaultPlan::none().is_empty());
    assert!(!plan.is_empty());
    assert!(plan.for_worker(2).is_empty());

    let w0 = plan.for_worker(0);
    // Panic fires at its exact step only.
    assert_eq!(w0.fire_at(2), None);
    assert_eq!(w0.fire_at(3), Some(FaultKind::Panic));
    assert_eq!(w0.fire_at(4), None);
    // Stall fires at its step and every later one.
    assert_eq!(w0.fire_at(5), Some(FaultKind::Stall { ms: 1 }));
    assert_eq!(w0.fire_at(99), Some(FaultKind::Stall { ms: 1 }));

    let w1 = plan.for_worker(1);
    assert_eq!(w1.fire_at(2), Some(FaultKind::Delay { ms: 1 }));
    assert_eq!(w1.fire_at(3), None);
}

#[test]
fn expired_deadline_aborts_and_releases_blocks() {
    let mut eng = Engine::new(model(), EngineConfig::default());
    let mut p = params(32);
    p.deadline = Some(Instant::now()); // already expired
    eng.submit(prompt("the merchant carries copper coins "), p);
    eng.submit(prompt("a courier guards sealed letters "), params(4));
    eng.run_to_completion();
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 2);
    done.sort_by_key(|r| r.id);
    assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
    assert_eq!(done[1].finish, FinishReason::Length);
    assert_eq!(eng.metrics.deadline_aborts, 1);
    assert_eq!(eng.reclaim_and_count_leaks(), 0, "deadline abort leaked KV blocks");
}

#[test]
fn mid_decode_deadline_aborts_a_running_sequence() {
    let mut eng = Engine::new(model(), EngineConfig::default());
    // A token budget no 30ms window can exhaust: the deadline must win.
    let mut p = params(1_000_000);
    p.deadline = Some(Instant::now() + Duration::from_millis(30));
    eng.submit(prompt("slow request that cannot finish in time "), p);
    // Step until the deadline sweep fires; generous cap so a genuinely
    // hung abort fails the assert rather than looping forever.
    let mut steps = 0;
    while eng.has_work() && steps < 200_000 {
        eng.step();
        steps += 1;
    }
    assert!(!eng.has_work(), "deadline abort never fired");
    let done = eng.take_finished();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
    assert!(done[0].tokens.len() < 1_000_000);
    assert_eq!(eng.metrics.deadline_aborts, 1);
    assert_eq!(eng.reclaim_and_count_leaks(), 0);
}

#[test]
fn cancel_waiting_and_running_releases_blocks() {
    let mut eng = Engine::new(model(), EngineConfig::default());
    // Cancel while still waiting (no step yet).
    let waiting_id = eng.submit(prompt("queued request "), params(8));
    assert!(eng.cancel(waiting_id));
    // Cancel mid-decode.
    let running_id = eng.submit(prompt("running request to cancel "), params(1_000));
    for _ in 0..5 {
        eng.step();
    }
    assert!(eng.cancel(running_id));
    assert!(!eng.cancel(running_id), "double cancel must be a no-op");
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 2);
    done.sort_by_key(|r| r.id);
    assert!(done.iter().all(|r| r.finish == FinishReason::Cancelled));
    assert_eq!(eng.metrics.disconnect_aborts, 2);
    assert_eq!(eng.reclaim_and_count_leaks(), 0, "cancel leaked KV blocks");
}

#[test]
fn salvage_partitions_fresh_from_progressed() {
    // Never-stepped request: safe to retry on a survivor.
    let mut eng = Engine::new(model(), EngineConfig::default());
    eng.submit(prompt("fresh request "), params(8));
    let (retry, dead) = eng.salvage();
    assert_eq!((retry.len(), dead.len()), (1, 0));
    assert_eq!(retry[0].prompt, prompt("fresh request "));
    assert!(!eng.has_work(), "salvage must drain the engine");

    // Request with visible progress: a replay could not reproduce it.
    let mut eng = Engine::new(model(), EngineConfig::default());
    eng.submit(prompt("progressed "), params(64));
    for _ in 0..20 {
        eng.step();
    }
    let (retry, dead) = eng.salvage();
    assert_eq!((retry.len(), dead.len()), (0, 1));
    // The dead entry carries its emitted-token count — the truncation
    // point a streaming client is told about.
    assert!(dead[0].1 >= 1, "progressed request must report emitted tokens");
}

#[test]
fn engine_rejects_above_max_waiting() {
    let mut eng = Engine::new(
        model(),
        EngineConfig {
            scheduler: SchedulerConfig { max_waiting: 2, ..Default::default() },
            ..Default::default()
        },
    );
    use hsr_attn::engine::Request;
    for i in 0..2 {
        let req =
            Request { id: i, prompt: prompt("q "), params: params(4), attempts: 0, stream: None };
        assert!(eng.submit_request(req).is_ok());
    }
    let req = Request { id: 9, prompt: prompt("q "), params: params(4), attempts: 0, stream: None };
    let back = eng.submit_request(req).expect_err("queue is full");
    assert_eq!(back.id, 9, "rejected request comes back intact");
    eng.run_to_completion();
    assert_eq!(eng.take_finished().len(), 2);
}

#[test]
fn router_restarts_panicked_worker_and_answers_everything() {
    with_watchdog(60, || {
        let cfg = EngineConfig {
            faults: FaultPlan::none()
                .with(Fault { worker: 0, step: 3, kind: FaultKind::Panic }),
            ..Default::default()
        };
        let router = Router::new(model(), cfg, 2);
        for i in 0..8 {
            router
                .submit(prompt(&format!("supervised request {i} ")), params(8))
                .expect("default caps fit 8 requests");
        }
        router.wait_idle();
        let responses = router.take_responses();
        let failures = router.take_failures();
        assert_eq!(
            responses.len() + failures.len(),
            8,
            "every accepted request needs exactly one terminal outcome"
        );
        for f in &failures {
            assert_eq!(f.code, "worker_failed");
        }
        assert_eq!(router.alive_workers(), 2, "panicked worker must restart");
        let m = router.shutdown();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.worker_restarts, 1);
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

/// The acceptance chaos run: 4 workers with panics injected on two of
/// them, a burst 4x past admission capacity, ~30% of clients
/// disconnecting without reading, and a few zero-deadline requests —
/// every request must reach exactly one terminal outcome, the server
/// must answer after recovery, and the block ledger must balance.
/// Tracing rides along: `{"cmd":"stats"}` scrapes must return valid
/// snapshots mid-chaos, and both panics must leave non-empty
/// flight-recorder dumps under the trace dir.
#[test]
fn chaos_panics_disconnects_and_overload() {
    with_watchdog(180, || {
        let trace_dir = std::env::temp_dir()
            .join(format!("hsr_chaos_trace_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&trace_dir);
        let cfg = EngineConfig {
            cache_capacity_tokens: 1 << 14,
            block_tokens: 16,
            scheduler: SchedulerConfig {
                max_batch: 4,
                prefill_chunk: 16,
                step_token_budget: 64,
                ..Default::default()
            },
            faults: FaultPlan::none()
                .with(Fault { worker: 1, step: 12, kind: FaultKind::Panic })
                .with(Fault { worker: 2, step: 20, kind: FaultKind::Panic }),
            trace: TraceConfig {
                trace_dir: Some(trace_dir.clone()),
                ..Default::default()
            },
            ..Default::default()
        };
        let rcfg = RouterConfig {
            max_queue_per_worker: 4,
            max_in_flight: 12,
            ..Default::default()
        };
        let router = Arc::new(Router::with_config(model(), cfg, 4, rcfg));

        // Deterministic deadline abort: expired before it ever decodes.
        let expired = {
            let mut p = params(8);
            p.deadline = Some(Instant::now());
            p
        };
        router.submit(prompt("expired immediately "), expired).expect("empty pool accepts");

        // Phase 1 — overload burst straight at the router: 48 back-to-back
        // submissions against a 12-request in-flight cap must shed load.
        // Requests are heavy enough (long prompt, 64 tokens) that workers
        // cannot drain the pool within the microseconds the loop takes.
        let (mut burst_ok, mut burst_shed) = (0usize, 0usize);
        for i in 0..48 {
            let p = format!("burst request number {i} with a long prompt ").repeat(4);
            match router.submit(prompt(&p), params(64)) {
                Ok(_) => burst_ok += 1,
                Err(_) => burst_shed += 1,
            }
        }
        assert!(burst_ok >= 1, "an unloaded pool must accept work");
        assert!(burst_shed >= 1, "48 instant submissions vs cap 12 must shed");
        router.wait_idle();

        // Phase 2 — chaos through the TCP front-end.
        let scfg = ServerConfig {
            drain: Duration::from_secs(2),
            // A lost terminal outcome surfaces as a "timeout" error line
            // well inside the watchdog window instead of a 120s stall.
            request_timeout: Duration::from_secs(20),
            ..Default::default()
        };
        let server = Server::bind_with(router.clone(), "127.0.0.1:0", scfg).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_handle();
        let srv = std::thread::spawn(move || server.serve());

        let mut clients = Vec::new();
        for i in 0..24usize {
            let addr = addr.clone();
            clients.push(std::thread::spawn(move || {
                // (ok_replies, err_replies, io_errors, deliberate_disconnects)
                let mut tally = (0usize, 0usize, 0usize, 0usize);
                if i % 3 == 0 {
                    // Disconnector: send one request, never read the reply.
                    if let Ok(mut s) = TcpStream::connect(&addr) {
                        let line = hsr_attn::server::render_request(&WireRequest {
                            prompt: format!("disconnector {i} "),
                            max_new_tokens: 64,
                            ..Default::default()
                        });
                        let _ = s.write_all(line.as_bytes());
                        let _ = s.write_all(b"\n");
                        let _ = s.flush();
                    }
                    tally.3 = 1;
                    return tally;
                }
                let Ok(mut c) = Client::connect(&addr) else {
                    tally.2 = 2;
                    return tally;
                };
                for j in 0..2usize {
                    let req = WireRequest {
                        prompt: format!("chaos client {i} request {j} "),
                        max_new_tokens: 8,
                        // A few requests expire instantly: "deadline" finish.
                        deadline_ms: (i % 5 == 1 && j == 1).then_some(0),
                        ..Default::default()
                    };
                    match c.request(&req) {
                        Ok(v) if v.get("finish").is_some() => tally.0 += 1,
                        Ok(_) => tally.1 += 1, // structured error line
                        Err(_) => tally.2 += 1,
                    }
                }
                tally
            }));
        }
        // Mid-chaos scraper: the `{"cmd":"stats"}` admin surface must
        // keep returning valid snapshots while panics, sheds, and
        // disconnects are in flight — connection failures are tolerated
        // (the pool is deliberately overloaded), protocol errors and
        // panics are not.
        let scraper = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut scrapes = 0usize;
                for k in 0..12 {
                    if let Ok(mut c) = Client::connect(&addr) {
                        if let Ok(v) = c.stats() {
                            for key in ["ts_us", "counters", "gauges", "histograms"] {
                                assert!(
                                    v.get(key).is_some(),
                                    "mid-chaos stats snapshot missing '{key}'"
                                );
                            }
                            scrapes += 1;
                        }
                        if k % 3 == 0 {
                            if let Ok(text) = c.stats_prometheus() {
                                assert!(
                                    text.contains("hsr_"),
                                    "prometheus exposition empty mid-chaos"
                                );
                            }
                        }
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                scrapes
            })
        };
        let mut ok = 0;
        let mut err = 0;
        let mut io_err = 0;
        let mut disconnects = 0;
        for c in clients {
            let (o, e, x, d) = c.join().expect("client thread");
            ok += o;
            err += e;
            io_err += x;
            disconnects += d;
        }
        assert_eq!(disconnects, 8);
        assert_eq!(
            ok + err + io_err,
            32,
            "every sent request needs exactly one wire-level resolution"
        );
        assert!(ok >= 1, "some requests must actually complete");
        let scrapes = scraper.join().expect("stats scraper thread");
        assert!(scrapes >= 1, "no stats scrape succeeded during the chaos run");

        // Phase 3 — the pool must still answer after both panics.
        let mut recovered = false;
        for _ in 0..100 {
            if let Ok(mut c) = Client::connect(&addr) {
                if let Ok(v) = c.generate("post recovery probe ", 4) {
                    if v.get("finish").is_some() {
                        recovered = true;
                        break;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(recovered, "server unresponsive after worker recovery");
        assert_eq!(router.alive_workers(), 4, "both panicked workers must restart");

        // Drain: every accepted request (including cancelled disconnector
        // requests) must reach its terminal outcome.
        router.wait_idle();
        let (done, submitted) = router.progress();
        assert_eq!(done, submitted, "accepted vs terminal outcomes mismatch");

        stop.store(true, Ordering::Relaxed);
        srv.join().expect("server thread").expect("serve exits cleanly");
        let router = Arc::try_unwrap(router)
            .ok()
            .expect("server must have released its router handles");
        let m = router.shutdown_within(Duration::from_secs(10));
        assert_eq!(m.worker_panics, 2, "both injected faults fire exactly once");
        assert_eq!(m.worker_restarts, 2);
        assert_eq!(m.kv_blocks_leaked, 0, "chaos run leaked KV blocks");
        assert!(m.requests_rejected >= burst_shed as u64);
        assert!(m.deadline_aborts >= 1, "the pre-expired request must abort");
        assert!(m.requests_completed >= ok as u64);

        // Both panicked workers (1 and 2, which each ran 12+ engine
        // steps before the fault fired) must have left a parseable,
        // non-empty flight-recorder dump.
        for widx in [1usize, 2] {
            let dump = trace_dir.join(format!("panic_worker{widx}.jsonl"));
            let data = std::fs::read_to_string(&dump).unwrap_or_else(|e| {
                panic!("missing flight-recorder dump {}: {e}", dump.display())
            });
            assert!(
                data.lines().count() >= 1,
                "flight-recorder dump {} is empty",
                dump.display()
            );
            for line in data.lines() {
                let v = hsr_attn::util::json::Json::parse(line)
                    .unwrap_or_else(|e| panic!("dump line not JSON ({e}): {line:?}"));
                assert!(v.get("ts_us").is_some() && v.get("span").is_some());
            }
        }
        let _ = std::fs::remove_dir_all(&trace_dir);
    });
}

// ---------------------------------------------------------------------
// Streaming: contiguous seq numbers, exactly one terminal frame, and
// mid-stream fault semantics (panic, disconnect, slow consumer).
// ---------------------------------------------------------------------

#[test]
fn streaming_over_tcp_is_contiguous_with_one_terminal_done() {
    with_watchdog(60, || {
        let router = Arc::new(Router::new(model(), EngineConfig::default(), 2));
        let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_handle();
        let srv = std::thread::spawn(move || server.serve());

        let mut c = Client::connect(&addr).unwrap();
        let frames = c
            .stream_generate(&WireRequest {
                prompt: "stream me a dozen tokens ".to_string(),
                max_new_tokens: 12,
                stream: true,
                ..Default::default()
            })
            .expect("an unloaded pool must stream");

        let mut next_seq = 0u64;
        let mut terminals = 0usize;
        for f in &frames {
            match f {
                StreamFrame::Token { seq, .. } => {
                    assert_eq!(*seq, next_seq, "seq numbers must be contiguous from 0");
                    next_seq += 1;
                }
                StreamFrame::Keepalive { .. } => {}
                StreamFrame::Done { tokens_streamed, finish, .. } => {
                    terminals += 1;
                    assert_eq!(*tokens_streamed, next_seq, "truncation-detection count");
                    assert_eq!(*tokens_streamed, 12);
                    assert_eq!(finish, "length");
                }
                other => panic!("unexpected terminal frame {other:?}"),
            }
        }
        assert_eq!(terminals, 1, "exactly one terminal frame per stream");

        stop.store(true, Ordering::Relaxed);
        srv.join().expect("server thread").expect("serve exits cleanly");
        let router = Arc::try_unwrap(router).ok().expect("router released");
        let m = router.shutdown();
        assert_eq!(m.tokens_streamed, 12);
        assert_eq!(m.streams_severed, 0);
        assert!(m.ttft_wire.count() >= 1, "wire TTFT must be recorded");
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

#[test]
fn mid_stream_panic_ends_with_error_carrying_truncation_point() {
    with_watchdog(60, || {
        // One worker, panic well past the first token: the request has
        // streamed visible progress, so salvage must NOT retry it — the
        // stream ends in a worker_failed error naming the emitted count.
        let cfg = EngineConfig {
            faults: FaultPlan::none()
                .with(Fault { worker: 0, step: 6, kind: FaultKind::Panic }),
            ..Default::default()
        };
        let router = Router::new(model(), cfg, 1);
        let (id, sink) = router
            .submit_streaming(prompt("stream that dies mid-flight "), params(64))
            .unwrap();

        // Drain to Closed; every token pushed before the panic is still
        // delivered (the sink closes only after the outcome lands).
        let mut seqs = Vec::new();
        loop {
            match sink.recv_timeout(Duration::from_millis(100)) {
                StreamRecv::Event(ev) => seqs.push(ev.seq),
                StreamRecv::Closed => break,
                StreamRecv::Empty => {}
            }
        }
        assert!(!seqs.is_empty(), "panic at step 6 must land after first tokens");
        for (i, s) in seqs.iter().enumerate() {
            assert_eq!(*s, i as u64, "delivered seqs stay contiguous across a panic");
        }
        let outcome = router
            .wait_for_outcome(id, Duration::from_secs(10))
            .expect("sink closes only after the outcome is recorded");
        match outcome {
            Outcome::Failed(e) => {
                assert_eq!(e.code, "worker_failed");
                let want = format!("({} tokens emitted)", seqs.len());
                assert!(
                    e.message.contains(&want),
                    "error {:?} must carry the truncation point {want:?}",
                    e.message
                );
            }
            Outcome::Done(r) => panic!("expected worker_failed, got {:?}", r.finish),
        }
        let m = router.shutdown();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.streams_severed, 1, "a truncated stream counts as severed");
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

#[test]
fn client_disconnect_mid_stream_cancels_without_leaks() {
    with_watchdog(60, || {
        // A 1ms stall every step paces decode so the disconnect
        // deterministically lands long before the token budget.
        let cfg = EngineConfig {
            faults: FaultPlan::none()
                .with(Fault { worker: 0, step: 0, kind: FaultKind::Stall { ms: 1 } }),
            ..Default::default()
        };
        let router = Arc::new(Router::new(model(), cfg, 1));
        let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_handle();
        let srv = std::thread::spawn(move || server.serve());

        let mut c = Client::connect(&addr).unwrap();
        c.send(&WireRequest {
            prompt: "disconnecting mid stream ".to_string(),
            max_new_tokens: 4096,
            stream: true,
            ..Default::default()
        })
        .unwrap();
        // Prove the stream is live, then vanish without a goodbye.
        let mut read = 0;
        while read < 2 {
            match c.read_frame().expect("live stream") {
                StreamFrame::Token { .. } => read += 1,
                StreamFrame::Keepalive { .. } => {}
                other => panic!("stream ended before the disconnect: {other:?}"),
            }
        }
        drop(c);

        // The server notices (failed write / disconnect probe), cancels,
        // and the request still reaches its one terminal outcome.
        router.wait_idle();
        let (done, submitted) = router.progress();
        assert_eq!(done, submitted, "disconnected stream lost its outcome");

        stop.store(true, Ordering::Relaxed);
        srv.join().expect("server thread").expect("serve exits cleanly");
        let router = Arc::try_unwrap(router).ok().expect("router released");
        let m = router.shutdown();
        assert_eq!(m.disconnect_aborts, 1, "disconnect must cancel the stream");
        assert!(m.generated_tokens < 4096, "cancel must cut decode short");
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

#[test]
fn slow_consumer_is_severed_and_shed_without_blocking_decode() {
    with_watchdog(60, || {
        // Deliberately slow reader at the sink level: never read at all.
        // Decode must sever the stream at the buffer bound and shed the
        // request — not block, not buffer 1000 tokens.
        let rcfg = RouterConfig { stream_buffer: 4, ..Default::default() };
        let router = Router::with_config(model(), EngineConfig::default(), 1, rcfg);
        let (id, sink) = router
            .submit_streaming(prompt("never read me "), params(1_000))
            .unwrap();
        let outcome = router
            .wait_for_outcome(id, Duration::from_secs(30))
            .expect("a severed stream still reaches a terminal outcome");
        match outcome {
            Outcome::Done(r) => assert_eq!(r.finish, FinishReason::Cancelled),
            Outcome::Failed(e) => panic!("expected cancelled shed, got {}", e.code),
        }
        assert!(sink.is_severed());
        // The tokens that fit the buffer stay deliverable, then Closed.
        let mut got = 0u64;
        loop {
            match sink.recv_timeout(Duration::from_millis(100)) {
                StreamRecv::Event(_) => got += 1,
                StreamRecv::Closed => break,
                StreamRecv::Empty => {}
            }
        }
        assert_eq!(got, 4, "exactly the buffered tokens are delivered");
        let m = router.shutdown();
        assert_eq!(m.slow_consumer_sheds, 1);
        assert_eq!(m.streams_severed, 1);
        assert_eq!(m.tokens_streamed, 4, "refused pushes must not count");
        assert!(m.generated_tokens < 1_000, "shed must cut decode short");
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

// ---------------------------------------------------------------------
// Prefix-affinity routing: cohorts follow the sketch into one worker's
// radix cache; degradation never turns the hint into availability loss.
// ---------------------------------------------------------------------

#[test]
fn affinity_routes_shared_prompts_into_one_radix_cache() {
    with_watchdog(60, || {
        let router = Router::new(model(), EngineConfig::default(), 4);
        let shared = "common instruction preamble shared by every client in the cohort ";
        router.submit(prompt(shared), params(4)).unwrap();
        router.wait_idle();
        for _ in 0..8 {
            router.submit(prompt(shared), params(4)).unwrap();
            router.wait_idle();
        }
        let m = router.shutdown();
        assert!(
            m.affinity_hits >= 8,
            "repeat prompts must follow the sketch (got {} hits)",
            m.affinity_hits
        );
        // The payoff: routing them to one worker means its radix cache
        // serves every repeat (4-way least-loaded would scatter them).
        assert!(
            m.prefix_hits >= 8,
            "affinity must convert into radix-cache hits (got {})",
            m.prefix_hits
        );
    });
}

#[test]
fn affinity_degrades_to_least_loaded_when_preferred_worker_saturated() {
    with_watchdog(60, || {
        // Worker 0 is pinned busy (stall paces its long request) and the
        // per-worker bound is 1: a same-prefix submission must fall back
        // to worker 1 instead of being refused or queued behind it.
        let cfg = EngineConfig {
            faults: FaultPlan::none()
                .with(Fault { worker: 0, step: 0, kind: FaultKind::Stall { ms: 2 } }),
            ..Default::default()
        };
        let rcfg = RouterConfig { max_queue_per_worker: 1, ..Default::default() };
        let router = Router::with_config(model(), cfg, 2, rcfg);
        let p = "cohort prompt with a nice long shared prefix for the sketch ";
        router.submit(prompt(p), params(64)).expect("first request pins worker 0");
        router
            .submit(prompt(p), params(4))
            .expect("affinity must not turn saturation into a refusal");
        router.wait_idle();
        let m = router.shutdown();
        assert!(m.affinity_fallbacks >= 1, "saturated preferred worker must degrade");
        assert_eq!(m.requests_completed, 2, "both requests must finish");
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}

#[test]
fn killed_preferred_worker_degrades_without_dropping_requests() {
    with_watchdog(120, || {
        // Affinity funnels the cohort into worker 0; a panic there must
        // cost at most structured errors — never a lost outcome.
        let cfg = EngineConfig {
            faults: FaultPlan::none()
                .with(Fault { worker: 0, step: 8, kind: FaultKind::Panic }),
            ..Default::default()
        };
        let router = Router::new(model(), cfg, 2);
        let p = "the whole cohort shares this exact long prompt prefix ";
        let mut accepted = 0usize;
        for i in 0..12 {
            if router.submit(prompt(&format!("{p}client {i} ")), params(16)).is_ok() {
                accepted += 1;
            }
        }
        assert!(accepted >= 1);
        router.wait_idle();
        let responses = router.take_responses();
        let failures = router.take_failures();
        assert_eq!(
            responses.len() + failures.len(),
            accepted,
            "every accepted request needs exactly one terminal outcome"
        );
        assert_eq!(router.alive_workers(), 2, "preferred worker must restart");
        let m = router.shutdown();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.kv_blocks_leaked, 0);
    });
}
