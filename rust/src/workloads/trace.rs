//! Serving-trace generation for the end-to-end benches: Poisson arrivals
//! with log-normal-ish prompt lengths and geometric output lengths,
//! loosely shaped after public LLM serving traces.

use crate::util::rng::Rng;

/// One request in a serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
}

/// Trace generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    /// Mean arrival rate (requests/second). `f64::INFINITY` → all at t=0
    /// (closed-loop / offline batch workload).
    pub rate: f64,
    /// Log-space mean and std of prompt lengths.
    pub prompt_log_mean: f64,
    pub prompt_log_std: f64,
    /// Clamp for prompt lengths.
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// Mean output length (geometric).
    pub mean_new_tokens: f64,
    pub max_new_tokens: usize,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            rate: 4.0,
            prompt_log_mean: 5.0, // e^5 ≈ 148 tokens
            prompt_log_std: 0.8,
            prompt_min: 8,
            prompt_max: 4096,
            mean_new_tokens: 32.0,
            max_new_tokens: 128,
        }
    }
}

/// Generate `count` requests.
pub fn generate(rng: &mut Rng, params: &TraceParams, count: usize) -> Vec<TraceRequest> {
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if params.rate.is_finite() {
            t += rng.exponential(params.rate);
        }
        let prompt = (rng.normal(params.prompt_log_mean, params.prompt_log_std))
            .exp()
            .round() as usize;
        let prompt_len = prompt.clamp(params.prompt_min, params.prompt_max);
        // Geometric with the given mean: p = 1/mean.
        let p = (1.0 / params.mean_new_tokens).clamp(1e-6, 1.0);
        let mut new_tokens = 1usize;
        while new_tokens < params.max_new_tokens && !rng.bool(p) {
            new_tokens += 1;
        }
        out.push(TraceRequest { arrival_s: t, prompt_len, max_new_tokens: new_tokens });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_rate_matches() {
        let mut rng = Rng::new(91);
        let params = TraceParams { rate: 10.0, ..Default::default() };
        let trace = generate(&mut rng, &params, 2000);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let total = trace.last().unwrap().arrival_s;
        let rate = trace.len() as f64 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate={rate}");
    }

    #[test]
    fn offline_trace_has_zero_arrivals() {
        let mut rng = Rng::new(92);
        let params = TraceParams { rate: f64::INFINITY, ..Default::default() };
        let trace = generate(&mut rng, &params, 10);
        assert!(trace.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn lengths_respect_bounds() {
        let mut rng = Rng::new(93);
        let params = TraceParams {
            prompt_min: 16,
            prompt_max: 256,
            max_new_tokens: 64,
            ..Default::default()
        };
        for r in generate(&mut rng, &params, 500) {
            assert!((16..=256).contains(&r.prompt_len));
            assert!((1..=64).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn mean_output_length_approximates_target() {
        let mut rng = Rng::new(94);
        let params = TraceParams {
            mean_new_tokens: 20.0,
            max_new_tokens: 1000,
            ..Default::default()
        };
        let trace = generate(&mut rng, &params, 3000);
        let mean: f64 =
            trace.iter().map(|r| r.max_new_tokens as f64).sum::<f64>() / trace.len() as f64;
        assert!((mean - 20.0).abs() < 2.0, "mean={mean}");
    }
}
