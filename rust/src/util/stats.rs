//! Small statistics helpers used by benches and metrics: summary stats,
//! percentiles, online histograms, and log-log regression for fitting
//! scaling exponents (used to verify the paper's O(n^{4/5}) claims).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (p in [0,100]) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Result of an ordinary least-squares line fit y = a + b x.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    /// Intercept.
    pub a: f64,
    /// Slope.
    pub b: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Least-squares line fit. Returns None for < 2 points or degenerate x.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Option<LineFit> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    if sxx == 0.0 {
        return None;
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    Some(LineFit { a, b, r2 })
}

/// Fit y ~ c * x^e on positive data by regressing log y on log x.
/// Returns (exponent e, r^2). This is how benches verify the paper's
/// exponents (e.g. decode time should fit e ≈ 4/5 in n).
pub fn power_fit(xs: &[f64], ys: &[f64]) -> Option<(f64, f64)> {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    if xs.iter().any(|&x| x <= 0.0) || ys.iter().any(|&y| y <= 0.0) {
        return None;
    }
    linear_fit(&lx, &ly).map(|f| (f.b, f.r2))
}

/// A latency histogram over fixed log-spaced buckets (nanoseconds),
/// cheap enough for the engine hot path.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Bucket upper bounds in ns (last is +inf).
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Log-spaced buckets from 1us to ~100s.
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1_000u64; // 1us
        while b < 100_000_000_000 {
            bounds.push(b);
            b = b.saturating_mul(2);
        }
        let n = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; n], total: 0, sum_ns: 0, max_ns: 0 }
    }

    /// Record one observation in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        let idx = match self.bounds.binary_search(&ns) {
            Ok(i) => i,
            Err(i) => i,
        };
        let last = self.counts.len() - 1;
        self.counts[idx.min(last)] += 1;
        self.total += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Record a duration.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean in ns.
    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.total as f64
        }
    }

    /// Maximum observed value in ns.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Sum of all observations in ns.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Per-bucket `(upper_bound_ns, count)` pairs in ascending order;
    /// the final overflow bucket has bound `None` (+inf). Counts are
    /// per-bucket, not cumulative.
    pub fn buckets(&self) -> impl Iterator<Item = (Option<u64>, u64)> + '_ {
        self.counts.iter().enumerate().map(move |(i, &c)| {
            (self.bounds.get(i).copied(), c)
        })
    }

    /// Approximate percentile (bucket upper bound), p in [0,100].
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max_ns };
            }
        }
        self.max_ns
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Pretty-print nanoseconds with a sensible unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn power_fit_recovers_exponent() {
        let xs: Vec<f64> = (1..20).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(0.8)).collect();
        let (e, r2) = power_fit(&xs, &ys).unwrap();
        assert!((e - 0.8).abs() < 1e-9, "e={e}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn linear_fit_degenerate() {
        assert!(linear_fit(&[1.0], &[1.0]).is_none());
        assert!(linear_fit(&[2.0, 2.0], &[1.0, 5.0]).is_none());
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 10_000); // 10us..10ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ns(50.0);
        let p99 = h.percentile_ns(99.0);
        assert!(p50 <= p99);
        assert!(p50 >= 1_000_000); // >= ~1ms given log buckets
        assert!(h.mean_ns() > 0.0);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_ns(5_000);
        b.record_ns(50_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000s");
    }
}
