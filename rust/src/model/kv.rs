//! Per-sequence KV state with optional per-(layer, head) dynamic HSR
//! indices — the data structure Algorithm 1 calls "KV Cache" plus the
//! HSR side-index its INIT procedure builds.
//!
//! Keys are stored *post-RoPE* (matching the JAX cache convention), one
//! contiguous `[n, d_head]` buffer per (layer, head) so HSR gathers and
//! attention reads are cache-friendly. When HSR indexing is enabled, the
//! same key rows are inserted into a [`DynamicHsr`] (logarithmic-method)
//! structure as they are appended — the amortized-update clause of
//! Theorem B.11 in action.

use crate::hsr::dynamic::DynamicHsr;
use crate::hsr::{HalfSpaceReport, HsrBackend, QueryStats};

/// KV + HSR state for one (layer, head).
pub struct HeadKv {
    /// Post-RoPE keys, row-major [n, d_head].
    pub keys: Vec<f32>,
    /// Values, row-major [n, d_head].
    pub values: Vec<f32>,
    /// Optional HSR index over the keys.
    pub hsr: Option<DynamicHsr>,
    /// Adaptive HSR threshold (raw inner-product scale), maintained by the
    /// top-r attention calibrator in `transformer.rs`.
    pub calib_threshold: Option<f32>,
    d_head: usize,
}

impl HeadKv {
    fn new(d_head: usize, hsr_backend: Option<HsrBackend>) -> HeadKv {
        HeadKv {
            keys: Vec::new(),
            values: Vec::new(),
            hsr: hsr_backend.map(|b| DynamicHsr::new(b, d_head)),
            calib_threshold: None,
            d_head,
        }
    }

    /// Number of cached tokens.
    pub fn len(&self) -> usize {
        self.keys.len() / self.d_head
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Append one (key, value) row, updating the HSR index.
    pub fn append(&mut self, key: &[f32], value: &[f32]) {
        debug_assert_eq!(key.len(), self.d_head);
        debug_assert_eq!(value.len(), self.d_head);
        if let Some(hsr) = &mut self.hsr {
            hsr.insert(key);
        }
        self.keys.extend_from_slice(key);
        self.values.extend_from_slice(value);
    }

    /// HSR query over the cached keys: all indices with <q, K_j> >= b_raw
    /// (b_raw is on the *unscaled* inner product). Deprecated-style shim
    /// for the [`HalfSpaceReport`] impl below.
    pub fn hsr_query(&self, q: &[f32], b_raw: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        self.query_into(q, b_raw, out, stats);
    }

    /// Score-carrying HSR query: like [`HeadKv::hsr_query`] but also
    /// reports each index's raw inner product. Deprecated-style shim for
    /// the [`HalfSpaceReport`] impl below.
    pub fn hsr_query_scored(
        &self,
        q: &[f32],
        b_raw: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        self.query_scored_into(q, b_raw, out, scores, stats);
    }

    #[inline]
    pub fn key_row(&self, j: usize) -> &[f32] {
        &self.keys[j * self.d_head..(j + 1) * self.d_head]
    }

    #[inline]
    pub fn value_row(&self, j: usize) -> &[f32] {
        &self.values[j * self.d_head..(j + 1) * self.d_head]
    }

    /// Reassemble a frozen head from parts decoded out of a cold-tier
    /// record ([`crate::kvstore::tier`]): the segment's exact key/value
    /// bit patterns plus an HSR index rebuilt or deserialized per the
    /// spill policy. Counterpart of [`HeadKv::snapshot_range`] for the
    /// refault path.
    pub(crate) fn from_frozen_parts(
        keys: Vec<f32>,
        values: Vec<f32>,
        hsr: Option<DynamicHsr>,
        calib_threshold: Option<f32>,
        d_head: usize,
    ) -> HeadKv {
        debug_assert_eq!(keys.len() % d_head, 0);
        debug_assert_eq!(values.len(), keys.len());
        HeadKv { keys, values, hsr, calib_threshold, d_head }
    }

    /// Frozen copy of rows `[start, start + len)`: contiguous keys/values
    /// with a freshly batch-built (single-bucket) HSR index over exactly
    /// those rows, carrying the current calibration threshold along as
    /// the segment's post-prefill snapshot. This is how the shared-prefix
    /// KV store ([`crate::kvstore`]) materializes a prefix segment out of
    /// a sequence's private tail: the copy is immutable from then on and
    /// its index is shared by every sequence holding the segment.
    pub fn snapshot_range(
        &self,
        start: usize,
        len: usize,
        backend: Option<HsrBackend>,
    ) -> HeadKv {
        let d = self.d_head;
        debug_assert!(start + len <= self.len());
        let keys = self.keys[start * d..(start + len) * d].to_vec();
        let values = self.values[start * d..(start + len) * d].to_vec();
        HeadKv {
            hsr: backend.map(|b| DynamicHsr::from_points(b, &keys, d)),
            calib_threshold: self.calib_threshold,
            keys,
            values,
            d_head: d,
        }
    }
}

/// A `HeadKv` *is* a half-space reporting structure over its cached
/// keys: the attached [`DynamicHsr`] answers queries when present, and a
/// brute scan over the contiguous key rows does otherwise (the engine's
/// `hsr_backend: None` ablation). This is what lets the transformer's
/// per-head attention be a thin caller of the session plan/execute
/// machinery — the session layer only ever sees `&dyn HalfSpaceReport`.
impl HalfSpaceReport for HeadKv {
    fn len(&self) -> usize {
        HeadKv::len(self)
    }

    fn dim(&self) -> usize {
        self.d_head
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        match &self.hsr {
            Some(hsr) => hsr.query_into(a, b, out, stats),
            None => {
                let n = HeadKv::len(self);
                stats.points_scanned += n;
                for j in 0..n {
                    if crate::hsr::dot(a, self.key_row(j)) >= b {
                        out.push(j as u32);
                        stats.reported += 1;
                    }
                }
            }
        }
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        match &self.hsr {
            Some(hsr) => hsr.query_scored_into(a, b, out, scores, stats),
            None => {
                let n = HeadKv::len(self);
                stats.points_scanned += n;
                for j in 0..n {
                    let s = crate::hsr::dot(a, self.key_row(j));
                    if s >= b {
                        out.push(j as u32);
                        scores.push(s);
                        stats.reported += 1;
                    }
                }
            }
        }
    }

    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        match &self.hsr {
            // Shared traversal through the dynamic index.
            Some(hsr) => hsr.query_many_scored_into(queries, bs, outs, scores, stats),
            None => {
                let d = self.d_head;
                let q = bs.len();
                assert_eq!(queries.len(), q * d);
                for i in 0..q {
                    self.query_scored_into(
                        &queries[i * d..(i + 1) * d],
                        bs[i],
                        &mut outs[i],
                        &mut scores[i],
                        stats,
                    );
                }
            }
        }
    }
}

/// Full per-sequence KV state: `n_layers × n_heads` of [`HeadKv`].
pub struct KvState {
    pub heads: Vec<HeadKv>, // layer-major: heads[layer * n_heads + head]
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
}

impl KvState {
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
        hsr_backend: Option<HsrBackend>,
    ) -> KvState {
        let heads = (0..n_layers * n_heads)
            .map(|_| HeadKv::new(d_head, hsr_backend))
            .collect();
        KvState { heads, n_layers, n_heads, d_head }
    }

    /// Cached sequence length (tokens).
    pub fn len(&self) -> usize {
        self.heads[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.heads[0].is_empty()
    }

    #[inline]
    pub fn head(&self, layer: usize, head: usize) -> &HeadKv {
        &self.heads[layer * self.n_heads + head]
    }

    #[inline]
    pub fn head_mut(&mut self, layer: usize, head: usize) -> &mut HeadKv {
        &mut self.heads[layer * self.n_heads + head]
    }

    /// Batch view: all of one layer's heads as a mutable slice, so the
    /// batched decode sweep can hand disjoint `&mut HeadKv` items from
    /// several sequences to scoped worker threads at once.
    #[inline]
    pub fn layer_heads_mut(&mut self, layer: usize) -> &mut [HeadKv] {
        &mut self.heads[layer * self.n_heads..(layer + 1) * self.n_heads]
    }

    /// Frozen copy of token rows `[start, start + len)` across every
    /// (layer, head) — the per-sequence side of
    /// [`HeadKv::snapshot_range`], used by the shared-prefix KV store to
    /// turn a prefilled tail range into an immutable, refcounted segment.
    pub fn snapshot_range(
        &self,
        start: usize,
        len: usize,
        backend: Option<HsrBackend>,
    ) -> KvState {
        KvState {
            heads: self
                .heads
                .iter()
                .map(|h| h.snapshot_range(start, len, backend))
                .collect(),
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_head: self.d_head,
        }
    }

    /// Approximate memory footprint in bytes (keys + values only).
    pub fn bytes(&self) -> usize {
        self.heads
            .iter()
            .map(|h| (h.keys.len() + h.values.len()) * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_query_consistency() {
        let mut rng = Rng::new(1);
        let d = 8;
        let mut kv = KvState::new(2, 2, d, Some(HsrBackend::BallTree));
        for _ in 0..300 {
            for l in 0..2 {
                for h in 0..2 {
                    let k = rng.gaussian_vec_f32(d, 1.0);
                    let v = rng.gaussian_vec_f32(d, 1.0);
                    kv.head_mut(l, h).append(&k, &v);
                }
            }
        }
        assert_eq!(kv.len(), 300);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let head = kv.head(1, 0);
        let mut via_hsr = Vec::new();
        let mut stats = QueryStats::default();
        head.hsr_query(&q, 1.0, &mut via_hsr, &mut stats);
        via_hsr.sort_unstable();
        // Brute-force over stored rows must agree.
        let mut brute = Vec::new();
        for j in 0..head.len() {
            if crate::hsr::dot(&q, head.key_row(j)) >= 1.0 {
                brute.push(j as u32);
            }
        }
        assert_eq!(via_hsr, brute);
    }

    #[test]
    fn no_index_falls_back_to_scan() {
        let mut rng = Rng::new(2);
        let d = 4;
        let mut kv = KvState::new(1, 1, d, None);
        for _ in 0..50 {
            let k = rng.gaussian_vec_f32(d, 1.0);
            kv.head_mut(0, 0).append(&k.clone(), &k);
        }
        let q = rng.gaussian_vec_f32(d, 1.0);
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        kv.head(0, 0).hsr_query(&q, 0.0, &mut out, &mut stats);
        assert_eq!(stats.points_scanned, 50);
    }

    #[test]
    fn bytes_accounts_keys_and_values() {
        let kv = KvState::new(2, 3, 16, None);
        assert_eq!(kv.bytes(), 0);
        let mut kv = kv;
        kv.head_mut(0, 0).append(&[0.0; 16], &[0.0; 16]);
        assert_eq!(kv.bytes(), 2 * 16 * 4);
    }
}
