//! Paged KV-cache accounting: a block allocator in the vLLM style.
//!
//! Sequences allocate fixed-size token blocks as they grow; admission and
//! preemption decisions are driven by pool pressure. The float payload
//! itself lives in each sequence's [`crate::model::kv::KvState`] (the HSR
//! index needs contiguous per-head key rows); this allocator is the
//! capacity authority — a sequence may only hold tokens it has blocks
//! for, which tests enforce.

/// Fixed-size block allocator over an abstract pool of token slots.
#[derive(Debug)]
pub struct BlockAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    total_blocks: usize,
}

impl BlockAllocator {
    /// Pool sized for `capacity_tokens` tokens in `block_tokens`-sized
    /// blocks.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> BlockAllocator {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        BlockAllocator {
            block_tokens,
            free: (0..total_blocks as u32).rev().collect(),
            total_blocks,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Tokens currently allocatable without eviction.
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate `count` blocks; None if the pool cannot satisfy it.
    pub fn alloc(&mut self, count: usize) -> Option<Vec<u32>> {
        if self.free.len() < count {
            return None;
        }
        Some(self.free.split_off(self.free.len() - count))
    }

    /// Grow a sequence's holding from `held` blocks to cover
    /// `needed_tokens`; appends new blocks to `blocks`.
    pub fn ensure(&mut self, blocks: &mut Vec<u32>, needed_tokens: usize) -> bool {
        let need = self.blocks_for(needed_tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc(need - blocks.len()) {
            Some(mut extra) => {
                blocks.append(&mut extra);
                true
            }
            None => false,
        }
    }

    /// Return blocks to the pool.
    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        self.free.append(blocks);
        debug_assert!(self.free.len() <= self.total_blocks);
    }

    /// Pool utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        1.0 - self.free.len() as f64 / self.total_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(1024, 16);
        assert_eq!(a.total_blocks(), 64);
        let mut b1 = a.alloc(10).unwrap();
        assert_eq!(a.free_blocks(), 54);
        a.release(&mut b1);
        assert_eq!(a.free_blocks(), 64);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = BlockAllocator::new(64, 16);
        assert!(a.alloc(4).is_some());
        assert!(a.alloc(1).is_none());
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut a = BlockAllocator::new(160, 16);
        let mut blocks = Vec::new();
        assert!(a.ensure(&mut blocks, 1)); // 1 block
        assert_eq!(blocks.len(), 1);
        assert!(a.ensure(&mut blocks, 16)); // still 1 block
        assert_eq!(blocks.len(), 1);
        assert!(a.ensure(&mut blocks, 17)); // 2 blocks
        assert_eq!(blocks.len(), 2);
        assert!(a.ensure(&mut blocks, 160));
        assert_eq!(blocks.len(), 10);
        assert!(!a.ensure(&mut blocks, 176)); // pool exhausted
        assert_eq!(blocks.len(), 10);
    }

    #[test]
    fn no_double_allocation() {
        let mut a = BlockAllocator::new(64, 8);
        let b1 = a.alloc(4).unwrap();
        let b2 = a.alloc(4).unwrap();
        for x in &b1 {
            assert!(!b2.contains(x), "block {x} double-allocated");
        }
    }

    #[test]
    fn utilization_tracks() {
        let mut a = BlockAllocator::new(100, 10);
        assert_eq!(a.utilization(), 0.0);
        let mut b = a.alloc(5).unwrap();
        assert!((a.utilization() - 0.5).abs() < 1e-12);
        a.release(&mut b);
        assert_eq!(a.utilization(), 0.0);
    }
}
