//! Request/response types of the serving engine.

use std::sync::Arc;
use std::time::Instant;

use super::stream::StreamSink;

/// Unique request id.
pub type RequestId = u64;

/// Sampling / generation parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenerationParams {
    pub max_new_tokens: usize,
    /// 0.0 → greedy.
    pub temperature: f32,
    /// Stop at this token if produced (byte value); None → length only.
    pub stop_token: Option<u32>,
    /// Absolute deadline. A sequence past it is aborted mid-decode
    /// (blocks and chain refs released) with
    /// [`FinishReason::DeadlineExceeded`]; None → no deadline.
    pub deadline: Option<Instant>,
    /// Parallel samples to return (the wire `"n"`). Values ≥ 2 fork the
    /// sequence after its first token so all samples share the prompt
    /// KV chain; the response carries one [`Choice`] per sample.
    pub n: u32,
    /// Candidates to generate (the wire `"best_of"`); 0 → same as `n`.
    /// When larger than `n`, the extra candidates are generated and the
    /// `n` best by cumulative log-probability are returned.
    pub best_of: u32,
    /// Beam-search width (the wire `"beam_width"`); 0 or 1 → off.
    /// Overrides `n`/`best_of`: decoding keeps the `beam_width` highest
    /// cumulative-log-probability hypotheses, forking on expansion and
    /// pruning losers each step.
    pub beam_width: u32,
}

impl Default for GenerationParams {
    fn default() -> Self {
        GenerationParams {
            max_new_tokens: 64,
            temperature: 0.0,
            stop_token: None,
            deadline: None,
            n: 1,
            best_of: 0,
            beam_width: 0,
        }
    }
}

impl GenerationParams {
    /// Beam search requested?
    pub fn is_beam(&self) -> bool {
        self.beam_width >= 2
    }

    /// Sibling sequences this request decodes concurrently: the beam
    /// width, else max(n, best_of). 1 → plain single-sequence request.
    pub fn group_width(&self) -> u32 {
        if self.is_beam() {
            self.beam_width
        } else {
            self.n.max(1).max(self.best_of)
        }
    }
}

/// An inbound generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    /// Times this request has been re-dispatched after a worker failure
    /// (bounds the supervision retry budget).
    pub attempts: u32,
    /// Per-token delivery channel for streaming requests; `None` for
    /// buffered (whole-response) requests. The engine pushes every
    /// sampled token; overruns sever the stream (slow-consumer shed)
    /// without ever blocking decode.
    pub stream: Option<Arc<StreamSink>>,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    StopToken,
    /// Engine shut down before completion.
    Aborted,
    /// Past its client-supplied deadline ("deadline" on the wire).
    DeadlineExceeded,
    /// Explicitly cancelled, e.g. the client disconnected.
    Cancelled,
}

/// One completed sibling of a grouped (parallel-sampling / beam)
/// request.
#[derive(Debug, Clone, PartialEq)]
pub struct Choice {
    /// Stable sibling index (0 = the original submission's lineage;
    /// forked siblings get the next free index at fork time). Matches
    /// the `sibling` tag on stream frames.
    pub index: u32,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Cumulative log-probability of `tokens` under the model's
    /// (temperature-independent) softmax — the beam score. 0.0 for
    /// plain requests.
    pub logprob: f64,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall time from submission to completion.
    pub latency_ms: f64,
    /// Time to first generated token.
    pub ttft_ms: f64,
    pub prompt_len: usize,
    /// Per-sibling results of a grouped request, ranked best-first
    /// (`tokens`/`finish` above mirror the best choice). Empty for
    /// plain single-sequence requests.
    pub choices: Vec<Choice>,
}

/// Engine-internal sequence state.
pub(crate) struct Sequence {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    pub generated: Vec<u32>,
    /// Private KV tail: everything past the adopted shared prefix (the
    /// whole cache when `prefix` is empty).
    pub kv: crate::model::kv::KvState,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    /// Blocks held in the cache pool **for the private tail** — shared
    /// prefix segments hold their own blocks, refcounted in the store.
    pub blocks: Vec<u32>,
    /// Number of prompt tokens already prefilled (chunked prefill
    /// cursor); tokens below `prefix_len` were adopted, not computed.
    pub prefilled: usize,
    /// Generated tokens already folded back into `prompt` by a previous
    /// preemption/shed (recompute re-feeds them); folding only the
    /// suffix past this cursor keeps a twice-preempted sequence from
    /// duplicating its early generations in the prompt.
    pub folded: usize,
    /// Adopted shared-prefix chain (radix node ids; one reference held
    /// on each node until finish/preemption).
    pub prefix: Vec<crate::kvstore::NodeId>,
    /// Tokens covered by `prefix` (the tail starts at this position).
    pub prefix_len: usize,
    /// Submission order; lower = older. Preemption only ever evicts
    /// strictly-younger sequences, which guarantees scheduler progress.
    pub priority: u64,
    /// Re-dispatch count inherited from the [`Request`] (see
    /// `Request::attempts`).
    pub attempts: u32,
    /// Streaming channel inherited from the [`Request`]. Tokens are
    /// pushed exactly once each at sample time; preemption re-feeds
    /// folded tokens through prefill without re-pushing them, so the
    /// wire sequence stays contiguous across preemptions.
    pub stream: Option<Arc<StreamSink>>,
    /// Per-sequence sampling RNG, seeded from the engine seed and the
    /// request id; forks give each sibling an independent stream
    /// ([`crate::util::rng::Rng::fork`]) so siblings diverge
    /// deterministically. Greedy decoding never draws from it.
    pub rng: crate::util::rng::Rng,
    /// Group primary's request id when this sequence belongs to a
    /// parallel-sampling group or beam (the primary points at itself);
    /// `None` for standalone sequences.
    pub group: Option<RequestId>,
    /// Sibling index within the group (0 = the original submission).
    pub sibling: u32,
    /// Cumulative log-probability of `generated` (beam score /
    /// best-of ranking key). Only maintained for grouped sequences.
    pub score: f64,
    /// Logits of the last prompt token, stashed when a group primary
    /// seeds its first generated token so sampling-group siblings can
    /// draw their own first token from the same distribution at
    /// fan-out (taken and dropped there).
    pub seed_logits: Option<Vec<f32>>,
}

impl Sequence {
    /// Split this sequence mid-decode: the sibling shares every KV
    /// chain segment the parent has adopted and clones the generated
    /// tokens, but starts with a **fresh private tail** (no blocks, no
    /// rows — the caller publishes the parent's tail into the chain
    /// first, see the engine's publish-on-fork path) and a forked RNG.
    /// The caller assigns the id, takes chain references for the
    /// child, and seeds its tail calibration.
    pub fn fork(&mut self, id: RequestId, hsr: Option<crate::hsr::HsrBackend>) -> Sequence {
        let kv = crate::model::kv::KvState::new(
            self.kv.n_layers,
            self.kv.n_heads,
            self.kv.d_head,
            hsr,
        );
        Sequence {
            id,
            prompt: self.prompt.clone(),
            params: self.params,
            generated: self.generated.clone(),
            kv,
            submitted: self.submitted,
            first_token_at: self.first_token_at,
            blocks: Vec::new(),
            prefilled: self.prefilled,
            folded: self.folded,
            prefix: self.prefix.clone(),
            prefix_len: self.prefix_len,
            priority: self.priority,
            attempts: self.attempts,
            stream: self.stream.clone(),
            rng: self.rng.fork(),
            group: self.group,
            sibling: self.sibling,
            score: self.score,
            seed_logits: None,
        }
    }
    /// Total tokens this sequence attends over: shared prefix + tail.
    /// (Diagnostics; block accounting uses [`Sequence::tail_tokens`].)
    #[allow(dead_code)]
    pub fn cached_tokens(&self) -> usize {
        self.prefix_len + self.kv.len()
    }

    /// Tokens in the private tail — what this sequence's own blocks
    /// must cover, and what preempting it would free.
    pub fn tail_tokens(&self) -> usize {
        self.kv.len()
    }

    /// Next token to feed: prompt remainder, else last generated.
    pub fn done(&self) -> bool {
        self.generated.len() >= self.params.max_new_tokens
    }
}
