//! Shared utilities: deterministic RNG, statistics, JSON, CLI parsing,
//! tensor I/O. These exist because the vendored dependency set is minimal
//! (no rand / serde / clap); everything here is small, tested and owned.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod tensor_io;
