//! Local shim for the `anyhow` API surface this workspace uses.
//!
//! The repo's dependency policy is "no crates.io fetches" — every build
//! input lives in-tree. This crate provides the subset of `anyhow` that
//! hsr-attn relies on (`Error`, `Result`, `anyhow!`, `bail!`, `ensure!`,
//! `Context`) with the same calling conventions, so application code is
//! source-compatible with the real crate should it ever be vendored.
//!
//! Differences from upstream: errors carry a flattened message string
//! (context frames are prefixed `"{context}: {cause}"`) instead of a
//! source chain, and there is no downcasting.

use std::fmt;

/// A flattened error value. Like upstream `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error` — that is what
/// makes the blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix a context frame onto the message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(,)?) => {
        $crate::Error::msg(format!($fmt))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn macros_and_context() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert!(inner(0).unwrap_err().to_string().contains("positive"));
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
        let with_ctx: Result<usize> = inner(0).context("parsing config");
        let msg = with_ctx.unwrap_err().to_string();
        assert!(msg.starts_with("parsing config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(err.to_string(), "missing field");
        assert_eq!(Some(5u32).context("x").unwrap(), 5);
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        let b = anyhow!("fmt {} {}", 1, 2);
        let e = "boom";
        let c = anyhow!("{e}");
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "fmt 1 2");
        assert_eq!(c.to_string(), "boom");
    }
}
