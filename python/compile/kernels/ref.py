"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in `hsr_attn.py` has its reference here, written as
plainly as possible straight from the paper's definitions:

* ``softmax_attention``        — Definition 1.1.
* ``relu_attention``           — Definition 1.2 (ReLU^alpha with bias b).
* ``masked_softmax_attention`` — Definition B.2 via a padded index layout
  (the serving engine gathers the HSR-reported rows and pads to r_max).
* ``masked_relu_attention``    — the ReLU^alpha counterpart.

pytest (`python/tests/test_kernel.py`) asserts allclose between these and
the Pallas implementations across hypothesis-generated shapes.
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax_attention(q, k, v):
    """Definition 1.1: Softmax(QK^T/sqrt(d)) V.

    q: [m, d], k: [n, d], v: [n, d] -> [m, d]
    """
    d = q.shape[-1]
    scores = q @ k.T / jnp.sqrt(jnp.float32(d))
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return weights @ v


def relu_attention(q, k, v, bias, alpha: int = 1):
    """Definition 1.2: D^{-1} ReLU^alpha(QK^T/sqrt(d) - b) V.

    Zero rows (nothing activated) produce zero output rows, matching the
    rust implementation's convention.
    """
    d = q.shape[-1]
    scores = q @ k.T / jnp.sqrt(jnp.float32(d)) - bias
    act = jnp.maximum(scores, 0.0) ** alpha
    denom = act.sum(axis=-1, keepdims=True)
    safe = jnp.where(denom > 0.0, denom, 1.0)
    out = (act / safe) @ v
    return jnp.where(denom > 0.0, out, 0.0)


def masked_softmax_attention(q, kg, vg, count):
    """Softmax attention over a padded gathered block (Definition B.2).

    q: [m, d]; kg/vg: [m, r_max, d] gathered rows per query; count: [m]
    number of valid rows (rows >= count are padding and must be ignored).
    """
    d = q.shape[-1]
    r_max = kg.shape[1]
    scores = jnp.einsum("md,mrd->mr", q, kg) / jnp.sqrt(jnp.float32(d))
    valid = jnp.arange(r_max)[None, :] < count[:, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    m = scores.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # all-padded row guard
    w = jnp.where(valid, jnp.exp(scores - m), 0.0)
    denom = w.sum(axis=-1, keepdims=True)
    safe = jnp.where(denom > 0.0, denom, 1.0)
    out = jnp.einsum("mr,mrd->md", w / safe, vg)
    return jnp.where(denom > 0.0, out, 0.0)


def masked_relu_attention(q, kg, vg, count, bias, alpha: int = 1):
    """ReLU^alpha attention over a padded gathered block."""
    d = q.shape[-1]
    r_max = kg.shape[1]
    scores = jnp.einsum("md,mrd->mr", q, kg) / jnp.sqrt(jnp.float32(d)) - bias
    valid = jnp.arange(r_max)[None, :] < count[:, None]
    act = jnp.where(valid, jnp.maximum(scores, 0.0) ** alpha, 0.0)
    denom = act.sum(axis=-1, keepdims=True)
    safe = jnp.where(denom > 0.0, denom, 1.0)
    out = jnp.einsum("mr,mrd->md", act / safe, vg)
    return jnp.where(denom > 0.0, out, 0.0)


def causal_softmax_attention(q, k, v):
    """Causal variant used by the transformer (L2): position i attends to
    keys 0..i. q/k/v: [t, d]."""
    t, d = q.shape
    scores = q @ k.T / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return weights @ v
