//! Runtime-dispatched 8-lane f32 micro-kernels.
//!
//! Every inner product, gathered score, weighted accumulation and softmax
//! row in the crate funnels through these entry points. On x86_64 with
//! AVX2+FMA (detected once at first use, cached in an atomic) the wide
//! paths run 8 lanes per instruction with fused multiply-add; everywhere
//! else a portable unrolled scalar path is used. The scalar twins are
//! `pub` so property tests and the before/after kernel benches can pin a
//! path explicitly.
//!
//! Numerical contract: SIMD and scalar paths may differ by float
//! associativity/FMA rounding only (≤ ~1e-6 relative on attention-scale
//! inputs; asserted to 1e-5 in the property tests below). Within one
//! process every call site uses the *same* dispatched path, so exactness
//! arguments that compare two sparse evaluations (e.g. ReLU sparse vs
//! dense) are unaffected.

use std::sync::atomic::{AtomicU8, Ordering};

const UNKNOWN: u8 = 0;
const SCALAR: u8 = 1;
#[cfg(target_arch = "x86_64")]
const AVX2: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(UNKNOWN);

#[inline(always)]
fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != UNKNOWN {
        l
    } else {
        detect()
    }
}

#[cold]
fn detect() -> u8 {
    #[cfg(target_arch = "x86_64")]
    let l = if std::arch::is_x86_64_feature_detected!("avx2")
        && std::arch::is_x86_64_feature_detected!("fma")
    {
        AVX2
    } else {
        SCALAR
    };
    #[cfg(not(target_arch = "x86_64"))]
    let l = SCALAR;
    LEVEL.store(l, Ordering::Relaxed);
    l
}

/// Name of the active dispatch path (for bench reports / diagnostics).
pub fn dispatch_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return "avx2+fma";
    }
    "scalar"
}

/// Force the scalar path on (or restore auto-detection with `false`).
/// Process-global; intended ONLY for single-threaded benches that need a
/// pre-SIMD baseline and for dispatch tests.
pub fn force_scalar(enable: bool) {
    LEVEL.store(if enable { SCALAR } else { UNKNOWN }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// dot
// ---------------------------------------------------------------------------

/// Inner product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // Hard assert: the AVX2 path walks raw pointers over both slices, so
    // a length mismatch would be OOB UB, not a panic, without this.
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// Portable unrolled-by-4 inner product (the pre-SIMD hot loop).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc0 += a[j] * b[j];
        acc1 += a[j + 1] * b[j + 1];
        acc2 += a[j + 2] * b[j + 2];
        acc3 += a[j + 3] * b[j + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for j in chunks * 4..a.len() {
        acc += a[j] * b[j];
    }
    acc
}

// ---------------------------------------------------------------------------
// axpy
// ---------------------------------------------------------------------------

/// out += w * x (equal lengths).
#[inline]
pub fn axpy(out: &mut [f32], x: &[f32], w: f32) {
    // Hard assert: guards the raw-pointer AVX2 store loop (see `dot`).
    assert_eq!(out.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::axpy(out, x, w) };
    }
    axpy_scalar(out, x, w)
}

/// Portable out += w * x.
#[inline]
pub fn axpy_scalar(out: &mut [f32], x: &[f32], w: f32) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += w * v;
    }
}

// ---------------------------------------------------------------------------
// blocked dense scoring
// ---------------------------------------------------------------------------

/// Dense scoring: out[j] = <q, keys[j]> * scale for j in 0..out.len().
/// The AVX2 path processes key rows in blocks of 4 sharing each 8-lane
/// load of q (the "blocked" kernel the dense scan and brute HSR use).
#[inline]
pub fn scaled_dots_into(q: &[f32], keys: &[f32], d: usize, scale: f32, out: &mut [f32]) {
    // Hard asserts (once per call): the AVX2 path walks raw pointers over
    // `q` and all key rows, so these bounds are the only OOB guard.
    assert!(keys.len() >= out.len() * d);
    assert_eq!(q.len(), d);
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::scaled_dots_into(q, keys, d, scale, out) };
    }
    scaled_dots_into_scalar(q, keys, d, scale, out)
}

/// Portable dense scoring.
#[inline]
pub fn scaled_dots_into_scalar(q: &[f32], keys: &[f32], d: usize, scale: f32, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot_scalar(q, &keys[j * d..(j + 1) * d]) * scale;
    }
}

// ---------------------------------------------------------------------------
// gathered subset scoring
// ---------------------------------------------------------------------------

/// Gathered scoring: out[t] = <q, keys[idx[t]]> * scale. `out` must be
/// caller-sized to idx.len() — the attention layer's `sized_scores`
/// helper is the canonical way to do that, so every scoring entry point
/// shares one buffer convention.
#[inline]
pub fn gathered_scaled_dots_into(
    q: &[f32],
    keys: &[f32],
    d: usize,
    idx: &[u32],
    scale: f32,
    out: &mut [f32],
) {
    // Hard asserts: each gathered row has length d; the AVX2 dot walks
    // raw pointers over q as well, so q must match exactly.
    assert_eq!(q.len(), d);
    assert_eq!(out.len(), idx.len());
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        for (o, &j) in out.iter_mut().zip(idx) {
            let j = j as usize;
            *o = unsafe { x86::dot(q, &keys[j * d..(j + 1) * d]) } * scale;
        }
        return;
    }
    gathered_scaled_dots_into_scalar(q, keys, d, idx, scale, out)
}

/// Portable gathered scoring (same caller-sized slice convention).
#[inline]
pub fn gathered_scaled_dots_into_scalar(
    q: &[f32],
    keys: &[f32],
    d: usize,
    idx: &[u32],
    scale: f32,
    out: &mut [f32],
) {
    assert_eq!(out.len(), idx.len());
    for (o, &j) in out.iter_mut().zip(idx) {
        let j = j as usize;
        *o = dot_scalar(q, &keys[j * d..(j + 1) * d]) * scale;
    }
}

// ---------------------------------------------------------------------------
// max / fused softmax row
// ---------------------------------------------------------------------------

/// Maximum element (NEG_INFINITY for an empty slice).
#[inline]
pub fn max(xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::max(xs) };
    }
    max_scalar(xs)
}

/// Portable maximum element.
#[inline]
pub fn max_scalar(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// Fused stable-softmax row primitive: finds the max (SIMD), replaces each
/// score with exp(score − max) **in place** (caching the exps so the
/// weighted-sum pass never recomputes them), and returns the sum of exps.
/// Returns 0.0 for an empty slice.
///
/// On AVX2 the exp itself is vectorized: an 8-lane Cody–Waite range
/// reduction + degree-6 polynomial (Cephes `expf` coefficients, ~2 ulp),
/// with the exact-same-polynomial scalar tail for the remainder lanes.
/// Inputs to the exp are max-subtracted and therefore ≤ 0, where the
/// polynomial path and libm agree to ulp scale (asserted in tests).
#[inline]
pub fn softmax_exp_in_place(scores: &mut [f32]) -> f32 {
    if scores.is_empty() {
        return 0.0;
    }
    let m = max(scores);
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::exp_sub_in_place_sum(scores, m) };
    }
    exp_sub_in_place_sum_scalar(scores, m)
}

/// Scalar twin of [`softmax_exp_in_place`] (libm exp — the pre-SIMD path
/// and the reference the vectorized polynomial is tested against).
#[inline]
pub fn softmax_exp_in_place_scalar(scores: &mut [f32]) -> f32 {
    if scores.is_empty() {
        return 0.0;
    }
    let m = max_scalar(scores);
    exp_sub_in_place_sum_scalar(scores, m)
}

/// Σ exp(x_i − m) without storing the exps — the logsumexp building
/// block (perplexity, sampling head). Vectorized like
/// [`softmax_exp_in_place`]; `m` must be the slice max (inputs ≤ 0 after
/// subtraction) for the polynomial-range contract to hold.
#[inline]
pub fn exp_sum(xs: &[f32], m: f32) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if level() == AVX2 {
        return unsafe { x86::exp_sum(xs, m) };
    }
    exp_sum_scalar(xs, m)
}

/// Scalar twin of [`exp_sum`].
#[inline]
pub fn exp_sum_scalar(xs: &[f32], m: f32) -> f32 {
    xs.iter().map(|&x| (x - m).exp()).sum()
}

/// s_i ← exp(s_i − m), returning Σ exp(s_i − m); portable libm path.
#[inline]
fn exp_sub_in_place_sum_scalar(scores: &mut [f32], m: f32) -> f32 {
    let mut sum = 0.0f32;
    for s in scores.iter_mut() {
        let e = (*s - m).exp();
        *s = e;
        sum += e;
    }
    sum
}

// ---------------------------------------------------------------------------
// x86_64 AVX2+FMA paths
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0x55>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hmax256(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let m = _mm_max_ps(lo, hi);
        let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
        let m = _mm_max_ss(m, _mm_shuffle_ps::<0x55>(m, m));
        _mm_cvtss_f32(m)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i)),
                _mm256_loadu_ps(bp.add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i)),
                _mm256_loadu_ps(bp.add(i)),
                acc0,
            );
            i += 8;
        }
        let mut acc = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            acc += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        acc
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(out: &mut [f32], x: &[f32], w: f32) {
        let n = out.len();
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let vw = _mm256_set1_ps(w);
        let mut i = 0usize;
        while i + 8 <= n {
            let vo = _mm256_loadu_ps(op.add(i));
            let vx = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(op.add(i), _mm256_fmadd_ps(vw, vx, vo));
            i += 8;
        }
        while i < n {
            *op.add(i) += w * *xp.add(i);
            i += 1;
        }
    }

    /// Blocked dense scoring: 4 key rows per outer step share each 8-lane
    /// load of q, quadrupling FMA throughput per load.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scaled_dots_into(
        q: &[f32],
        keys: &[f32],
        d: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        let n = out.len();
        let qp = q.as_ptr();
        let kp = keys.as_ptr();
        let mut j = 0usize;
        while j + 4 <= n {
            let r0 = kp.add(j * d);
            let r1 = kp.add((j + 1) * d);
            let r2 = kp.add((j + 2) * d);
            let r3 = kp.add((j + 3) * d);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut i = 0usize;
            while i + 8 <= d {
                let vq = _mm256_loadu_ps(qp.add(i));
                a0 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r0.add(i)), a0);
                a1 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r1.add(i)), a1);
                a2 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r2.add(i)), a2);
                a3 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r3.add(i)), a3);
                i += 8;
            }
            let mut s0 = hsum256(a0);
            let mut s1 = hsum256(a1);
            let mut s2 = hsum256(a2);
            let mut s3 = hsum256(a3);
            while i < d {
                let qv = *qp.add(i);
                s0 += qv * *r0.add(i);
                s1 += qv * *r1.add(i);
                s2 += qv * *r2.add(i);
                s3 += qv * *r3.add(i);
                i += 1;
            }
            *out.get_unchecked_mut(j) = s0 * scale;
            *out.get_unchecked_mut(j + 1) = s1 * scale;
            *out.get_unchecked_mut(j + 2) = s2 * scale;
            *out.get_unchecked_mut(j + 3) = s3 * scale;
            j += 4;
        }
        while j < n {
            *out.get_unchecked_mut(j) = dot(q, &keys[j * d..(j + 1) * d]) * scale;
            j += 1;
        }
    }

    // ---- vectorized exp (Cephes expf): 8 lanes per iteration ----
    //
    // exp(x) = 2^k · exp(r),  k = floor(x·log2 e + ½),  r = x − k·ln 2
    // (ln 2 split Cody–Waite style into C1 + C2 so the reduction is
    // single-rounding under FMA), exp(r) via a degree-6 polynomial.
    // Inputs are clamped to ±88.376; softmax feeds max-subtracted
    // (≤ 0) values, where underflow collapses to +0 exactly like libm
    // up to denormals (absolute error < 1e-38).

    const EXP_HI: f32 = 88.376_26;
    const EXP_LO: f32 = -88.376_26;
    const LOG2EF: f32 = 1.442_695_04;
    const LN2_HI: f32 = 0.693_359_375;
    const LN2_LO: f32 = -2.121_944_4e-4;
    const P0: f32 = 1.987_569_1e-4;
    const P1: f32 = 1.398_199_9e-3;
    const P2: f32 = 8.333_452e-3;
    const P3: f32 = 4.166_579_6e-2;
    const P4: f32 = 1.666_666_5e-1;
    const P5: f32 = 5.000_000_1e-1;

    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp256(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(EXP_HI));
        let x = _mm256_max_ps(x, _mm256_set1_ps(EXP_LO));
        let fx = _mm256_floor_ps(_mm256_fmadd_ps(
            x,
            _mm256_set1_ps(LOG2EF),
            _mm256_set1_ps(0.5),
        ));
        let x = _mm256_fnmadd_ps(fx, _mm256_set1_ps(LN2_HI), x);
        let x = _mm256_fnmadd_ps(fx, _mm256_set1_ps(LN2_LO), x);
        let z = _mm256_mul_ps(x, x);
        let mut y = _mm256_set1_ps(P0);
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(P1));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(P2));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(P3));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(P4));
        y = _mm256_fmadd_ps(y, x, _mm256_set1_ps(P5));
        y = _mm256_fmadd_ps(y, z, x);
        y = _mm256_add_ps(y, _mm256_set1_ps(1.0));
        // 2^fx by exponent-field construction (fx ∈ [-127, 127] after
        // the clamp, so the biased exponent stays in range).
        let n = _mm256_cvttps_epi32(fx);
        let n = _mm256_add_epi32(n, _mm256_set1_epi32(0x7f));
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(n));
        _mm256_mul_ps(y, pow2)
    }

    /// Scalar lane of the same polynomial (remainder elements), kept
    /// bit-compatible with `exp256` via fused mul-adds.
    #[target_feature(enable = "fma")]
    unsafe fn exp1(x: f32) -> f32 {
        let x = x.clamp(EXP_LO, EXP_HI);
        let fx = x.mul_add(LOG2EF, 0.5).floor();
        let x = (-fx).mul_add(LN2_HI, x);
        let x = (-fx).mul_add(LN2_LO, x);
        let z = x * x;
        let mut y = P0;
        y = y.mul_add(x, P1);
        y = y.mul_add(x, P2);
        y = y.mul_add(x, P3);
        y = y.mul_add(x, P4);
        y = y.mul_add(x, P5);
        y = y.mul_add(z, x) + 1.0;
        let n = (fx as i32 + 0x7f) << 23;
        y * f32::from_bits(n as u32)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_sub_in_place_sum(scores: &mut [f32], m: f32) -> f32 {
        let n = scores.len();
        let p = scores.as_mut_ptr();
        let vm = _mm256_set1_ps(m);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let e = exp256(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), vm));
            _mm256_storeu_ps(p.add(i), e);
            acc = _mm256_add_ps(acc, e);
            i += 8;
        }
        let mut sum = hsum256(acc);
        while i < n {
            let e = exp1(*p.add(i) - m);
            *p.add(i) = e;
            sum += e;
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_sum(xs: &[f32], m: f32) -> f32 {
        let n = xs.len();
        let p = xs.as_ptr();
        let vm = _mm256_set1_ps(m);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            acc = _mm256_add_ps(acc, exp256(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), vm)));
            i += 8;
        }
        let mut sum = hsum256(acc);
        while i < n {
            sum += exp1(*p.add(i) - m);
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn max(xs: &[f32]) -> f32 {
        let n = xs.len();
        if n == 0 {
            return f32::NEG_INFINITY;
        }
        let xp = xs.as_ptr();
        let mut i = 0usize;
        let mut m = f32::NEG_INFINITY;
        if n >= 8 {
            let mut acc = _mm256_loadu_ps(xp);
            i = 8;
            while i + 8 <= n {
                acc = _mm256_max_ps(acc, _mm256_loadu_ps(xp.add(i)));
                i += 8;
            }
            m = hmax256(acc);
        }
        while i < n {
            m = m.max(*xp.add(i));
            i += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    fn close(got: f32, want: f64, len: usize) -> bool {
        // 1e-5 relative to the magnitude scale of a length-`len` Gaussian
        // inner product; absolute floor covers near-cancellation cases.
        let scale = 1.0 + want.abs() + (len as f64).sqrt();
        ((got as f64) - want).abs() < 1e-5 * scale
    }

    /// SIMD and scalar dot agree to 1e-5 on random lengths, covering every
    /// remainder-lane count 0–7 and the 16/8-stride main loops.
    #[test]
    fn dot_simd_matches_scalar_all_remainders() {
        let mut rng = Rng::new(71);
        let mut lens: Vec<usize> = (0..=40).collect();
        lens.extend([63, 64, 65, 127, 128, 129, 1000]);
        for &len in &lens {
            let a = rng.gaussian_vec_f32(len, 1.0);
            let b = rng.gaussian_vec_f32(len, 1.0);
            let want = naive_dot(&a, &b);
            let simd = dot(&a, &b);
            let scalar = dot_scalar(&a, &b);
            assert!(close(simd, want, len), "simd len={len}: {simd} vs {want}");
            assert!(close(scalar, want, len), "scalar len={len}");
            assert!(
                (simd - scalar).abs() < 1e-5 * (1.0 + scalar.abs() + (len as f32).sqrt()),
                "len={len}: simd {simd} scalar {scalar}"
            );
        }
    }

    #[test]
    fn axpy_simd_matches_scalar() {
        let mut rng = Rng::new(72);
        for len in [0usize, 1, 5, 7, 8, 9, 16, 31, 64, 100] {
            let x = rng.gaussian_vec_f32(len, 1.0);
            let base = rng.gaussian_vec_f32(len, 1.0);
            let w = rng.normal(0.0, 2.0) as f32;
            let mut a = base.clone();
            let mut b = base.clone();
            axpy(&mut a, &x, w);
            axpy_scalar(&mut b, &x, w);
            for i in 0..len {
                assert!((a[i] - b[i]).abs() < 1e-5, "len={len} i={i}");
            }
        }
    }

    #[test]
    fn scaled_dots_simd_matches_scalar() {
        let mut rng = Rng::new(73);
        for &(n, d) in &[(0usize, 4usize), (1, 3), (3, 8), (4, 16), (5, 7), (17, 64), (33, 11)] {
            let q = rng.gaussian_vec_f32(d, 1.0);
            let keys = rng.gaussian_vec_f32(n * d, 1.0);
            let scale = 1.0 / (d as f32).sqrt();
            let mut simd = vec![0f32; n];
            let mut scalar = vec![0f32; n];
            scaled_dots_into(&q, &keys, d, scale, &mut simd);
            scaled_dots_into_scalar(&q, &keys, d, scale, &mut scalar);
            for j in 0..n {
                let tol = 1e-5 * (1.0 + scalar[j].abs() + (d as f32).sqrt());
                assert!((simd[j] - scalar[j]).abs() < tol, "n={n} d={d} j={j}");
            }
        }
    }

    #[test]
    fn gathered_dots_match_dense() {
        let mut rng = Rng::new(74);
        let (n, d) = (50usize, 13usize);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let keys = rng.gaussian_vec_f32(n * d, 1.0);
        let scale = 0.25f32;
        let mut dense = vec![0f32; n];
        scaled_dots_into(&q, &keys, d, scale, &mut dense);
        let idx: Vec<u32> = (0..n as u32).step_by(3).collect();
        let mut gathered = vec![0f32; idx.len()];
        gathered_scaled_dots_into(&q, &keys, d, &idx, scale, &mut gathered);
        let mut gathered_sc = vec![0f32; idx.len()];
        gathered_scaled_dots_into_scalar(&q, &keys, d, &idx, scale, &mut gathered_sc);
        for (t, &j) in idx.iter().enumerate() {
            assert!((gathered[t] - dense[j as usize]).abs() < 1e-5);
            assert!((gathered[t] - gathered_sc[t]).abs() < 1e-5);
        }
    }

    #[test]
    fn max_simd_matches_scalar() {
        let mut rng = Rng::new(75);
        assert_eq!(max(&[]), f32::NEG_INFINITY);
        for len in [1usize, 2, 7, 8, 9, 15, 16, 17, 100] {
            let xs = rng.gaussian_vec_f32(len, 3.0);
            assert_eq!(max(&xs), max_scalar(&xs), "len={len}");
        }
    }

    #[test]
    fn softmax_exp_in_place_matches_two_pass() {
        let mut rng = Rng::new(76);
        for len in [0usize, 1, 5, 8, 13, 64, 200] {
            let scores = rng.gaussian_vec_f32(len, 2.0);
            let m = max_scalar(&scores);
            let want_denom: f32 = scores.iter().map(|&s| (s - m).exp()).sum();
            let mut cached = scores.clone();
            let denom = softmax_exp_in_place(&mut cached);
            let mut cached_sc = scores.clone();
            let denom_sc = softmax_exp_in_place_scalar(&mut cached_sc);
            if len == 0 {
                assert_eq!(denom, 0.0);
                continue;
            }
            assert!((denom - want_denom).abs() < 1e-4 * (1.0 + want_denom.abs()));
            assert!((denom - denom_sc).abs() < 1e-4 * (1.0 + want_denom.abs()));
            for i in 0..len {
                assert!((cached[i] - (scores[i] - m).exp()).abs() < 1e-6);
            }
        }
    }

    /// Dispatched (vector-polynomial on AVX2) exp vs scalar libm exp:
    /// agreement to ulp-scale relative tolerance on adversarial rows —
    /// large negatives (underflow edge), all-equal rows (exp(0) must be
    /// exactly 1), single-element rows, and every remainder-lane count.
    #[test]
    fn simd_exp_matches_scalar_exp_adversarial() {
        let rel = 1e-6f32; // ~8 ulp headroom over the ~2 ulp polynomial
        let abs = 1e-30f32; // underflow-to-denormal region
        let rows: Vec<Vec<f32>> = vec![
            vec![0.0],                          // single element
            vec![-3.25],                        // single element, nonzero
            vec![2.5; 17],                      // all-equal (exps all 1)
            vec![-1e4, -500.0, -104.0, -87.4, -86.9, -20.0, 0.0], // deep negatives
            (0..100).map(|i| -(i as f32) * 1.7).collect(),
            (0..9).map(|i| (i as f32) * 0.111 - 0.5).collect(),
        ];
        // Every remainder count 0..7 around the 8-lane stride.
        let mut rng = Rng::new(77);
        let mut all = rows;
        for len in 1usize..=40 {
            all.push(rng.gaussian_vec_f32(len, 30.0));
        }
        for scores in &all {
            let mut simd_row = scores.clone();
            let mut scalar_row = scores.clone();
            let denom = softmax_exp_in_place(&mut simd_row);
            let denom_sc = softmax_exp_in_place_scalar(&mut scalar_row);
            assert!(
                (denom - denom_sc).abs() <= rel * denom_sc.abs() * 4.0 + abs,
                "denom {denom} vs {denom_sc} (len {})",
                scores.len()
            );
            for (i, (&a, &b)) in simd_row.iter().zip(&scalar_row).enumerate() {
                assert!(
                    (a - b).abs() <= rel * b.abs() + abs,
                    "len {} elem {i}: {a} vs {b}",
                    scores.len()
                );
            }
            // All-equal / max elements must be exactly 1.
            let m = max_scalar(scores);
            for (i, &s) in scores.iter().enumerate() {
                if s == m {
                    assert_eq!(simd_row[i], 1.0, "exp(0) must be exact");
                }
            }
            // exp_sum agrees with the in-place kernel's denominator and
            // with its own scalar twin.
            let es = exp_sum(scores, m);
            let es_sc = exp_sum_scalar(scores, m);
            assert!((es - es_sc).abs() <= rel * es_sc.abs() * 4.0 + abs);
            assert!((es - denom).abs() <= rel * denom.abs() * 4.0 + abs);
        }
    }

    // NOTE: `force_scalar` is deliberately not exercised here — cargo
    // runs tests concurrently and flipping the process-global dispatch
    // mid-run would race the exact-equality assertions of other tests.
    // The single-threaded bench binary is its only intended caller.
    #[test]
    fn dispatch_reports_a_known_path() {
        let name = dispatch_name();
        assert!(name == "avx2+fma" || name == "scalar", "unexpected: {name}");
    }
}
