//! Request/response types of the serving engine.

use std::sync::Arc;
use std::time::Instant;

use super::stream::StreamSink;

/// Unique request id.
pub type RequestId = u64;

/// Sampling / generation parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenerationParams {
    pub max_new_tokens: usize,
    /// 0.0 → greedy.
    pub temperature: f32,
    /// Stop at this token if produced (byte value); None → length only.
    pub stop_token: Option<u32>,
    /// Absolute deadline. A sequence past it is aborted mid-decode
    /// (blocks and chain refs released) with
    /// [`FinishReason::DeadlineExceeded`]; None → no deadline.
    pub deadline: Option<Instant>,
}

impl Default for GenerationParams {
    fn default() -> Self {
        GenerationParams {
            max_new_tokens: 64,
            temperature: 0.0,
            stop_token: None,
            deadline: None,
        }
    }
}

/// An inbound generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    /// Times this request has been re-dispatched after a worker failure
    /// (bounds the supervision retry budget).
    pub attempts: u32,
    /// Per-token delivery channel for streaming requests; `None` for
    /// buffered (whole-response) requests. The engine pushes every
    /// sampled token; overruns sever the stream (slow-consumer shed)
    /// without ever blocking decode.
    pub stream: Option<Arc<StreamSink>>,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    StopToken,
    /// Engine shut down before completion.
    Aborted,
    /// Past its client-supplied deadline ("deadline" on the wire).
    DeadlineExceeded,
    /// Explicitly cancelled, e.g. the client disconnected.
    Cancelled,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall time from submission to completion.
    pub latency_ms: f64,
    /// Time to first generated token.
    pub ttft_ms: f64,
    pub prompt_len: usize,
}

/// Engine-internal sequence state.
pub(crate) struct Sequence {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    pub generated: Vec<u32>,
    /// Private KV tail: everything past the adopted shared prefix (the
    /// whole cache when `prefix` is empty).
    pub kv: crate::model::kv::KvState,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    /// Blocks held in the cache pool **for the private tail** — shared
    /// prefix segments hold their own blocks, refcounted in the store.
    pub blocks: Vec<u32>,
    /// Number of prompt tokens already prefilled (chunked prefill
    /// cursor); tokens below `prefix_len` were adopted, not computed.
    pub prefilled: usize,
    /// Generated tokens already folded back into `prompt` by a previous
    /// preemption/shed (recompute re-feeds them); folding only the
    /// suffix past this cursor keeps a twice-preempted sequence from
    /// duplicating its early generations in the prompt.
    pub folded: usize,
    /// Adopted shared-prefix chain (radix node ids; one reference held
    /// on each node until finish/preemption).
    pub prefix: Vec<crate::kvstore::NodeId>,
    /// Tokens covered by `prefix` (the tail starts at this position).
    pub prefix_len: usize,
    /// Submission order; lower = older. Preemption only ever evicts
    /// strictly-younger sequences, which guarantees scheduler progress.
    pub priority: u64,
    /// Re-dispatch count inherited from the [`Request`] (see
    /// `Request::attempts`).
    pub attempts: u32,
    /// Streaming channel inherited from the [`Request`]. Tokens are
    /// pushed exactly once each at sample time; preemption re-feeds
    /// folded tokens through prefill without re-pushing them, so the
    /// wire sequence stays contiguous across preemptions.
    pub stream: Option<Arc<StreamSink>>,
}

impl Sequence {
    /// Total tokens this sequence attends over: shared prefix + tail.
    /// (Diagnostics; block accounting uses [`Sequence::tail_tokens`].)
    #[allow(dead_code)]
    pub fn cached_tokens(&self) -> usize {
        self.prefix_len + self.kv.len()
    }

    /// Tokens in the private tail — what this sequence's own blocks
    /// must cover, and what preempting it would free.
    pub fn tail_tokens(&self) -> usize {
        self.kv.len()
    }

    /// Next token to feed: prompt remainder, else last generated.
    pub fn done(&self) -> bool {
        self.generated.len() >= self.params.max_new_tokens
    }
}
