"""L2 correctness: transformer shapes, decode-step/prefill parity, RoPE
properties, and that a short training run actually reduces loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import train as T


@pytest.fixture(scope="module")
def small_setup():
    cfg = M.CONFIGS["mini"]
    params = M.init_params(cfg, 3)
    tokens = jnp.asarray(D.eval_document(5, 48).astype(np.int32))
    return cfg, params, tokens


def test_forward_shapes(small_setup):
    cfg, params, tokens = small_setup
    logits = M.forward(params, cfg, tokens)
    assert logits.shape == (48, M.VOCAB_SIZE)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_matches_forward(small_setup):
    cfg, params, tokens = small_setup
    full = M.forward(params, cfg, tokens)
    pre, k_cache, v_cache = M.prefill(params, cfg, tokens)
    np.testing.assert_allclose(pre, full, atol=1e-5, rtol=1e-4)
    assert k_cache.shape == (cfg.n_layers, cfg.n_heads, 48, cfg.d_head)
    assert v_cache.shape == k_cache.shape


@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_step_matches_forward(small_setup, use_pallas):
    """Autoregressive decode with a KV cache reproduces the full forward
    logits at every step — the invariant Algorithm 1 relies on."""
    cfg, params, tokens = small_setup
    t = 16
    n_ctx = 32
    full = M.forward(params, cfg, tokens[:t])
    k_cache = jnp.zeros((cfg.n_layers, cfg.n_heads, n_ctx, cfg.d_head))
    v_cache = jnp.zeros_like(k_cache)
    for pos in range(t):
        logits, new_k, new_v = M.decode_step(
            params, cfg, tokens[pos], jnp.asarray(pos), k_cache, v_cache,
            use_pallas=use_pallas,
        )
        np.testing.assert_allclose(
            logits, full[pos], atol=2e-4, rtol=1e-3,
            err_msg=f"pos={pos} pallas={use_pallas}",
        )
        k_cache = k_cache.at[:, :, pos, :].set(new_k)
        v_cache = v_cache.at[:, :, pos, :].set(new_v)


def test_rope_preserves_norm_and_relative_property(small_setup):
    cfg, _, _ = small_setup
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(cfg.d_head,)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(cfg.d_head,)), jnp.float32)
    # Norm preservation (rotation).
    for pos in [0, 3, 77]:
        rx = M.apply_rope(x, jnp.asarray(pos))
        assert abs(float(jnp.linalg.norm(rx) - jnp.linalg.norm(x))) < 1e-4
    # Relative property: <R_p x, R_q y> depends only on p - q.
    a = float(M.apply_rope(x, jnp.asarray(5)) @ M.apply_rope(y, jnp.asarray(2)))
    b = float(M.apply_rope(x, jnp.asarray(13)) @ M.apply_rope(y, jnp.asarray(10)))
    assert abs(a - b) < 1e-3


def test_rope_at_zero_is_identity(small_setup):
    cfg, _, _ = small_setup
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(cfg.d_head,)), jnp.float32)
    np.testing.assert_allclose(M.apply_rope(x, jnp.asarray(0)), x, atol=1e-6)


def test_loss_decreases_with_training():
    cfg = M.CONFIGS["mini"]
    _, losses = T.train(
        cfg, seed=11, steps=25, seq_len=64, batch_size=8,
        corpus_bytes=40_000, log_every=100,
    )
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    assert last < first - 0.5, f"no learning: {first:.3f} -> {last:.3f}"
    # Byte-level uniform is ln(256) ≈ 5.55; must start near it.
    assert 4.5 < losses[0] < 7.0


def test_param_count_formula():
    cfg = M.CONFIGS["small"]
    params = M.init_params(cfg, 0)
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == cfg.param_count()


def test_corpus_properties():
    c = D.corpus_bytes(0, 50_000)
    assert len(c) == 50_000
    assert c.dtype == np.uint8
    # ASCII text only.
    assert int(c.max()) < 128
    # Deterministic.
    assert np.array_equal(c, D.corpus_bytes(0, 50_000))
    # Needles present.
    text = bytes(c).decode("ascii")
    assert "remember:" in text and "token is" in text


def test_batches_are_next_byte_shifted():
    c = D.corpus_bytes(1, 10_000)
    for x, y in D.batches(c, seq_len=16, batch_size=4, steps=3, seed=0):
        assert x.shape == (4, 16) and y.shape == (4, 16)
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])
