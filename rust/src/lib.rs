//! # hsr-attn — HSR-Enhanced Sparse Attention Acceleration
//!
//! A production-shaped reproduction of *"HSR-Enhanced Sparse Attention
//! Acceleration"* (Chen, Liang, Sha, Shi, Song; 2024): half-space
//! reporting (HSR) data structures used to identify the activated /
//! "massively activated" entries of ReLU^α and Softmax attention, wrapped
//! in a continuous-batching serving engine.
//!
//! Layer map (see DESIGN.md):
//! * [`kernel`] — runtime-dispatched SIMD micro-kernels and the
//!   per-thread scratch arena every hot path above is built on.
//! * [`hsr`] — the HSR substrate (Algorithm 3, Corollary 3.1), including
//!   the batched multi-query entry point that answers a whole query
//!   block in one shared traversal.
//! * [`attention`] — ReLU^α / Softmax attention math, thresholds
//!   (Lemma 6.1), top-r selection (Definition B.2), error bounds
//!   (Theorem 4.3), and the **unified session API**
//!   ([`attention::AttentionConfig`] → [`attention::AttentionSession`] →
//!   plan/execute) every engine path is a thin caller of.
//! * [`engine`] — Algorithm 1 (generation decoding) and Algorithm 2
//!   (prompt prefilling) integrated with a paged KV cache, a
//!   continuous-batching scheduler and a request router.
//! * [`kvstore`] — the shared-prefix KV store: a refcounted radix
//!   prefix cache over block-paged segments with copy-on-write forks,
//!   so sequences with a common prompt share one payload and one HSR
//!   index per (layer, head) — and decode as one query block.
//! * [`model`] — the native transformer forward used by the serving hot
//!   path (weights trained & exported by the Python build step).
//! * [`runtime`] — PJRT CPU client executing the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py`.
//! * [`workloads`] — the paper's Gaussian / massive-activation workload
//!   generators and serving traces.
//! * [`bench`] — the micro-benchmark harness used by `cargo bench`.

// Tolerate older clippy versions that do not know newer lint names, and
// keep the crate's pervasive `(a + b - 1) / b` sharding arithmetic —
// `div_ceil` is not available on the oldest toolchains this crate
// supports, so the manual form is intentional.
#![allow(unknown_lints)]
#![allow(clippy::manual_div_ceil)]

pub mod attention;
pub mod bench;
pub mod engine;
pub mod hsr;
pub mod kernel;
pub mod kvstore;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workloads;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
