//! The paper's threshold machinery (Lemma 6.1 / E.3) and the analytic
//! sparsity predictions behind Table 1.
//!
//! For Q with entries ~ N(0, σ_q²) and K with entries ~ N(0, σ_k²):
//!   σ_a = 4 · (1 + d^{-1}·log(m/δ))^{1/2} · σ_q σ_k        (Lemma E.3)
//!   b   = σ_a · sqrt(0.4 · log n)
//! and with probability ≥ 1 − δ every row of the attention matrix has at
//! most 2·n^{4/5} activated entries. The derivation sets
//!   E[k̃_i] ≤ n · exp(−b²/(2σ_a²)) = n · n^{-0.2} = n^{4/5},
//! so `log` throughout is the natural logarithm.

/// Parameters of the Lemma 6.1 threshold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThresholdParams {
    /// Query entry standard deviation σ_q.
    pub sigma_q: f64,
    /// Key entry standard deviation σ_k.
    pub sigma_k: f64,
    /// Head dimension d.
    pub d: usize,
    /// Number of query rows m (union-bounded over).
    pub m: usize,
    /// Failure probability δ.
    pub delta: f64,
}

impl ThresholdParams {
    /// Standard workload: unit-variance Q/K, failure probability 1%.
    pub fn standard(d: usize, m: usize) -> ThresholdParams {
        ThresholdParams { sigma_q: 1.0, sigma_k: 1.0, d, m, delta: 0.01 }
    }

    /// σ_a = 4 (1 + d^{-1} ln(m/δ))^{1/2} σ_q σ_k  (Lemma E.3).
    pub fn sigma_a(&self) -> f64 {
        4.0 * (1.0 + (self.m as f64 / self.delta).ln() / self.d as f64).sqrt()
            * self.sigma_q
            * self.sigma_k
    }

    /// b = σ_a · sqrt(0.4 ln n)  (Lemma 6.1). This is the threshold on the
    /// *scaled* score <q,k>/sqrt(d).
    pub fn bias(&self, n: usize) -> f64 {
        assert!(n >= 2, "threshold undefined for n < 2");
        self.sigma_a() * (0.4 * (n as f64).ln()).sqrt()
    }

    /// The whp row bound of Lemma 6.1: 2 n^{4/5}.
    pub fn row_bound(&self, n: usize) -> f64 {
        2.0 * (n as f64).powf(0.8)
    }

    /// The *practical* threshold: Lemma 6.1's b with the per-row
    /// concentration value σ_a ≈ σ_q σ_k (i.e. without the factor
    /// 4·(1 + d⁻¹ln(m/δ))^{1/2} worst-case inflation of Lemma E.2).
    /// The paper's inflated σ_a makes b an ~8σ event on realistic sizes —
    /// sound for the upper bound, but it deactivates *every* entry. With
    /// this σ_a the expected activation is exactly the n^{4/5} the paper's
    /// Table 1 reports; the Lemma 6.1 bound still holds a fortiori.
    pub fn practical_bias(&self, n: usize) -> f64 {
        assert!(n >= 2);
        self.sigma_q * self.sigma_k * (0.4 * (n as f64).ln()).sqrt()
    }

    /// Expected activated entries n·exp(−b²/(2σ_a²)) for an arbitrary b
    /// (Lemma E.1), with σ_a taken from these params.
    pub fn expected_activated(&self, n: usize, b: f64) -> f64 {
        let sa = self.sigma_a();
        n as f64 * (-b * b / (2.0 * sa * sa)).exp()
    }
}

/// One Table-1 row: context length, analytic activated entries (n^{4/5}),
/// and sparsity ratio 1 − n^{4/5}/n = 1 − n^{-1/5}.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparsityRow {
    pub n: usize,
    pub activated: f64,
    pub sparsity: f64,
}

/// Regenerate the analytic Table 1 for the given context lengths. The
/// paper's own table is this computation at n = 1k … 1024k ("Our approach
/// activates merely n^{4/5} entries per inference").
pub fn sparsity_table(ns: &[usize]) -> Vec<SparsityRow> {
    ns.iter()
        .map(|&n| {
            let activated = (n as f64).powf(0.8);
            SparsityRow { n, activated, sparsity: 1.0 - activated / n as f64 }
        })
        .collect()
}

/// Calibrate a threshold b so the expected report size is `target`
/// entries, inverting Lemma E.1: b = σ_a · sqrt(2 ln(n/target)).
/// This is how Theorems 4.2/5.2 "choose b such that R = NN(n^{4/5},q,K)"
/// is realized for distributions where σ_a is known.
pub fn bias_for_target(params: &ThresholdParams, n: usize, target: f64) -> f64 {
    assert!(target > 0.0 && (target as f64) <= n as f64);
    let sa = params.sigma_a();
    sa * (2.0 * (n as f64 / target).ln()).max(0.0).sqrt()
}

/// Like [`bias_for_target`] but with the *practical* (uninflated) score
/// deviation σ_a ≈ σ_q σ_k, which matches the realized score distribution
/// instead of its whp upper bound — this is the calibration the engine and
/// benches use to actually hit a ~`target`-sized report.
pub fn practical_bias_for_target(params: &ThresholdParams, n: usize, target: f64) -> f64 {
    assert!(target > 0.0 && target <= n as f64);
    params.sigma_q * params.sigma_k * (2.0 * (n as f64 / target).ln()).max(0.0).sqrt()
}

/// Empirical quantile calibration: given a sample of scaled scores from
/// the live distribution, choose b as the quantile that reports ~target
/// of n entries. Used by the engine for *trained* (non-Gaussian) keys.
pub fn bias_from_sample(sample_scores: &mut [f32], n: usize, target: usize) -> f32 {
    assert!(!sample_scores.is_empty());
    let frac = (target as f64 / n as f64).clamp(0.0, 1.0);
    let keep = ((sample_scores.len() as f64) * frac).round() as usize;
    let keep = keep.clamp(1, sample_scores.len());
    // b = the keep-th largest sample score.
    sample_scores.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    sample_scores[keep - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::count_activated;
    use crate::util::rng::Rng;

    #[test]
    fn sigma_a_formula() {
        let p = ThresholdParams { sigma_q: 1.0, sigma_k: 1.0, d: 64, m: 1, delta: 1.0 };
        // ln(1/1) = 0 → σ_a = 4.
        assert!((p.sigma_a() - 4.0).abs() < 1e-12);
        let p2 = ThresholdParams { sigma_q: 2.0, sigma_k: 3.0, ..p };
        assert!((p2.sigma_a() - 24.0).abs() < 1e-12);
    }

    #[test]
    fn expected_activated_at_lemma_threshold_is_n_to_4_5() {
        let p = ThresholdParams::standard(64, 1);
        for n in [1024usize, 65536, 1 << 20] {
            let b = p.bias(n);
            let expect = p.expected_activated(n, b);
            let target = (n as f64).powf(0.8);
            assert!(
                (expect / target - 1.0).abs() < 1e-9,
                "n={n} expect={expect} target={target}"
            );
        }
    }

    #[test]
    fn table1_matches_paper_values() {
        // Paper Table 1: n=1k → 251 activated (0.75), n=1024k → 64304 (0.94).
        // The paper's entries are ⌈n^{4/5}⌉-ish with n in binary units.
        let rows = sparsity_table(&[1024, 1 << 20]);
        assert!((rows[0].activated - 256.0).abs() < 8.0, "{:?}", rows[0]);
        assert!((rows[0].sparsity - 0.75) < 0.01);
        assert!((rows[1].activated - 65536.0).abs() < 1500.0, "{:?}", rows[1]);
        assert!(rows[1].sparsity > 0.93);
    }

    /// Empirical validation of Lemma 6.1: on the Gaussian workload with
    /// the paper's b, measured activation counts stay under 2n^{4/5}.
    #[test]
    fn lemma_6_1_bound_holds_empirically() {
        let mut rng = Rng::new(61);
        let (m, n, d) = (8usize, 8192usize, 64usize);
        let p = ThresholdParams::standard(d, m);
        let b = p.bias(n) as f32;
        let q = rng.gaussian_vec_f32(m * d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let counts = count_activated(&q, &k, d, b);
        let bound = p.row_bound(n);
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) <= bound,
                "row {i}: {c} activated > bound {bound}"
            );
        }
        // The paper's factor-4 σ_a is conservative: with the practical
        // (uninflated) threshold, rows activate a non-trivial, still
        // sub-n^{4/5} number of entries.
        let bp = p.practical_bias(n) as f32;
        let counts_p = count_activated(&q, &k, d, bp);
        assert!(counts_p.iter().any(|&c| c > 0), "practical threshold vacuous");
        for &c in &counts_p {
            assert!((c as f64) <= bound, "practical counts exceed Lemma 6.1 bound");
        }
    }

    #[test]
    fn bias_for_target_inverts_expectation() {
        let p = ThresholdParams::standard(32, 4);
        let n = 1 << 16;
        let target = 500.0;
        let b = bias_for_target(&p, n, target);
        assert!((p.expected_activated(n, b) - target).abs() / target < 1e-9);
    }

    #[test]
    fn bias_from_sample_hits_fraction() {
        let mut rng = Rng::new(62);
        let sample: Vec<f32> = (0..10_000).map(|_| rng.gaussian() as f32).collect();
        let n = 100_000;
        let target = 10_000; // 10% of n
        let b = bias_from_sample(&mut sample.clone(), n, target);
        let above = sample.iter().filter(|&&s| s >= b).count();
        let frac = above as f64 / sample.len() as f64;
        assert!((frac - 0.1).abs() < 0.01, "frac={frac}");
    }
}
