//! Request router: shards requests across supervised engine worker
//! threads (vllm-project/router-shaped, scaled to this testbed). Each
//! worker owns one [`Engine`] replica behind a `Mutex`+`Condvar` inbox;
//! the router picks the least-loaded live worker, enforces admission
//! control (bounded per-worker queue depth + a pool-wide in-flight cap),
//! and merges metrics/outcomes.
//!
//! # Failure model
//!
//! * [`Router::submit`] returns `Result` — a saturated or stopping pool
//!   sheds load with [`SubmitError`] instead of queueing unboundedly
//!   (and never panics the accept path: no `expect` on worker state).
//! * Each worker wraps its engine turn in `catch_unwind`. On a panic
//!   (injected via [`FaultPlan`](super::serving::FaultPlan) or real)
//!   the worker marks itself dead, salvages its in-flight requests,
//!   restarts in place with a fresh engine (the fault plan cleared so a
//!   deterministic fault fires once), re-dispatches never-decoded
//!   requests to live workers under a bounded retry budget, and answers
//!   the rest with a structured [`Outcome::Failed`].
//! * Completion is event-driven: outcomes land in a Condvar-signaled
//!   table ([`Router::wait_for_outcome`] / [`Router::wait_idle`] block
//!   on the Condvar — no sleep-polling on the request path).
//! * [`Router::cancel`] removes a queued request from its inbox
//!   outright, or broadcasts to the engines so the owner aborts it
//!   mid-decode (releasing its KV blocks and chain refs).

use super::metrics::Metrics;
use super::request::{FinishReason, GenerationParams, Request, RequestId, Response};
use super::serving::{Engine, EngineConfig, FaultPlan};
use crate::model::Model;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Admission-control and supervision knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Per-worker bound on queued + running requests; submission skips
    /// workers at the bound.
    pub max_queue_per_worker: usize,
    /// Pool-wide in-flight cap; beyond it `submit` sheds load.
    pub max_in_flight: usize,
    /// Re-dispatch budget for requests salvaged from a panicked worker.
    pub max_retries: u32,
    /// Retry hint attached to `Overloaded` rejections.
    pub retry_after_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            max_queue_per_worker: 64,
            max_in_flight: 512,
            max_retries: 2,
            retry_after_ms: 50,
        }
    }
}

/// Why a submission was refused at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control shed the request; retry after the hint.
    Overloaded { retry_after_ms: u64 },
    /// The router is draining; no new work is accepted.
    ShuttingDown,
    /// Every worker is dead (mid-restart window).
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded (retry after {retry_after_ms} ms)")
            }
            SubmitError::ShuttingDown => write!(f, "shutting down"),
            SubmitError::NoWorkers => write!(f, "no live workers"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Terminal failure of an accepted request (structured error line on
/// the wire: `code` + `message` + optional retry hint).
#[derive(Debug, Clone)]
pub struct RequestError {
    pub id: RequestId,
    pub code: &'static str,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

/// Exactly-one terminal outcome per accepted request.
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(Response),
    Failed(RequestError),
}

impl Outcome {
    pub fn id(&self) -> RequestId {
        match self {
            Outcome::Done(r) => r.id,
            Outcome::Failed(e) => e.id,
        }
    }
}

enum WorkerMsg {
    Submit(Request),
    Cancel(RequestId),
    Shutdown { abort: bool },
}

/// Per-worker mailbox + liveness, shared so a dying worker can reach
/// survivors' inboxes when re-dispatching salvaged requests.
struct WorkerState {
    inbox: Mutex<VecDeque<WorkerMsg>>,
    cv: Condvar,
    /// Queued + running requests owned by this worker.
    in_flight: AtomicUsize,
    alive: AtomicBool,
}

#[derive(Default)]
struct CompletionState {
    ready: HashMap<RequestId, Outcome>,
    completed: usize,
}

#[derive(Default)]
struct Completions {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

struct Shared {
    model: Arc<Model>,
    cfg: EngineConfig,
    rcfg: RouterConfig,
    workers: Vec<WorkerState>,
    completions: Completions,
    submitted: AtomicUsize,
    next_id: AtomicU64,
    stopping: AtomicBool,
    // Router-level robustness counters, merged into Metrics at shutdown.
    rejected: AtomicU64,
    failed: AtomicU64,
    cancelled_in_queue: AtomicU64,
    worker_panics: AtomicU64,
    worker_restarts: AtomicU64,
    queue_depth_peak: AtomicU64,
    /// Metrics from exited/panicked engines (each engine's counters are
    /// merged here exactly once).
    metrics: Mutex<Metrics>,
}

/// Mutex access that survives a poisoned lock (a panicking worker never
/// holds these locks across engine code, but supervision should not be
/// taken down by a poisoned mutex either way).
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Shared {
    /// Least-loaded live worker; `respect_caps` also skips workers at
    /// the queue bound.
    fn pick_worker(&self, respect_caps: bool) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, w) in self.workers.iter().enumerate() {
            if !w.alive.load(Ordering::Acquire) {
                continue;
            }
            let load = w.in_flight.load(Ordering::Relaxed);
            if respect_caps && load >= self.rcfg.max_queue_per_worker {
                continue;
            }
            if best.map(|(_, b)| load < b).unwrap_or(true) {
                best = Some((i, load));
            }
        }
        best.map(|(i, _)| i)
    }

    fn total_in_flight(&self) -> usize {
        self.workers
            .iter()
            .map(|w| w.in_flight.load(Ordering::Relaxed))
            .sum()
    }

    fn note_queue_depth(&self) {
        self.queue_depth_peak
            .fetch_max(self.total_in_flight() as u64, Ordering::Relaxed);
    }

    fn enqueue(&self, widx: usize, msg: WorkerMsg) {
        let w = &self.workers[widx];
        lock_ok(&w.inbox).push_back(msg);
        w.cv.notify_one();
    }

    /// Dispatch to the least-loaded live worker, ignoring queue caps
    /// (used for salvage re-dispatch); returns the request when no
    /// worker is live.
    fn dispatch(&self, req: Request) -> Result<usize, Request> {
        match self.pick_worker(false) {
            Some(widx) => {
                self.workers[widx].in_flight.fetch_add(1, Ordering::Relaxed);
                self.note_queue_depth();
                self.enqueue(widx, WorkerMsg::Submit(req));
                Ok(widx)
            }
            None => Err(req),
        }
    }

    /// Record a terminal outcome and wake every waiter.
    fn finish_outcome(&self, outcome: Outcome) {
        {
            let mut st = lock_ok(&self.completions.state);
            st.ready.insert(outcome.id(), outcome);
            st.completed += 1;
        }
        self.completions.cv.notify_all();
    }

    /// Outcome from worker `widx`: the request leaves its ledger.
    fn publish(&self, widx: usize, outcome: Outcome) {
        self.workers[widx].in_flight.fetch_sub(1, Ordering::Relaxed);
        self.finish_outcome(outcome);
    }

    /// Terminal structured error for a request no worker owns anymore.
    fn fail(&self, id: RequestId, code: &'static str, message: String) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.finish_outcome(Outcome::Failed(RequestError {
            id,
            code,
            message,
            retry_after_ms: None,
        }));
    }
}

/// Multi-worker router with supervision.
pub struct Router {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Router {
    /// Spawn `n_workers` engines over a shared model with default
    /// admission control.
    pub fn new(model: Arc<Model>, cfg: EngineConfig, n_workers: usize) -> Router {
        Router::with_config(model, cfg, n_workers, RouterConfig::default())
    }

    pub fn with_config(
        model: Arc<Model>,
        cfg: EngineConfig,
        n_workers: usize,
        rcfg: RouterConfig,
    ) -> Router {
        assert!(n_workers >= 1);
        let workers = (0..n_workers)
            .map(|_| WorkerState {
                inbox: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                in_flight: AtomicUsize::new(0),
                alive: AtomicBool::new(true),
            })
            .collect();
        let shared = Arc::new(Shared {
            model,
            cfg,
            rcfg,
            workers,
            completions: Completions::default(),
            submitted: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled_in_queue: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            queue_depth_peak: AtomicU64::new(0),
            metrics: Mutex::new(Metrics::default()),
        });
        let handles = (0..n_workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("engine-{w}"))
                    .spawn(move || worker_loop(w, shared))
                    .expect("spawn engine worker")
            })
            .collect();
        Router { shared, handles: Mutex::new(handles) }
    }

    /// Submit to the least-loaded live worker. Sheds load (never
    /// panics, never blocks on a worker) when the pool is saturated,
    /// draining, or dead; ids are router-assigned and globally unique.
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        params: GenerationParams,
    ) -> Result<RequestId, SubmitError> {
        let s = &self.shared;
        if s.stopping.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        if s.total_in_flight() >= s.rcfg.max_in_flight {
            s.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Overloaded { retry_after_ms: s.rcfg.retry_after_ms });
        }
        let Some(widx) = s.pick_worker(true) else {
            let any_alive = s.workers.iter().any(|w| w.alive.load(Ordering::Acquire));
            s.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(if any_alive {
                SubmitError::Overloaded { retry_after_ms: s.rcfg.retry_after_ms }
            } else {
                SubmitError::NoWorkers
            });
        };
        let id = s.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        s.submitted.fetch_add(1, Ordering::SeqCst);
        s.workers[widx].in_flight.fetch_add(1, Ordering::Relaxed);
        s.note_queue_depth();
        s.enqueue(widx, WorkerMsg::Submit(Request { id, prompt, params, attempts: 0 }));
        Ok(id)
    }

    /// Cancel a request: if it is still queued in an inbox it is
    /// removed there (terminal `Cancelled` outcome, true returned);
    /// otherwise a cancel is broadcast so the owning engine aborts it
    /// mid-decode (false — delivery is asynchronous, and a request that
    /// already finished is a no-op).
    pub fn cancel(&self, id: RequestId) -> bool {
        let s = &self.shared;
        for (widx, w) in s.workers.iter().enumerate() {
            let removed = {
                let mut inbox = lock_ok(&w.inbox);
                let pos = inbox.iter().position(
                    |m| matches!(m, WorkerMsg::Submit(r) if r.id == id),
                );
                pos.and_then(|p| inbox.remove(p))
            };
            if let Some(WorkerMsg::Submit(req)) = removed {
                s.cancelled_in_queue.fetch_add(1, Ordering::Relaxed);
                s.publish(
                    widx,
                    Outcome::Done(Response {
                        id,
                        tokens: Vec::new(),
                        finish: FinishReason::Cancelled,
                        latency_ms: 0.0,
                        ttft_ms: 0.0,
                        prompt_len: req.prompt.len(),
                    }),
                );
                return true;
            }
        }
        for (widx, w) in s.workers.iter().enumerate() {
            if w.alive.load(Ordering::Acquire) {
                s.enqueue(widx, WorkerMsg::Cancel(id));
            }
        }
        false
    }

    /// Block (Condvar-signaled; no polling) until the request's
    /// terminal outcome arrives or `timeout` elapses.
    pub fn wait_for_outcome(&self, id: RequestId, timeout: Duration) -> Option<Outcome> {
        let s = &self.shared;
        let deadline = Instant::now() + timeout;
        let mut st = lock_ok(&s.completions.state);
        loop {
            if let Some(o) = st.ready.remove(&id) {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = s
                .completions
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// Completed / submitted counts (completed includes failures and
    /// cancellations — every accepted request reaches one outcome).
    pub fn progress(&self) -> (usize, usize) {
        let done = lock_ok(&self.shared.completions.state).completed;
        (done, self.shared.submitted.load(Ordering::SeqCst))
    }

    /// Queued + running requests across the pool (gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.total_in_flight()
    }

    /// Workers currently accepting work.
    pub fn alive_workers(&self) -> usize {
        self.shared
            .workers
            .iter()
            .filter(|w| w.alive.load(Ordering::Acquire))
            .count()
    }

    /// Drain all successful responses accumulated so far.
    pub fn take_responses(&self) -> Vec<Response> {
        let mut st = lock_ok(&self.shared.completions.state);
        let ids: Vec<RequestId> = st
            .ready
            .iter()
            .filter(|(_, o)| matches!(o, Outcome::Done(_)))
            .map(|(&k, _)| k)
            .collect();
        ids.into_iter()
            .filter_map(|k| match st.ready.remove(&k) {
                Some(Outcome::Done(r)) => Some(r),
                _ => None,
            })
            .collect()
    }

    /// Drain all terminal failures accumulated so far.
    pub fn take_failures(&self) -> Vec<RequestError> {
        let mut st = lock_ok(&self.shared.completions.state);
        let ids: Vec<RequestId> = st
            .ready
            .iter()
            .filter(|(_, o)| matches!(o, Outcome::Failed(_)))
            .map(|(&k, _)| k)
            .collect();
        ids.into_iter()
            .filter_map(|k| match st.ready.remove(&k) {
                Some(Outcome::Failed(e)) => Some(e),
                _ => None,
            })
            .collect()
    }

    /// Remove and return the successful response with this id, if
    /// present.
    pub fn take_response_by_id(&self, id: RequestId) -> Option<Response> {
        let mut st = lock_ok(&self.shared.completions.state);
        match st.ready.get(&id) {
            Some(Outcome::Done(_)) => match st.ready.remove(&id) {
                Some(Outcome::Done(r)) => Some(r),
                _ => None,
            },
            _ => None,
        }
    }

    /// Block until every accepted request has a terminal outcome
    /// (Condvar-signaled — no sleep-polling).
    pub fn wait_idle(&self) {
        let s = &self.shared;
        let mut st = lock_ok(&s.completions.state);
        while st.completed < s.submitted.load(Ordering::SeqCst) {
            st = s
                .completions
                .cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Graceful shutdown: stop admitting, let workers drain, merge
    /// their metrics. Blocks until all in-flight work completes.
    pub fn shutdown(self) -> Metrics {
        self.shutdown_inner(None)
    }

    /// Drain-then-abort shutdown: in-flight work gets `drain` to
    /// finish, then survivors are aborted (each still gets a terminal
    /// `Aborted` outcome).
    pub fn shutdown_within(self, drain: Duration) -> Metrics {
        self.shutdown_inner(Some(drain))
    }

    fn shutdown_inner(self, drain: Option<Duration>) -> Metrics {
        let s = &self.shared;
        s.stopping.store(true, Ordering::SeqCst);
        for widx in 0..s.workers.len() {
            s.enqueue(widx, WorkerMsg::Shutdown { abort: false });
        }
        if let Some(d) = drain {
            let deadline = Instant::now() + d;
            let mut st = lock_ok(&s.completions.state);
            while st.completed < s.submitted.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = s
                    .completions
                    .cv
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                st = guard;
            }
            let drained = st.completed >= s.submitted.load(Ordering::SeqCst);
            drop(st);
            if !drained {
                for widx in 0..s.workers.len() {
                    s.enqueue(widx, WorkerMsg::Shutdown { abort: true });
                }
            }
        }
        let handles = std::mem::take(&mut *lock_ok(&self.handles));
        for h in handles {
            if h.join().is_err() {
                // A worker died outside its catch_unwind (should not
                // happen): count it instead of silently dropping.
                s.worker_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        let mut merged = Metrics::default();
        merged.merge(&lock_ok(&s.metrics));
        merged.requests_rejected += s.rejected.load(Ordering::Relaxed);
        merged.requests_failed += s.failed.load(Ordering::Relaxed);
        merged.disconnect_aborts += s.cancelled_in_queue.load(Ordering::Relaxed);
        merged.worker_panics += s.worker_panics.load(Ordering::Relaxed);
        merged.worker_restarts += s.worker_restarts.load(Ordering::Relaxed);
        merged.queue_depth_peak = merged
            .queue_depth_peak
            .max(s.queue_depth_peak.load(Ordering::Relaxed));
        merged
    }
}

/// Per-worker engine: distinct seed, a disjoint id range for any
/// engine-assigned ids, and only this worker's slice of the fault plan.
fn worker_engine(shared: &Shared, widx: usize, faults: FaultPlan) -> Engine {
    let mut wcfg = shared.cfg;
    wcfg.seed = shared.cfg.seed.wrapping_add(widx as u64);
    wcfg.id_offset = ((widx as u64) + 1) << 40;
    // Engine-side queue bound: above the router cap (salvage re-dispatch
    // may overshoot it) but still finite.
    wcfg.scheduler.max_waiting = wcfg
        .scheduler
        .max_waiting
        .min(shared.rcfg.max_queue_per_worker.saturating_mul(2).saturating_add(8));
    wcfg.faults = faults;
    Engine::new(shared.model.clone(), wcfg)
}

fn worker_loop(widx: usize, shared: Arc<Shared>) {
    let me = &shared.workers[widx];
    let mut engine = worker_engine(&shared, widx, shared.cfg.faults.for_worker(widx));
    let mut shutdown = false;
    let mut abort = false;
    loop {
        // Collect inbox messages, blocking only when fully idle.
        let mut msgs: Vec<WorkerMsg> = Vec::new();
        {
            let mut inbox = lock_ok(&me.inbox);
            while inbox.is_empty() && !engine.has_work() && !shutdown {
                inbox = me.cv.wait(inbox).unwrap_or_else(|e| e.into_inner());
            }
            while let Some(m) = inbox.pop_front() {
                msgs.push(m);
            }
        }
        for m in &msgs {
            if let WorkerMsg::Shutdown { abort: a } = m {
                shutdown = true;
                abort = abort || *a;
            }
        }
        // One engine turn — message application plus a step — under
        // catch_unwind so a panic (injected or real) stays contained.
        let turn = catch_unwind(AssertUnwindSafe(|| {
            let mut rejected: Vec<Request> = Vec::new();
            for m in msgs {
                match m {
                    WorkerMsg::Submit(req) => {
                        if let Err(req) = engine.submit_request(req) {
                            rejected.push(req);
                        }
                    }
                    WorkerMsg::Cancel(id) => {
                        engine.cancel(id);
                    }
                    WorkerMsg::Shutdown { .. } => {}
                }
            }
            if abort {
                engine.abort_all();
            }
            if engine.has_work() {
                engine.step();
            }
            (engine.take_finished(), rejected)
        }));
        match turn {
            Ok((done, rejected)) => {
                for resp in done {
                    shared.publish(widx, Outcome::Done(resp));
                }
                for req in rejected {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                    shared.publish(
                        widx,
                        Outcome::Failed(RequestError {
                            id: req.id,
                            code: "overloaded",
                            message: "worker queue full".to_string(),
                            retry_after_ms: Some(shared.rcfg.retry_after_ms),
                        }),
                    );
                }
            }
            Err(_) => {
                engine = recover_from_panic(widx, &shared, engine);
                continue;
            }
        }
        if shutdown && !engine.has_work() {
            break;
        }
    }
    // Merge final metrics; count KV blocks the drained engine failed to
    // return (0 in a correct engine — cross-checked against the
    // allocator's debug ledger).
    let leaked = engine.reclaim_and_count_leaks();
    let mut m = engine.metrics.clone();
    m.kv_blocks_leaked += leaked as u64;
    lock_ok(&shared.metrics).merge(&m);
    me.alive.store(false, Ordering::Release);
}

/// Supervision: contain a worker panic. Salvages the dead engine's
/// requests, restarts the worker in place with a fresh engine (fault
/// plan cleared so deterministic faults fire once), re-dispatches
/// never-decoded requests within the retry budget, and fails the rest
/// with a structured error.
fn recover_from_panic(widx: usize, shared: &Shared, mut engine: Engine) -> Engine {
    let me = &shared.workers[widx];
    me.alive.store(false, Ordering::Release);
    shared.worker_panics.fetch_add(1, Ordering::Relaxed);
    let (retry, dead) = engine.salvage();
    me.in_flight
        .fetch_sub(retry.len() + dead.len(), Ordering::Relaxed);
    let (redispatch, exhausted): (Vec<Request>, Vec<Request>) =
        retry.into_iter().partition(|r| r.attempts < shared.rcfg.max_retries);
    // The panicked engine's counters survive (the old shutdown bug
    // dropped them); re-dispatched requests will be counted as
    // submissions by their new engine, so they leave this snapshot.
    let mut m = engine.metrics.clone();
    m.requests_submitted = m.requests_submitted.saturating_sub(redispatch.len() as u64);
    lock_ok(&shared.metrics).merge(&m);
    drop(engine); // pool/radix state is untrusted — discard wholesale
    let fresh = worker_engine(shared, widx, FaultPlan::none());
    shared.worker_restarts.fetch_add(1, Ordering::Relaxed);
    me.alive.store(true, Ordering::Release);
    for mut req in redispatch {
        req.attempts += 1;
        if let Err(req) = shared.dispatch(req) {
            shared.fail(
                req.id,
                "worker_failed",
                "worker panicked and no live worker could take the retry".to_string(),
            );
        }
    }
    for req in exhausted {
        shared.fail(
            req.id,
            "worker_failed",
            "worker panicked; retry budget exhausted".to_string(),
        );
    }
    for req in dead {
        shared.fail(
            req.id,
            "worker_failed",
            "worker panicked mid-generation".to_string(),
        );
    }
    fresh
}
