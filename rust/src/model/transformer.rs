//! Native transformer forward + HSR-sparse decode — the serving hot path.
//!
//! Mirrors `python/compile/model.py` op-for-op (RMSNorm/RoPE/SwiGLU,
//! fp32); parity is asserted against golden vectors exported by aot.py.
//! The attention inner loop is pluggable via [`AttentionPolicy`]:
//!
//! * `Dense` — the naive O(n) softmax over the whole KV cache
//!   (Definition 1.1; the baseline of Theorems 4.2/5.2).
//! * `TopR` — Algorithm 1's inference loop: HSR query for the candidate
//!   half-space, then exact top-r restriction (Definition B.2). The
//!   threshold b is auto-calibrated per (layer, head) from observed score
//!   quantiles ("choose b such that R = NN(r, q, K)" — Theorem 4.2) and
//!   adapts as the distribution drifts during generation. Because the HSR
//!   query is exact, candidates ⊇ top-r whenever |candidates| ≥ r, so the
//!   selected index set equals the true NN(r, q, K).

use super::kv::KvState;
use super::Model;
use crate::attention::softmax::{log_sum_exp, softmax_attention_row_scored};
use crate::attention::topk::{rth_largest, top_r_select_into};
use crate::hsr::QueryStats;
use crate::util::tensor_io::Tensor;

/// How many candidates (relative to r) the calibrator aims to report:
/// a 2x superset absorbs distribution drift between steps.
const CALIBRATION_SLACK: f32 = 2.0;

/// Attention policy for cached attention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttentionPolicy {
    /// Full softmax attention over the cache.
    Dense,
    /// Softmax attention restricted to the top-r indices, r = spec(n).
    TopR(RSpec),
}

/// How r scales with the cache length n.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RSpec {
    /// Constant r.
    Fixed(usize),
    /// r = ceil(n^p) — the paper's n^{4/5} with p = 0.8.
    Pow(f64),
}

impl RSpec {
    /// The paper's r = n^{4/5}.
    pub fn paper() -> RSpec {
        RSpec::Pow(0.8)
    }

    pub fn r_for(&self, n: usize) -> usize {
        match *self {
            RSpec::Fixed(r) => r.max(1),
            RSpec::Pow(p) => (n as f64).powf(p).ceil().max(1.0) as usize,
        }
    }
}

/// Per-step instrumentation aggregated across layers/heads.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    /// HSR work counters summed over heads.
    pub hsr: QueryStats,
    /// Total attended (selected) entries.
    pub attended: usize,
    /// Total cache entries that a dense pass would have attended.
    pub dense_equivalent: usize,
    /// Number of calibration fallbacks (full re-scans).
    pub fallbacks: usize,
}

/// Reusable scratch buffers for a forward step (no allocation on the
/// token hot path).
pub struct Workspace {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    ffn_a: Vec<f32>,
    ffn_b: Vec<f32>,
    scores: Vec<f32>,
    cand: Vec<u32>,
    cand_scores: Vec<f32>,
    selected: Vec<u32>,
    logits: Vec<f32>,
}

impl Workspace {
    pub fn new(model: &Model) -> Workspace {
        let c = &model.cfg;
        Workspace {
            x: vec![0.0; c.d_model],
            h: vec![0.0; c.d_model],
            q: vec![0.0; c.d_model],
            k: vec![0.0; c.d_model],
            v: vec![0.0; c.d_model],
            att: vec![0.0; c.d_model],
            proj: vec![0.0; c.d_model],
            ffn_a: vec![0.0; c.d_ffn],
            ffn_b: vec![0.0; c.d_ffn],
            scores: Vec::new(),
            cand: Vec::new(),
            cand_scores: Vec::new(),
            selected: Vec::new(),
            logits: vec![0.0; c.vocab],
        }
    }
}

/// out = x @ W for row-major W [d_in, d_out].
fn matvec(x: &[f32], w: &Tensor, out: &mut [f32]) {
    let d_in = w.shape[0];
    let d_out = w.shape[1];
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(out.len(), d_out);
    out.fill(0.0);
    for i in 0..d_in {
        let xi = x[i];
        let row = &w.data[i * d_out..(i + 1) * d_out];
        crate::kernel::simd::axpy(out, row, xi);
    }
}

/// RMSNorm: x * rsqrt(mean(x^2) + eps) * w.
fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let scale = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * scale * wv;
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// In-place RoPE on one head vector (consecutive-pair layout, matching
/// model.py's apply_rope).
pub fn apply_rope(x: &mut [f32], pos: usize, theta: f64) {
    let d_head = x.len();
    let half = d_head / 2;
    for i in 0..half {
        let freq = theta.powf(-((2 * i) as f64) / d_head as f64);
        let ang = pos as f64 * freq;
        let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
        let e = x[2 * i];
        let o = x[2 * i + 1];
        x[2 * i] = e * cos - o * sin;
        x[2 * i + 1] = e * sin + o * cos;
    }
}

impl Model {
    /// One autoregressive step: appends this token's K/V to the cache and
    /// returns the next-token logits. `pos` must equal `kv.len()`.
    pub fn decode_step(
        &self,
        token: u32,
        kv: &mut KvState,
        policy: AttentionPolicy,
        ws: &mut Workspace,
        stats: &mut StepStats,
    ) -> Vec<f32> {
        let c = &self.cfg;
        let pos = kv.len();
        // Embedding.
        let emb = self.tensor("tok_emb");
        ws.x.copy_from_slice(emb.row(token as usize));

        for layer in 0..c.n_layers {
            // --- attention block ---
            rms_norm(&ws.x, &self.layer_tensor("attn_norm", layer).data, c.rms_eps, &mut ws.h);
            matvec(&ws.h, self.layer_tensor("wq", layer), &mut ws.q);
            matvec(&ws.h, self.layer_tensor("wk", layer), &mut ws.k);
            matvec(&ws.h, self.layer_tensor("wv", layer), &mut ws.v);
            for head in 0..c.n_heads {
                let s = head * c.d_head;
                let e = s + c.d_head;
                apply_rope(&mut ws.q[s..e], pos, c.rope_theta);
                apply_rope(&mut ws.k[s..e], pos, c.rope_theta);
                // Append current token so it participates in attention.
                let hk = kv.head_mut(layer, head);
                hk.append(&ws.k[s..e], &ws.v[s..e]);
                attend_head(
                    hk,
                    &ws.q[s..e],
                    c.d_head,
                    policy,
                    &mut ws.scores,
                    &mut ws.cand,
                    &mut ws.cand_scores,
                    &mut ws.selected,
                    &mut ws.att[s..e],
                    stats,
                );
            }
            matvec(&ws.att, self.layer_tensor("wo", layer), &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
            // --- MLP block (SwiGLU) ---
            rms_norm(&ws.x, &self.layer_tensor("mlp_norm", layer).data, c.rms_eps, &mut ws.h);
            matvec(&ws.h, self.layer_tensor("w1", layer), &mut ws.ffn_a);
            matvec(&ws.h, self.layer_tensor("w3", layer), &mut ws.ffn_b);
            for (a, &b) in ws.ffn_a.iter_mut().zip(&ws.ffn_b) {
                *a = silu(*a) * b;
            }
            matvec(&ws.ffn_a, self.layer_tensor("w2", layer), &mut ws.proj);
            for (x, &p) in ws.x.iter_mut().zip(&ws.proj) {
                *x += p;
            }
        }
        rms_norm(&ws.x, &self.tensor("final_norm").data, c.rms_eps, &mut ws.h);
        matvec(&ws.h, self.tensor("w_out"), &mut ws.logits);
        ws.logits.clone()
    }

    /// Prefill a prompt through the decode path (token by token) and
    /// return all logits [t, vocab]. `policy` applies from position
    /// `sparse_from` onward (early positions have tiny caches where
    /// sparsity is meaningless).
    pub fn prefill(
        &self,
        tokens: &[u32],
        kv: &mut KvState,
        policy: AttentionPolicy,
        stats: &mut StepStats,
    ) -> Vec<f32> {
        let mut ws = Workspace::new(self);
        let mut all = Vec::with_capacity(tokens.len() * self.cfg.vocab);
        for &t in tokens {
            let logits = self.decode_step(t, kv, policy, &mut ws, stats);
            all.extend_from_slice(&logits);
        }
        all
    }

    /// Full dense forward (reference path for golden tests): [t, vocab].
    pub fn forward_full(&self, tokens: &[u32]) -> Vec<f32> {
        let mut kv = KvState::new(self.cfg.n_layers, self.cfg.n_heads, self.cfg.d_head, None);
        let mut stats = StepStats::default();
        self.prefill(tokens, &mut kv, AttentionPolicy::Dense, &mut stats)
    }

    /// Mean negative log-likelihood (nats/byte) of `tokens[1..]` given the
    /// running prefix under the given policy — exp() of this is the
    /// perplexity of Section 7.
    pub fn nll(&self, tokens: &[u32], policy: AttentionPolicy) -> f64 {
        assert!(tokens.len() >= 2);
        let mut kv = KvState::new(
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.d_head,
            Some(crate::hsr::HsrBackend::BallTree),
        );
        let mut ws = Workspace::new(self);
        let mut stats = StepStats::default();
        let mut total = 0f64;
        for i in 0..tokens.len() - 1 {
            let logits = self.decode_step(tokens[i], &mut kv, policy, &mut ws, &mut stats);
            let lse = log_sum_exp(&logits);
            total += (lse - logits[tokens[i + 1] as usize]) as f64;
        }
        total / (tokens.len() - 1) as f64
    }
}

/// One head of cached attention under a policy. `out` has length d_head.
/// All buffers come from the per-engine [`Workspace`]; the HSR query
/// carries raw scores out with the report, so no inner product is ever
/// computed twice on this path.
#[allow(clippy::too_many_arguments)]
fn attend_head(
    hk: &mut super::kv::HeadKv,
    q: &[f32],
    d_head: usize,
    policy: AttentionPolicy,
    scores: &mut Vec<f32>,
    cand: &mut Vec<u32>,
    cand_scores: &mut Vec<f32>,
    selected: &mut Vec<u32>,
    out: &mut [f32],
    stats: &mut StepStats,
) {
    let n = hk.len();
    let inv_sqrt_d = 1.0 / (d_head as f32).sqrt();
    stats.dense_equivalent += n;
    let r = match policy {
        AttentionPolicy::Dense => n,
        AttentionPolicy::TopR(spec) => spec.r_for(n),
    };
    if r >= n {
        // Dense (or top-r covering everything): one blocked scoring pass,
        // one fused softmax — no index set, no second dot pass.
        crate::attention::softmax::softmax_attention_row(
            q, &hk.keys, &hk.values, d_head, scores, out,
        );
        stats.attended += n;
        return;
    }

    // --- Algorithm 1 inference: scored HSR query, then exact top-r. ---
    // The HSR threshold lives on the raw inner product <q, k>.
    let mut b_raw = hk.calib_threshold.unwrap_or(f32::NEG_INFINITY);
    cand.clear();
    cand_scores.clear();
    let mut q_stats = QueryStats::default();
    hk.hsr_query_scored(q, b_raw, cand, cand_scores, &mut q_stats);
    if cand.len() < r {
        // Calibration miss: fall back to the full half-space (b = -inf ≡
        // brute top-r) and recalibrate. Exactness is never compromised.
        stats.fallbacks += 1;
        cand.clear();
        cand_scores.clear();
        hk.hsr_query_scored(q, f32::NEG_INFINITY, cand, cand_scores, &mut q_stats);
    }
    stats.hsr.add(&q_stats);
    // Recalibrate: aim the next report at ~CALIBRATION_SLACK * r.
    let target = ((r as f32 * CALIBRATION_SLACK) as usize).min(cand.len());
    if target >= 1 {
        b_raw = rth_largest(cand_scores, target);
        hk.calib_threshold = Some(b_raw);
    }
    // Exact top-r over the candidate superset (= true NN(r, q, K)),
    // carrying the already-paid-for scores into the softmax.
    top_r_select_into(cand, cand_scores, r, selected, scores);
    for s in scores.iter_mut() {
        *s *= inv_sqrt_d;
    }
    stats.attended += selected.len();
    softmax_attention_row_scored(selected, scores, &hk.values, d_head, out);
}

/// Greedy argmax sampling.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Temperature sampling with a deterministic RNG.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let scaled: Vec<f32> = logits.iter().map(|&l| l / temperature).collect();
    let probs = crate::attention::softmax::softmax(&scaled);
    rng.categorical(&probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_zero_is_identity() {
        let mut x = vec![0.3f32, -1.2, 0.7, 2.0];
        let orig = x.clone();
        apply_rope(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![0.3f32, -1.2, 0.7, 2.0, 1.0, -0.5, 0.1, 0.9];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        apply_rope(&mut x, 123, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn rope_relative_property() {
        // <R_p x, R_q y> depends only on p − q.
        let x = vec![0.5f32, -0.3, 1.1, 0.2];
        let y = vec![-0.7f32, 0.9, 0.4, -1.3];
        let ip = |p: usize, qpos: usize| {
            let mut a = x.clone();
            let mut b = y.clone();
            apply_rope(&mut a, p, 10000.0);
            apply_rope(&mut b, qpos, 10000.0);
            crate::hsr::dot(&a, &b)
        };
        assert!((ip(7, 3) - ip(11, 7)).abs() < 1e-4);
    }

    #[test]
    fn rspec_scaling() {
        assert_eq!(RSpec::Fixed(16).r_for(1000), 16);
        assert_eq!(RSpec::paper().r_for(1024), (1024f64.powf(0.8).ceil()) as usize);
        assert_eq!(RSpec::Pow(0.8).r_for(1), 1);
    }

    #[test]
    fn argmax_and_sample() {
        let logits = vec![0.0f32, 5.0, -1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = crate::util::rng::Rng::new(0);
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // Low temperature: overwhelmingly picks the max.
        let picks: Vec<u32> = (0..50).map(|_| sample(&logits, 0.1, &mut rng)).collect();
        assert!(picks.iter().filter(|&&p| p == 1).count() > 45);
    }
}
