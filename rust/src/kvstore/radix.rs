//! [`RadixIndex`] — token-prefix → shared segment chain, with refcounts
//! and LRU eviction under pool pressure.
//!
//! Each node owns one immutable shared-prefix segment and is labelled by
//! that segment's token run; a root-to-node path therefore spells a
//! cached prompt prefix, and matching a prompt against the tree returns
//! the longest chain of **fully matched** nodes. Node runs are
//! arbitrary-length (whatever a publishing sequence had prefilled when
//! it published), and there is deliberately **no node splitting**: a
//! prompt that diverges mid-run simply stops matching at the previous
//! node. Sharing granularity is thus the publish granularity (one
//! prefill chunk), which captures the shared-prompt workloads this
//! store exists for without ever having to split a segment's HSR index.
//!
//! # Refcount lifecycle
//!
//! * [`RadixIndex::ref_chain`] / [`RadixIndex::deref_chain`] — a running
//!   sequence holds exactly one reference on **every** node of its
//!   adopted chain, taken at adoption and dropped at finish/preemption
//!   (or when the sequence re-adopts a longer chain).
//! * A node with `refs > 0` is never touched by eviction, so a chain a
//!   sequence decodes against can never be freed underneath it.
//!
//! # Eviction across tiers
//!
//! With the cold tier off, eviction is the classic shape: only
//! unreferenced **leaves** are LRU candidates, and evicting one destroys
//! its segment (pages return to the shared budget) and unlinks the node
//! — a later identical prompt re-prefills and republishes.
//!
//! With the cold tier on, eviction prefers **demotion in place**: the
//! victim's payload is compressed into the spill store, its pages are
//! freed, and the node *stays in the tree* — its token run remains
//! matchable, so a later prompt refaults the payload instead of
//! re-prefilling it. Because demotion preserves topology, interior
//! nodes are candidates too (sole-owner ones; a segment another radix
//! node still owns must stay hot for that owner). Teardown
//! (`want_free == usize::MAX`) bypasses demotion entirely and also
//! reaps cold leaves, so a full reclaim leaves nothing behind.

use super::pool::{Demoted, PagePool, SegmentId};

/// Identifier of a node slot inside a [`RadixIndex`].
pub type NodeId = u32;

struct Node {
    seg: SegmentId,
    parent: Option<NodeId>,
    children: Vec<NodeId>,
    /// Sequences currently holding this node in their adopted chain.
    refs: usize,
    /// LRU stamp: bumped every time a match traverses the node.
    last_use: u64,
}

/// The prefix tree over cached segments.
#[derive(Default)]
pub struct RadixIndex {
    nodes: Vec<Option<Node>>,
    free_slots: Vec<u32>,
    roots: Vec<NodeId>,
    clock: u64,
}

impl RadixIndex {
    pub fn new() -> RadixIndex {
        RadixIndex::default()
    }

    /// Number of live nodes.
    pub fn len(&self) -> usize {
        self.nodes.len() - self.free_slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn node(&self, id: NodeId) -> &Node {
        self.nodes[id as usize].as_ref().expect("live radix node")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id as usize].as_mut().expect("live radix node")
    }

    /// The segment a node owns.
    pub fn segment_of(&self, id: NodeId) -> SegmentId {
        self.node(id).seg
    }

    /// Current reference count of a node (tests/diagnostics).
    pub fn refs_of(&self, id: NodeId) -> usize {
        self.node(id).refs
    }

    /// Whether a node slot still holds a live node (tests/diagnostics).
    pub fn is_live(&self, id: NodeId) -> bool {
        matches!(self.nodes.get(id as usize), Some(Some(_)))
    }

    /// Walk the tree matching `tokens`, returning the chain of fully
    /// matched nodes and the total token count they cover. A node only
    /// matches if its whole run fits inside `tokens[..limit]` — callers
    /// pass `limit = prompt_len - 1` so the last prompt token is always
    /// recomputed (its logits seed the first generated token). Cold
    /// nodes match like hot ones (their runs stay resident; adoption
    /// refaults the payload afterwards), except poisoned ones — a lost
    /// spill record ends the match there. Matched nodes get their LRU
    /// stamp bumped.
    pub fn match_chain(
        &mut self,
        pool: &PagePool,
        tokens: &[u32],
        limit: usize,
    ) -> (Vec<NodeId>, usize) {
        let mut chain = Vec::new();
        let mut pos = 0usize;
        let mut candidates: &[NodeId] = &self.roots;
        'walk: loop {
            let mut next: Option<NodeId> = None;
            for &cid in candidates {
                let seg = self.node(cid).seg;
                if !pool.is_matchable(seg) {
                    continue;
                }
                let run = pool.tokens_of(seg);
                if pos + run.len() <= limit.min(tokens.len())
                    && tokens[pos..pos + run.len()] == run[..]
                {
                    next = Some(cid);
                    break;
                }
            }
            match next {
                Some(cid) => {
                    pos += pool.len_of(self.node(cid).seg);
                    chain.push(cid);
                    candidates = &self.node(cid).children;
                    // Reborrow dance: bump the stamp after the borrow of
                    // `candidates` is re-derived each iteration.
                    if candidates.is_empty() {
                        break 'walk;
                    }
                }
                None => break 'walk,
            }
        }
        self.clock += 1;
        let stamp = self.clock;
        for &cid in &chain {
            // Split borrow: `chain` is local, nodes are in `self.nodes`.
            self.nodes[cid as usize]
                .as_mut()
                .expect("matched node is live")
                .last_use = stamp;
        }
        (chain, pos)
    }

    /// Insert a new node owning `seg` as a child of `parent` (`None` →
    /// a new root). Returns the node id; the node starts unreferenced.
    pub fn insert_child(&mut self, parent: Option<NodeId>, seg: SegmentId) -> NodeId {
        self.clock += 1;
        let node = Node {
            seg,
            parent,
            children: Vec::new(),
            refs: 0,
            last_use: self.clock,
        };
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = Some(node);
                slot
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        };
        match parent {
            Some(p) => self.node_mut(p).children.push(id),
            None => self.roots.push(id),
        }
        id
    }

    /// Take one reference on every node of `chain`.
    pub fn ref_chain(&mut self, chain: &[NodeId]) {
        for &id in chain {
            self.node_mut(id).refs += 1;
        }
    }

    /// Drop one reference from every node of `chain`.
    pub fn deref_chain(&mut self, chain: &[NodeId]) {
        for &id in chain {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "deref of unreferenced radix node");
            n.refs = n.refs.saturating_sub(1);
        }
    }

    /// Free pool blocks by LRU-evicting unreferenced cached prefixes
    /// until `pool.free_blocks() >= want_free` or no candidate remains.
    /// With the cold tier on, sole-owner victims are **demoted in
    /// place** (payload spilled, node kept matchable); otherwise
    /// victims are destroyed and unlinked. `want_free == usize::MAX`
    /// means teardown: demotion is bypassed and cold leaves are reaped
    /// too, cascading leaf-first until only referenced chains remain.
    /// Returns the number of victims processed (demoted or removed).
    pub fn evict_lru(&mut self, pool: &mut PagePool, want_free: usize) -> usize {
        let teardown = want_free == usize::MAX;
        let mut evicted = 0usize;
        while pool.free_blocks() < want_free {
            let mut victim: Option<(NodeId, u64)> = None;
            for (slot, node) in self.nodes.iter().enumerate() {
                let Some(n) = node else { continue };
                if n.refs != 0 {
                    continue;
                }
                let childless = n.children.is_empty();
                let eligible = if teardown {
                    childless
                } else {
                    pool.holds_blocks(n.seg) && (childless || pool.can_demote(n.seg))
                };
                if eligible && victim.map(|(_, lu)| n.last_use < lu).unwrap_or(true) {
                    victim = Some((slot as u32, n.last_use));
                }
            }
            let Some((id, _)) = victim else { break };
            self.evict_node(pool, id, teardown);
            evicted += 1;
        }
        evicted
    }

    /// Process one eviction victim (unreferenced; childless unless a
    /// demotable interior).
    fn evict_node(&mut self, pool: &mut PagePool, id: NodeId, teardown: bool) {
        let seg = self.node(id).seg;
        let childless = self.node(id).children.is_empty();
        if teardown {
            debug_assert!(childless);
            if pool.holds_blocks(seg) {
                pool.release_segment(seg, false, true);
            } else {
                pool.release_cold(seg);
            }
            self.unlink_leaf(id);
            return;
        }
        if pool.can_demote(seg) {
            match pool.release_segment(seg, true, childless) {
                // Demoted in place: the node survives, now cold.
                Demoted::Spilled => {}
                // Spill write failed on a childless victim: dropped.
                Demoted::Dropped => self.unlink_leaf(id),
                // Spill write failed on an interior victim: kept hot.
                // The pool has disabled spill, so this node stops being
                // a candidate and the eviction loop cannot spin on it.
                Demoted::Kept => {}
                Demoted::SharedKept => unreachable!("can_demote implies sole owner"),
            }
        } else {
            // Childless (candidate rule) — drop this owner's claim and
            // unlink; the payload survives iff another owner holds it.
            debug_assert!(childless);
            pool.release_segment(seg, false, true);
            self.unlink_leaf(id);
        }
    }

    /// Targeted eviction of one chain, leaf-first: walk from the leaf
    /// toward the root, demoting or destroying each unreferenced node,
    /// skipping over already-cold ones (they hold no blocks), and
    /// stopping at the first node still shared. Used when a sequence
    /// sheds its adopted chain under pool wedge — the freed blocks must
    /// materialize *now*, or the next lookup would just re-adopt the
    /// chain and wedge again. Returns the count of nodes demoted or
    /// removed.
    pub fn evict_chain(&mut self, pool: &mut PagePool, chain: &[NodeId]) -> usize {
        let mut evicted = 0usize;
        for &id in chain.iter().rev() {
            let n = self.node(id);
            if n.refs != 0 {
                break;
            }
            let seg = n.seg;
            let childless = n.children.is_empty();
            if pool.can_demote(seg) {
                match pool.release_segment(seg, true, childless) {
                    Demoted::Spilled => evicted += 1,
                    Demoted::Dropped => {
                        self.unlink_leaf(id);
                        evicted += 1;
                    }
                    Demoted::Kept => break,
                    Demoted::SharedKept => unreachable!("can_demote implies sole owner"),
                }
            } else if childless && pool.holds_blocks(seg) {
                pool.release_segment(seg, false, true);
                self.unlink_leaf(id);
                evicted += 1;
            } else if !pool.holds_blocks(seg) {
                // Already cold: nothing to free here; keep walking up so
                // hot ancestors still demote.
                continue;
            } else {
                // Hot interior that cannot demote (spill off, or shared
                // owner): everything above it is held too. Stop.
                break;
            }
        }
        evicted
    }

    /// Unlink one childless node from the tree (its segment claim must
    /// already be released).
    fn unlink_leaf(&mut self, id: NodeId) {
        let node = self.nodes[id as usize]
            .take()
            .expect("unlinking a live node");
        debug_assert!(node.refs == 0 && node.children.is_empty());
        match node.parent {
            Some(p) => {
                let siblings = &mut self.node_mut(p).children;
                siblings.retain(|&c| c != id);
            }
            None => self.roots.retain(|&r| r != id),
        }
        self.free_slots.push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::HsrBackend;
    use crate::kvstore::tier::{SpillConfig, SpillPolicy, TierConfig};
    use crate::model::kv::KvState;
    use crate::util::rng::Rng;

    fn pool_with_source(n: usize, d: usize) -> (PagePool, KvState) {
        let mut rng = Rng::new(11);
        let mut kv = KvState::new(1, 1, d, Some(HsrBackend::BallTree));
        for _ in 0..n {
            let k = rng.gaussian_vec_f32(d, 1.0);
            let v = rng.gaussian_vec_f32(d, 1.0);
            kv.head_mut(0, 0).append(&k, &v);
        }
        (PagePool::new(1024, 16, Some(HsrBackend::BallTree)), kv)
    }

    fn tiered_pool_with_source(n: usize, d: usize) -> (PagePool, KvState) {
        let (_, kv) = pool_with_source(n, d);
        let pool = PagePool::with_tier(
            1024,
            16,
            Some(HsrBackend::BallTree),
            &TierConfig { spill: SpillConfig::Memory, policy: SpillPolicy::RebuildOnRefault },
        );
        (pool, kv)
    }

    /// Publish tokens[start..end) as a child of `parent`.
    fn publish(
        radix: &mut RadixIndex,
        pool: &mut PagePool,
        kv: &KvState,
        tokens: &[u32],
        start: usize,
        end: usize,
        parent: Option<NodeId>,
    ) -> NodeId {
        let seg = pool
            .create_segment(&tokens[start..end], start, kv, start)
            .expect("fits");
        radix.insert_child(parent, seg)
    }

    #[test]
    fn match_walks_full_runs_only() {
        let (mut pool, kv) = pool_with_source(64, 4);
        let tokens: Vec<u32> = (0..64).collect();
        let mut radix = RadixIndex::new();
        let a = publish(&mut radix, &mut pool, &kv, &tokens, 0, 16, None);
        let b = publish(&mut radix, &mut pool, &kv, &tokens, 16, 40, Some(a));
        // Full prompt: matches both nodes.
        let (chain, matched) = radix.match_chain(&pool, &tokens, 63);
        assert_eq!(chain, vec![a, b]);
        assert_eq!(matched, 40);
        // A prompt diverging inside node b stops after a.
        let mut div = tokens.clone();
        div[20] = 999;
        let (chain, matched) = radix.match_chain(&pool, &div, 63);
        assert_eq!(chain, vec![a]);
        assert_eq!(matched, 16);
        // The limit caps matching: a 17-token prompt cannot use node b,
        // and a 16-token prompt cannot even fully use node a (limit 15).
        let (chain, matched) = radix.match_chain(&pool, &tokens[..17], 16);
        assert_eq!(chain, vec![a]);
        assert_eq!(matched, 16);
        let (chain, matched) = radix.match_chain(&pool, &tokens[..16], 15);
        assert!(chain.is_empty());
        assert_eq!(matched, 0);
    }

    #[test]
    fn refcounts_guard_eviction() {
        let (mut pool, kv) = pool_with_source(64, 4);
        let tokens: Vec<u32> = (0..64).collect();
        let mut radix = RadixIndex::new();
        let a = publish(&mut radix, &mut pool, &kv, &tokens, 0, 16, None);
        let b = publish(&mut radix, &mut pool, &kv, &tokens, 16, 32, Some(a));
        radix.ref_chain(&[a, b]);
        assert_eq!(radix.refs_of(a), 1);
        // Nothing evictable while referenced (and `a` has a child).
        assert_eq!(radix.evict_lru(&mut pool, usize::MAX), 0);
        radix.deref_chain(&[a, b]);
        // Now the leaf b goes first, then a.
        let free0 = pool.free_blocks();
        assert_eq!(radix.evict_lru(&mut pool, free0 + 1), 1);
        assert_eq!(radix.len(), 1);
        assert_eq!(radix.evict_lru(&mut pool, usize::MAX), 1);
        assert!(radix.is_empty());
        assert_eq!(pool.segment_count(), 0);
    }

    #[test]
    fn lru_prefers_the_stalest_leaf() {
        let (mut pool, kv) = pool_with_source(64, 4);
        let tokens: Vec<u32> = (0..64).collect();
        let other: Vec<u32> = (100..164).collect();
        let mut kv2 = KvState::new(1, 1, 4, None);
        let mut rng = Rng::new(12);
        for _ in 0..64 {
            let k = rng.gaussian_vec_f32(4, 1.0);
            kv2.head_mut(0, 0).append(&k.clone(), &k);
        }
        let mut radix = RadixIndex::new();
        let a = publish(&mut radix, &mut pool, &kv, &tokens, 0, 16, None);
        let b = publish(&mut radix, &mut pool, &kv2, &other, 0, 16, None);
        // Touch `a` so `b` is stalest.
        let _ = radix.match_chain(&pool, &tokens, 63);
        let free0 = pool.free_blocks();
        assert_eq!(radix.evict_lru(&mut pool, free0 + 1), 1);
        assert_eq!(radix.refs_of(a), 0); // a survives
        assert!(!radix.is_live(b), "stalest leaf evicted");
    }

    #[test]
    fn eviction_demotes_in_place_and_teardown_reaps() {
        let (mut pool, kv) = tiered_pool_with_source(64, 4);
        let tokens: Vec<u32> = (0..64).collect();
        let mut radix = RadixIndex::new();
        let a = publish(&mut radix, &mut pool, &kv, &tokens, 0, 16, None);
        let b = publish(&mut radix, &mut pool, &kv, &tokens, 16, 32, Some(a));
        let free0 = pool.free_blocks();
        // Spill on: eviction demotes (both nodes — interiors included),
        // freeing all blocks while keeping the tree matchable.
        assert_eq!(radix.evict_lru(&mut pool, free0 + 2), 2);
        assert_eq!(radix.len(), 2, "nodes survive demotion");
        assert!(pool.is_cold(radix.segment_of(a)));
        assert!(pool.is_cold(radix.segment_of(b)));
        let (chain, matched) = radix.match_chain(&pool, &tokens, 63);
        assert_eq!(chain, vec![a, b]);
        assert_eq!(matched, 32);
        // Teardown reaps the cold leaves too.
        assert_eq!(radix.evict_lru(&mut pool, usize::MAX), 2);
        assert!(radix.is_empty());
        assert_eq!(pool.segment_count(), 0);
        assert_eq!(pool.spill_live_bytes(), 0);
        pool.debug_assert_all_free();
    }

    #[test]
    fn evict_chain_demotes_past_cold_nodes() {
        let (mut pool, kv) = tiered_pool_with_source(64, 4);
        let tokens: Vec<u32> = (0..64).collect();
        let mut radix = RadixIndex::new();
        let a = publish(&mut radix, &mut pool, &kv, &tokens, 0, 16, None);
        let b = publish(&mut radix, &mut pool, &kv, &tokens, 16, 32, Some(a));
        let c = publish(&mut radix, &mut pool, &kv, &tokens, 32, 48, Some(b));
        // Demote just the middle node (simulate earlier LRU pressure):
        // release b directly via the pool, keeping the node.
        assert_eq!(pool.release_segment(radix.segment_of(b), true, false), Demoted::Spilled);
        // Shedding the chain must demote c AND walk past cold b to a.
        assert_eq!(radix.evict_chain(&mut pool, &[a, b, c]), 2);
        assert_eq!(radix.len(), 3);
        for id in [a, b, c] {
            assert!(pool.is_cold(radix.segment_of(id)));
        }
        pool.debug_assert_all_free();
    }
}
