//! ReLU^α attention (Definition 1.2).
//!
//! A_r = ReLU^α(QK^T/√d − b), D = diag(A_r·1_n), out = D^{-1} A_r V.
//! The crucial property the paper exploits: entries with score ≤ b
//! contribute *exactly zero*, so evaluating only the HSR-reported set
//! {j : <q,K_j>/√d ≥ b} is **error-free** (unlike the softmax case which
//! pays the Theorem 4.3 approximation error).
//!
//! Rows whose activations are all zero have D_ii = 0; we define the output
//! row as zero in that case (the paper's D^{-1} is undefined there — the
//! Lemma 6.1 threshold makes this a measure-zero event for Gaussian data,
//! but the engine must not NaN on it).

use super::{axpy_row, scores_into, scores_subset_into};

/// ReLU(x)^α for integer α ≥ 1.
#[inline]
pub fn relu_pow(x: f32, alpha: u32) -> f32 {
    if x <= 0.0 {
        return 0.0;
    }
    match alpha {
        1 => x,
        2 => x * x,
        3 => x * x * x,
        a => x.powi(a as i32),
    }
}

/// Dense ReLU^α attention for one query row (naive O(nd) baseline).
/// `out` length d.
pub fn relu_attention_row(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    alpha: u32,
    bias: f32,
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores_into(q, keys, d, scores_buf);
    out.fill(0.0);
    let mut denom = 0f32;
    for s in scores_buf.iter_mut() {
        *s = relu_pow(*s - bias, alpha);
        denom += *s;
    }
    if denom <= 0.0 {
        return;
    }
    let inv = 1.0 / denom;
    for (j, &a) in scores_buf.iter().enumerate() {
        if a > 0.0 {
            axpy_row(out, values, d, j, a * inv);
        }
    }
}

/// Sparse ReLU^α attention evaluated only on `idx` — exact whenever `idx`
/// is a superset of the activated set {j : score_j > b} (Algorithm 1
/// line 17-18 / Algorithm 2 line 12-13).
pub fn relu_attention_row_sparse(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    alpha: u32,
    bias: f32,
    idx: &[u32],
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores_subset_into(q, keys, d, idx, scores_buf);
    relu_attention_row_scored(idx, scores_buf, values, d, alpha, bias, out);
}

/// Sparse ReLU^α attention over an index set whose **scaled scores are
/// already known** (carried out of a score-reporting HSR query), so no
/// inner product is recomputed. `scaled_scores[t]` must be
/// `<q, K_{idx_t}>/√d`; the buffer is consumed (rewritten to ReLU^α
/// activation weights in place).
pub fn relu_attention_row_scored(
    idx: &[u32],
    scaled_scores: &mut [f32],
    values: &[f32],
    d: usize,
    alpha: u32,
    bias: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(idx.len(), scaled_scores.len());
    out.fill(0.0);
    let denom = relu_weights_in_place(scaled_scores, alpha, bias);
    if denom <= 0.0 {
        return;
    }
    let inv = 1.0 / denom;
    for (t, &a) in scaled_scores.iter().enumerate() {
        if a > 0.0 {
            axpy_row(out, values, d, idx[t] as usize, a * inv);
        }
    }
}

/// Weight phase of the scored ReLU row shared with the batched decode
/// path: rewrites each scaled score s to ReLU(s − bias)^α in place and
/// returns the normalizer Σ weights (≤ 0 means an all-inactive row).
#[inline]
pub fn relu_weights_in_place(scaled_scores: &mut [f32], alpha: u32, bias: f32) -> f32 {
    let mut denom = 0f32;
    for s in scaled_scores.iter_mut() {
        *s = relu_pow(*s - bias, alpha);
        denom += *s;
    }
    denom
}

/// Dense ReLU^α attention over full Q (m×d): Definition 1.2 verbatim.
pub fn relu_attention(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    alpha: u32,
    bias: f32,
) -> Vec<f32> {
    let m = q.len() / d;
    let mut out = vec![0f32; m * d];
    let mut buf = Vec::new();
    for i in 0..m {
        relu_attention_row(
            &q[i * d..(i + 1) * d],
            keys,
            values,
            d,
            alpha,
            bias,
            &mut buf,
            &mut out[i * d..(i + 1) * d],
        );
    }
    out
}

/// Count activated entries per row of the attention matrix — the
/// \tilde{k}_i of Lemma 6.1, measured exactly.
pub fn count_activated(q: &[f32], keys: &[f32], d: usize, bias: f32) -> Vec<usize> {
    let m = q.len() / d;
    let n = keys.len() / d;
    let mut buf = vec![0f32; n];
    let mut counts = Vec::with_capacity(m);
    for i in 0..m {
        scores_into(&q[i * d..(i + 1) * d], keys, d, &mut buf);
        counts.push(buf.iter().filter(|&&s| s - bias > 0.0).count());
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::linf;
    use crate::hsr::dot;
    use crate::util::rng::Rng;

    #[test]
    fn relu_pow_cases() {
        assert_eq!(relu_pow(-1.0, 1), 0.0);
        assert_eq!(relu_pow(0.0, 3), 0.0);
        assert_eq!(relu_pow(2.0, 1), 2.0);
        assert_eq!(relu_pow(2.0, 2), 4.0);
        assert_eq!(relu_pow(2.0, 3), 8.0);
        assert_eq!(relu_pow(2.0, 4), 16.0);
    }

    /// The core exactness property: evaluating only the activated set
    /// reproduces the dense result bit-for-bit up to float associativity.
    #[test]
    fn sparse_on_activated_set_is_exact() {
        let mut rng = Rng::new(19);
        for alpha in [1u32, 2, 3] {
            let (m, n, d) = (4usize, 120usize, 8usize);
            let q = rng.gaussian_vec_f32(m * d, 1.0);
            let k = rng.gaussian_vec_f32(n * d, 1.0);
            let v = rng.gaussian_vec_f32(n * d, 1.0);
            let bias = 0.4f32;
            let dense = relu_attention(&q, &k, &v, d, alpha, bias);
            let inv_sqrt_d = 1.0 / (d as f32).sqrt();
            let mut buf = Vec::new();
            for i in 0..m {
                let qi = &q[i * d..(i + 1) * d];
                // Activated set computed independently.
                let idx: Vec<u32> = (0..n)
                    .filter(|&j| dot(qi, &k[j * d..(j + 1) * d]) * inv_sqrt_d - bias > 0.0)
                    .map(|j| j as u32)
                    .collect();
                let mut out = vec![0f32; d];
                relu_attention_row_sparse(qi, &k, &v, d, alpha, bias, &idx, &mut buf, &mut out);
                assert!(
                    linf(&out, &dense[i * d..(i + 1) * d]) < 1e-5,
                    "alpha={alpha} row={i}"
                );
            }
        }
    }

    #[test]
    fn superset_indices_still_exact() {
        // Extra non-activated indices must not change the result: their
        // ReLU contribution is zero by construction.
        let mut rng = Rng::new(20);
        let (n, d) = (60usize, 4usize);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let bias = 0.3f32;
        let dense = relu_attention(&q, &k, &v, d, 2, bias);
        let all: Vec<u32> = (0..n as u32).collect();
        let mut buf = Vec::new();
        let mut out = vec![0f32; d];
        relu_attention_row_sparse(&q, &k, &v, d, 2, bias, &all, &mut buf, &mut out);
        assert!(linf(&out, &dense) < 1e-5);
    }

    #[test]
    fn all_below_threshold_yields_zero_row() {
        let q = [1.0f32, 0.0];
        let k = [-5.0f32, 0.0, -3.0, 0.0];
        let v = [1.0f32, 1.0, 1.0, 1.0];
        let out = relu_attention(&q, &k, &v, 2, 1, 0.0);
        assert_eq!(out, vec![0.0, 0.0]);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn weights_are_convex_combination() {
        // With V = all-ones, any normalized attention returns ones.
        let mut rng = Rng::new(21);
        let (n, d) = (50usize, 6usize);
        let q = rng.gaussian_vec_f32(d, 2.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = vec![1f32; n * d];
        let out = relu_attention(&q, &k, &v, d, 2, -10.0);
        for &x in &out {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn count_activated_matches_manual() {
        let mut rng = Rng::new(22);
        let (m, n, d) = (3usize, 200usize, 4usize);
        let q = rng.gaussian_vec_f32(m * d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let counts = count_activated(&q, &k, d, 0.5);
        assert_eq!(counts.len(), m);
        let inv = 1.0 / (d as f32).sqrt();
        for i in 0..m {
            let qi = &q[i * d..(i + 1) * d];
            let manual = (0..n)
                .filter(|&j| dot(qi, &k[j * d..(j + 1) * d]) * inv > 0.5)
                .count();
            assert_eq!(counts[i], manual);
        }
    }
}
