//! Wire protocol (JSON lines) for the serving front-end.
//!
//! One JSON object per line, in either direction. Success lines carry
//! `id`/`text`/`finish`/latency fields; error lines carry the schema
//! `{"error": <message>, "code": <short-code>, "retry_after_ms": <ms>?}`
//! (see the README "Failure model" section).
//!
//! # Streaming frames
//!
//! A request with `"stream": true` is answered with a sequence of
//! frames, each a JSON line carrying `id` and `event`:
//!
//! * `token` — one generated token, with a 0-based `seq` number that is
//!   **contiguous** (`0, 1, 2, ...`, no gaps, no reordering);
//! * exactly **one terminal frame** ends every stream, always:
//!   `done` (clean finish: `length`/`stop`), `error` (`code`,
//!   `tokens_streamed`, optional `retry_after_ms`), or `cancelled`
//!   (`reason`: `deadline`/`cancelled`/`aborted`/`timeout`). Terminal
//!   frames carry `tokens_streamed` so truncation is always detectable:
//!   a client holding k token frames knows the stream is complete iff
//!   the terminal frame says k;
//! * `keepalive` frames may appear between tokens while decode is busy
//!   (prefill, queueing) and carry no data — clients skip them.
//!
//! # Grouped requests (parallel sampling / beam search)
//!
//! A request with `"n"`/`"best_of"` ≥ 2 or `"beam_width"` ≥ 2 decodes
//! several sibling hypotheses off one shared prompt. Buffered replies
//! gain a `choices` array (ranked best-first; the flat `text`/`finish`
//! mirror the best choice). Streams interleave siblings on one
//! connection: every frame carries a `sibling` index (omitted when 0,
//! so plain streams are byte-identical to the pre-fork wire format),
//! `seq` stays globally contiguous across the whole stream, and every
//! sibling gets **exactly one terminal frame**, tagged with its
//! `sibling` plus the total `siblings` count (omitted when 1) so
//! clients know how many terminals to await. Beam losers and dropped
//! `best_of` candidates close with `cancelled`/`"pruned"`.

use crate::engine::{Choice, FinishReason, Response};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use anyhow::Result;

/// Parsed inbound request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub stop_token: Option<u32>,
    /// Relative deadline in milliseconds from receipt; the engine
    /// aborts the request past it with finish `"deadline"`.
    pub deadline_ms: Option<u64>,
    /// Stream tokens as they decode (`token` frames + one terminal
    /// frame) instead of one buffered response line.
    pub stream: bool,
    /// Parallel samples to return (`"n"`); clamped to [1, 64]. Values
    /// ≥ 2 return a `choices` array.
    pub n: u32,
    /// Candidates to decode (`"best_of"`, 0 → same as `n`); clamped to
    /// [0, 64]. The best `n` by cumulative log-probability come back.
    pub best_of: u32,
    /// Beam-search width (`"beam_width"`, 0/1 → off); clamped to
    /// [0, 32]. Overrides `n`/`best_of`.
    pub beam_width: u32,
}

impl Default for WireRequest {
    /// The wire defaults: what [`parse_request`] fills in for every
    /// omitted field (the empty prompt itself would be rejected).
    fn default() -> Self {
        WireRequest {
            prompt: String::new(),
            max_new_tokens: 64,
            temperature: 0.0,
            stop_token: None,
            deadline_ms: None,
            stream: false,
            n: 1,
            best_of: 0,
            beam_width: 0,
        }
    }
}

/// Parse a request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = v.req_str("prompt")?.to_string();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(64)
        .clamp(1, 4096);
    let temperature = v
        .get("temperature")
        .and_then(|x| x.as_f64())
        .unwrap_or(0.0) as f32;
    let stop_token = v
        .get("stop_token")
        .and_then(|x| x.as_usize())
        .map(|t| t as u32);
    let deadline_ms = v
        .get("deadline_ms")
        .and_then(|x| x.as_usize())
        .map(|ms| ms as u64);
    let stream = v.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
    let n = v
        .get("n")
        .and_then(|x| x.as_usize())
        .unwrap_or(1)
        .clamp(1, 64) as u32;
    let best_of = v
        .get("best_of")
        .and_then(|x| x.as_usize())
        .unwrap_or(0)
        .min(64) as u32;
    let beam_width = v
        .get("beam_width")
        .and_then(|x| x.as_usize())
        .unwrap_or(0)
        .min(32) as u32;
    Ok(WireRequest {
        prompt,
        max_new_tokens,
        temperature,
        stop_token,
        deadline_ms,
        stream,
        n,
        best_of,
        beam_width,
    })
}

/// Render a request line (the inverse of [`parse_request`] for values
/// already inside the clamped ranges — used by clients and the
/// round-trip property tests).
pub fn render_request(req: &WireRequest) -> String {
    let mut o = Json::obj();
    o.set("prompt", req.prompt.as_str().into())
        .set("max_new_tokens", req.max_new_tokens.into())
        .set("temperature", (req.temperature as f64).into());
    if let Some(t) = req.stop_token {
        o.set("stop_token", (t as usize).into());
    }
    if let Some(ms) = req.deadline_ms {
        o.set("deadline_ms", ms.into());
    }
    if req.stream {
        o.set("stream", true.into());
    }
    if req.n != 1 {
        o.set("n", (req.n as usize).into());
    }
    if req.best_of != 0 {
        o.set("best_of", (req.best_of as usize).into());
    }
    if req.beam_width != 0 {
        o.set("beam_width", (req.beam_width as usize).into());
    }
    o.to_string()
}

/// Stable wire name of a finish reason.
pub fn finish_str(finish: FinishReason) -> &'static str {
    match finish {
        FinishReason::Length => "length",
        FinishReason::StopToken => "stop",
        FinishReason::Aborted => "aborted",
        FinishReason::DeadlineExceeded => "deadline",
        FinishReason::Cancelled => "cancelled",
    }
}

/// Render a response line. Grouped responses (parallel sampling /
/// beam) carry a ranked `choices` array; `text`/`finish` mirror the
/// best choice so single-answer consumers keep working.
pub fn render_response(resp: &Response, tokenizer: &ByteTokenizer) -> String {
    let mut o = Json::obj();
    o.set("id", resp.id.into())
        .set("text", tokenizer.decode(&resp.tokens).into())
        .set("latency_ms", resp.latency_ms.into())
        .set("ttft_ms", resp.ttft_ms.into())
        .set("prompt_len", resp.prompt_len.into())
        .set("finish", finish_str(resp.finish).into());
    if !resp.choices.is_empty() {
        let arr: Vec<Json> = resp
            .choices
            .iter()
            .map(|c| {
                let mut co = Json::obj();
                co.set("index", (c.index as usize).into())
                    .set("text", tokenizer.decode(&c.tokens).into())
                    .set("finish", finish_str(c.finish).into())
                    .set("logprob", c.logprob.into());
                co
            })
            .collect();
        o.set("choices", Json::Arr(arr));
    }
    o.to_string()
}

/// Render a structured error line: `error` (human message), `code`
/// (stable short code), optional `retry_after_ms` backpressure hint.
pub fn render_error(code: &str, message: &str, retry_after_ms: Option<u64>) -> String {
    let mut o = Json::obj();
    o.set("error", message.into()).set("code", code.into());
    if let Some(ms) = retry_after_ms {
        o.set("retry_after_ms", ms.into());
    }
    o.to_string()
}

/// Encoding of a `stats` admin reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsFormat {
    /// Structured snapshot (`{"event":"stats","stats":{...}}`).
    Json,
    /// Prometheus text exposition carried as one JSON string
    /// (`{"event":"stats","format":"prometheus","text":"..."}`).
    Prometheus,
}

impl StatsFormat {
    /// Stable wire name (the request's `format` field).
    pub fn wire_name(self) -> &'static str {
        match self {
            StatsFormat::Json => "json",
            StatsFormat::Prometheus => "prometheus",
        }
    }
}

/// Parsed admin frame: `{"cmd": ...}` lines on a serving connection,
/// dispatched *before* request parsing (they carry no prompt).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdminCmd {
    /// Live metrics scrape: `{"cmd":"stats"}`, optionally with
    /// `"format":"prometheus"` for text exposition.
    Stats { format: StatsFormat },
}

/// Detect and parse an admin frame. Returns `None` when the line is
/// not an admin frame at all (no parseable object with a `cmd` key) —
/// the caller falls through to [`parse_request`] and its error paths —
/// and `Some(Err(..))` for a `cmd` frame that is malformed (unknown
/// command or bad format), which deserves a structured error reply
/// rather than an "empty prompt" one.
pub fn parse_admin(line: &str) -> Option<Result<AdminCmd>> {
    let v = Json::parse(line).ok()?;
    let cmd = v.get("cmd")?.as_str();
    Some(match cmd {
        Some("stats") => {
            match v.get("format").map(|f| f.as_str()) {
                None | Some(Some("json")) => {
                    Ok(AdminCmd::Stats { format: StatsFormat::Json })
                }
                Some(Some("prometheus")) => {
                    Ok(AdminCmd::Stats { format: StatsFormat::Prometheus })
                }
                Some(other) => Err(anyhow::anyhow!(
                    "unknown stats format {:?}",
                    other.unwrap_or("<non-string>")
                )),
            }
        }
        Some(other) => Err(anyhow::anyhow!("unknown admin command {other:?}")),
        None => Err(anyhow::anyhow!("admin 'cmd' must be a string")),
    })
}

/// Render a `stats` admin request line.
pub fn render_stats_request(format: StatsFormat) -> String {
    let mut o = Json::obj();
    o.set("cmd", "stats".into());
    if format != StatsFormat::Json {
        o.set("format", format.wire_name().into());
    }
    o.to_string()
}

/// Render the JSON-snapshot reply to a `stats` admin frame.
pub fn render_stats_response(stats: Json) -> String {
    let mut o = Json::obj();
    o.set("event", "stats".into()).set("stats", stats);
    o.to_string()
}

/// Render the Prometheus-text reply to a `stats` admin frame (the
/// exposition rides as one JSON string so the connection stays a
/// JSON-lines stream).
pub fn render_stats_text_response(text: &str) -> String {
    let mut o = Json::obj();
    o.set("event", "stats".into())
        .set("format", "prometheus".into())
        .set("text", text.into());
    o.to_string()
}

/// One parsed `stats` reply, either encoding.
#[derive(Debug, Clone, PartialEq)]
pub enum StatsReply {
    /// Structured snapshot object.
    Json(Json),
    /// Prometheus text exposition.
    Text(String),
}

/// Parse a `stats` reply line (the inverse of the render pair above).
pub fn parse_stats_response(line: &str) -> Result<StatsReply> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    anyhow::ensure!(v.req_str("event")? == "stats", "not a stats reply");
    if v.get("format").and_then(|f| f.as_str()) == Some("prometheus") {
        return Ok(StatsReply::Text(v.req_str("text")?.to_string()));
    }
    let stats = v
        .get("stats")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("stats reply missing 'stats' object"))?;
    Ok(StatsReply::Json(stats))
}

/// One parsed streaming frame (see the module docs for the grammar).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamFrame {
    /// One generated token; `seq` is 0-based and contiguous across the
    /// whole stream (all siblings interleaved); `sibling` says which
    /// hypothesis of a grouped request produced it (0 for plain
    /// streams; omitted on the wire when 0).
    Token { id: u64, seq: u64, token: u32, text: String, sibling: u32 },
    /// Terminal: clean finish of one sibling (`sibling`/`siblings`
    /// default 0/1 for plain streams and are omitted on the wire
    /// then). `tokens_streamed` counts this sibling's own `token`
    /// frames; `text` is the sibling's full decoded generation.
    Done {
        id: u64,
        tokens_streamed: u64,
        finish: String,
        text: String,
        latency_ms: f64,
        ttft_ms: f64,
        prompt_len: usize,
        sibling: u32,
        siblings: u32,
    },
    /// Terminal: the sibling failed after `tokens_streamed` of its
    /// tokens went out (truncation point). `code` is a stable short
    /// code (`worker_failed`, `slow_consumer`, ...).
    Error {
        id: u64,
        code: String,
        message: String,
        tokens_streamed: u64,
        retry_after_ms: Option<u64>,
        sibling: u32,
        siblings: u32,
    },
    /// Terminal: the sibling's stream was cut short deliberately
    /// (`reason` ∈ deadline / cancelled / aborted / timeout / pruned —
    /// `pruned` closes beam losers and dropped `best_of` candidates).
    Cancelled {
        id: u64,
        reason: String,
        tokens_streamed: u64,
        sibling: u32,
        siblings: u32,
    },
    /// Non-terminal heartbeat while decode is busy; carries no data.
    Keepalive { id: u64 },
}

impl StreamFrame {
    /// Terminal frames end one sibling's stream; a full stream is over
    /// after `siblings()` of them.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            StreamFrame::Done { .. }
                | StreamFrame::Error { .. }
                | StreamFrame::Cancelled { .. }
        )
    }

    /// Total terminal frames this stream will carry (from any terminal
    /// frame's `siblings` tag); `None` for non-terminal frames.
    pub fn siblings(&self) -> Option<u32> {
        match self {
            StreamFrame::Done { siblings, .. }
            | StreamFrame::Error { siblings, .. }
            | StreamFrame::Cancelled { siblings, .. } => Some(*siblings),
            _ => None,
        }
    }
}

/// Tag a frame object with `sibling`/`siblings`, omitting the plain
/// defaults (0 and 1) so single-sequence streams keep the pre-fork
/// byte format.
fn tag_sibling(o: &mut Json, sibling: u32, siblings: u32) {
    if sibling != 0 {
        o.set("sibling", (sibling as usize).into());
    }
    if siblings != 1 {
        o.set("siblings", (siblings as usize).into());
    }
}

/// Render a `token` frame.
pub fn render_token_frame(
    id: u64,
    seq: u64,
    token: u32,
    sibling: u32,
    tokenizer: &ByteTokenizer,
) -> String {
    let mut o = Json::obj();
    o.set("id", id.into())
        .set("event", "token".into())
        .set("seq", seq.into())
        .set("token", (token as u64).into())
        .set("text", tokenizer.decode(&[token]).into());
    tag_sibling(&mut o, sibling, 1);
    o.to_string()
}

/// Render the terminal `done` frame for a cleanly finished plain
/// (single-sequence) stream. Grouped streams render one
/// [`render_choice_done_frame`] per surviving choice instead.
pub fn render_done_frame(
    resp: &Response,
    tokens_streamed: u64,
    tokenizer: &ByteTokenizer,
) -> String {
    let mut o = Json::obj();
    o.set("id", resp.id.into())
        .set("event", "done".into())
        .set("tokens_streamed", tokens_streamed.into())
        .set("finish", finish_str(resp.finish).into())
        .set("text", tokenizer.decode(&resp.tokens).into())
        .set("latency_ms", resp.latency_ms.into())
        .set("ttft_ms", resp.ttft_ms.into())
        .set("prompt_len", resp.prompt_len.into());
    o.to_string()
}

/// Render the terminal `done` frame of one grouped-stream sibling: the
/// choice's own text/finish/logprob, the group's latency/ttft, and the
/// `sibling`/`siblings` tags.
pub fn render_choice_done_frame(
    resp: &Response,
    choice: &Choice,
    siblings: u32,
    tokens_streamed: u64,
    tokenizer: &ByteTokenizer,
) -> String {
    let mut o = Json::obj();
    o.set("id", resp.id.into())
        .set("event", "done".into())
        .set("tokens_streamed", tokens_streamed.into())
        .set("finish", finish_str(choice.finish).into())
        .set("text", tokenizer.decode(&choice.tokens).into())
        .set("logprob", choice.logprob.into())
        .set("latency_ms", resp.latency_ms.into())
        .set("ttft_ms", resp.ttft_ms.into())
        .set("prompt_len", resp.prompt_len.into());
    tag_sibling(&mut o, choice.index, siblings);
    o.to_string()
}

/// Render a terminal `error` frame (plain stream: sibling 0 of 1).
pub fn render_stream_error(
    id: u64,
    code: &str,
    message: &str,
    tokens_streamed: u64,
    retry_after_ms: Option<u64>,
) -> String {
    render_stream_error_sibling(id, code, message, tokens_streamed, retry_after_ms, 0, 1)
}

/// Render one sibling's terminal `error` frame.
pub fn render_stream_error_sibling(
    id: u64,
    code: &str,
    message: &str,
    tokens_streamed: u64,
    retry_after_ms: Option<u64>,
    sibling: u32,
    siblings: u32,
) -> String {
    let mut o = Json::obj();
    o.set("id", id.into())
        .set("event", "error".into())
        .set("error", message.into())
        .set("code", code.into())
        .set("tokens_streamed", tokens_streamed.into());
    if let Some(ms) = retry_after_ms {
        o.set("retry_after_ms", ms.into());
    }
    tag_sibling(&mut o, sibling, siblings);
    o.to_string()
}

/// Render a terminal `cancelled` frame (plain stream: sibling 0 of 1).
pub fn render_cancelled_frame(id: u64, reason: &str, tokens_streamed: u64) -> String {
    render_cancelled_frame_sibling(id, reason, tokens_streamed, 0, 1)
}

/// Render one sibling's terminal `cancelled` frame.
pub fn render_cancelled_frame_sibling(
    id: u64,
    reason: &str,
    tokens_streamed: u64,
    sibling: u32,
    siblings: u32,
) -> String {
    let mut o = Json::obj();
    o.set("id", id.into())
        .set("event", "cancelled".into())
        .set("reason", reason.into())
        .set("tokens_streamed", tokens_streamed.into());
    tag_sibling(&mut o, sibling, siblings);
    o.to_string()
}

/// Render a `keepalive` frame.
pub fn render_keepalive(id: u64) -> String {
    let mut o = Json::obj();
    o.set("id", id.into()).set("event", "keepalive".into());
    o.to_string()
}

/// Parse any streaming frame line (the inverse of the `render_*_frame`
/// family). Never panics on malformed input — errors instead.
pub fn parse_frame(line: &str) -> Result<StreamFrame> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let id = v.req_usize("id")? as u64;
    // Absent sibling tags mean "plain stream": sibling 0, 1 terminal.
    let sibling = v.get("sibling").and_then(|x| x.as_usize()).unwrap_or(0) as u32;
    let siblings = v.get("siblings").and_then(|x| x.as_usize()).unwrap_or(1) as u32;
    match v.req_str("event")? {
        "token" => Ok(StreamFrame::Token {
            id,
            seq: v.req_usize("seq")? as u64,
            token: v.req_usize("token")? as u32,
            text: v.req_str("text")?.to_string(),
            sibling,
        }),
        "done" => Ok(StreamFrame::Done {
            id,
            tokens_streamed: v.req_usize("tokens_streamed")? as u64,
            finish: v.req_str("finish")?.to_string(),
            text: v.req_str("text")?.to_string(),
            latency_ms: v.req_f64("latency_ms")?,
            ttft_ms: v.req_f64("ttft_ms")?,
            prompt_len: v.req_usize("prompt_len")?,
            sibling,
            siblings,
        }),
        "error" => Ok(StreamFrame::Error {
            id,
            code: v.req_str("code")?.to_string(),
            message: v.req_str("error")?.to_string(),
            tokens_streamed: v.req_usize("tokens_streamed")? as u64,
            retry_after_ms: v
                .get("retry_after_ms")
                .and_then(|x| x.as_usize())
                .map(|ms| ms as u64),
            sibling,
            siblings,
        }),
        "cancelled" => Ok(StreamFrame::Cancelled {
            id,
            reason: v.req_str("reason")?.to_string(),
            tokens_streamed: v.req_usize("tokens_streamed")? as u64,
            sibling,
            siblings,
        }),
        "keepalive" => Ok(StreamFrame::Keepalive { id }),
        other => anyhow::bail!("unknown stream event {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let r = parse_request(
            r#"{"prompt":"hello","max_new_tokens":12,"temperature":0.5,"stop_token":46,"deadline_ms":1500}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.max_new_tokens, 12);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.stop_token, Some(46));
        assert_eq!(r.deadline_ms, Some(1500));
    }

    #[test]
    fn defaults_and_validation() {
        let r = parse_request(r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.stop_token, None);
        assert_eq!(r.deadline_ms, None);
        assert!(parse_request(r#"{"prompt":""}"#).is_err());
        assert!(parse_request("not json").is_err());
        // max_new_tokens clamped.
        let r = parse_request(r#"{"prompt":"x","max_new_tokens":100000}"#).unwrap();
        assert_eq!(r.max_new_tokens, 4096);
    }

    #[test]
    fn render_roundtrips_through_json() {
        let resp = Response {
            id: 9,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            latency_ms: 1.5,
            ttft_ms: 0.5,
            prompt_len: 3,
            choices: Vec::new(),
        };
        let line = render_response(&resp, &ByteTokenizer);
        assert!(!line.contains("choices"), "plain responses carry no choices array");
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req_str("text").unwrap(), "hi");
        assert_eq!(v.req_usize("id").unwrap(), 9);
        assert_eq!(v.req_str("finish").unwrap(), "length");
    }

    #[test]
    fn request_roundtrips_through_render() {
        let req = WireRequest {
            prompt: "say \"hi\"\n".to_string(),
            max_new_tokens: 7,
            temperature: 0.25,
            stop_token: Some(10),
            deadline_ms: Some(250),
            stream: false,
            n: 1,
            best_of: 0,
            beam_width: 0,
        };
        let parsed = parse_request(&render_request(&req)).unwrap();
        assert_eq!(parsed, req);
        let req = WireRequest { stream: true, ..req };
        let line = render_request(&req);
        assert!(line.contains("\"stream\":true"));
        // Default group fields stay off the wire entirely.
        assert!(!line.contains("\"n\""));
        assert!(!line.contains("best_of"));
        assert!(!line.contains("beam_width"));
        assert_eq!(parse_request(&line).unwrap(), req);
    }

    #[test]
    fn grouped_request_fields_roundtrip() {
        let req = WireRequest {
            prompt: "p".to_string(),
            max_new_tokens: 4,
            temperature: 0.75,
            stop_token: None,
            deadline_ms: None,
            stream: true,
            n: 4,
            best_of: 8,
            beam_width: 3,
        };
        assert_eq!(parse_request(&render_request(&req)).unwrap(), req);
        // Clamps: n in 1..=64, best_of/beam_width capped.
        let r = parse_request(r#"{"prompt":"x","n":0}"#).unwrap();
        assert_eq!(r.n, 1);
        let r = parse_request(r#"{"prompt":"x","n":1000,"best_of":1000,"beam_width":1000}"#)
            .unwrap();
        assert_eq!((r.n, r.best_of, r.beam_width), (64, 64, 32));
    }

    #[test]
    fn stream_frames_roundtrip() {
        let token_line = render_token_frame(7, 3, 104, 0, &ByteTokenizer);
        // Plain frames stay byte-compatible: no sibling tags when 0/1.
        assert!(!token_line.contains("sibling"));
        let f = parse_frame(&token_line).unwrap();
        assert_eq!(
            f,
            StreamFrame::Token { id: 7, seq: 3, token: 104, text: "h".to_string(), sibling: 0 }
        );
        let resp = Response {
            id: 7,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            latency_ms: 1.5,
            ttft_ms: 0.5,
            prompt_len: 3,
            choices: Vec::new(),
        };
        let done_line = render_done_frame(&resp, 2, &ByteTokenizer);
        assert!(!done_line.contains("sibling"));
        let f = parse_frame(&done_line).unwrap();
        assert_eq!(
            f,
            StreamFrame::Done {
                id: 7,
                tokens_streamed: 2,
                finish: "length".to_string(),
                text: "hi".to_string(),
                latency_ms: 1.5,
                ttft_ms: 0.5,
                prompt_len: 3,
                sibling: 0,
                siblings: 1,
            }
        );
        let f = parse_frame(&render_stream_error(7, "worker_failed", "boom", 2, Some(50)))
            .unwrap();
        assert_eq!(
            f,
            StreamFrame::Error {
                id: 7,
                code: "worker_failed".to_string(),
                message: "boom".to_string(),
                tokens_streamed: 2,
                retry_after_ms: Some(50),
                sibling: 0,
                siblings: 1,
            }
        );
        let f = parse_frame(&render_cancelled_frame(7, "deadline", 2)).unwrap();
        assert_eq!(
            f,
            StreamFrame::Cancelled {
                id: 7,
                reason: "deadline".to_string(),
                tokens_streamed: 2,
                sibling: 0,
                siblings: 1,
            }
        );
        let f = parse_frame(&render_keepalive(7)).unwrap();
        assert_eq!(f, StreamFrame::Keepalive { id: 7 });
    }

    #[test]
    fn sibling_tagged_frames_roundtrip() {
        let f = parse_frame(&render_token_frame(7, 9, 104, 2, &ByteTokenizer)).unwrap();
        assert_eq!(
            f,
            StreamFrame::Token { id: 7, seq: 9, token: 104, text: "h".to_string(), sibling: 2 }
        );
        let resp = Response {
            id: 7,
            tokens: vec![104],
            finish: FinishReason::Length,
            latency_ms: 2.0,
            ttft_ms: 1.0,
            prompt_len: 3,
            choices: Vec::new(),
        };
        let choice = Choice {
            index: 2,
            tokens: vec![104, 105],
            finish: FinishReason::StopToken,
            logprob: -1.25,
        };
        let line = render_choice_done_frame(&resp, &choice, 4, 2, &ByteTokenizer);
        let f = parse_frame(&line).unwrap();
        assert_eq!(
            f,
            StreamFrame::Done {
                id: 7,
                tokens_streamed: 2,
                finish: "stop".to_string(),
                text: "hi".to_string(),
                latency_ms: 2.0,
                ttft_ms: 1.0,
                prompt_len: 3,
                sibling: 2,
                siblings: 4,
            }
        );
        assert_eq!(f.siblings(), Some(4));
        assert!(f.is_terminal());
        let f = parse_frame(&render_stream_error_sibling(
            7, "worker_failed", "boom", 1, None, 1, 3,
        ))
        .unwrap();
        assert_eq!(f.siblings(), Some(3));
        let f = parse_frame(&render_cancelled_frame_sibling(7, "pruned", 0, 3, 4)).unwrap();
        assert_eq!(
            f,
            StreamFrame::Cancelled {
                id: 7,
                reason: "pruned".to_string(),
                tokens_streamed: 0,
                sibling: 3,
                siblings: 4,
            }
        );
        assert!(!StreamFrame::Keepalive { id: 7 }.is_terminal());
        assert_eq!(StreamFrame::Keepalive { id: 7 }.siblings(), None);
    }

    #[test]
    fn grouped_response_renders_choices() {
        let resp = Response {
            id: 11,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            latency_ms: 1.5,
            ttft_ms: 0.5,
            prompt_len: 3,
            choices: vec![
                Choice {
                    index: 0,
                    tokens: vec![104, 105],
                    finish: FinishReason::Length,
                    logprob: -0.5,
                },
                Choice {
                    index: 2,
                    tokens: vec![105],
                    finish: FinishReason::StopToken,
                    logprob: -0.75,
                },
            ],
        };
        let v = Json::parse(&render_response(&resp, &ByteTokenizer)).unwrap();
        let arr = match v.get("choices") {
            Some(Json::Arr(a)) => a,
            other => panic!("expected choices array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req_usize("index").unwrap(), 0);
        assert_eq!(arr[0].req_str("text").unwrap(), "hi");
        assert_eq!(arr[1].req_str("finish").unwrap(), "stop");
        assert!((arr[1].req_f64("logprob").unwrap() + 0.75).abs() < 1e-9);
    }

    #[test]
    fn parse_frame_rejects_malformed() {
        assert!(parse_frame("not json").is_err());
        assert!(parse_frame(r#"{"id":1}"#).is_err()); // no event
        assert!(parse_frame(r#"{"event":"token"}"#).is_err()); // no id
        assert!(parse_frame(r#"{"id":1,"event":"warp"}"#).is_err()); // unknown
        assert!(parse_frame(r#"{"id":1,"event":"token","seq":0}"#).is_err());
    }

    #[test]
    fn new_finish_reasons_render() {
        let mut resp = Response {
            id: 1,
            tokens: vec![],
            finish: FinishReason::DeadlineExceeded,
            latency_ms: 0.0,
            ttft_ms: 0.0,
            prompt_len: 1,
            choices: Vec::new(),
        };
        let v = Json::parse(&render_response(&resp, &ByteTokenizer)).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "deadline");
        resp.finish = FinishReason::Cancelled;
        let v = Json::parse(&render_response(&resp, &ByteTokenizer)).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "cancelled");
    }

    #[test]
    fn admin_frames_parse_and_roundtrip() {
        // Plain stats request, default JSON format.
        let cmd = parse_admin(r#"{"cmd":"stats"}"#).unwrap().unwrap();
        assert_eq!(cmd, AdminCmd::Stats { format: StatsFormat::Json });
        assert_eq!(
            parse_admin(&render_stats_request(StatsFormat::Json)).unwrap().unwrap(),
            AdminCmd::Stats { format: StatsFormat::Json }
        );
        assert_eq!(
            parse_admin(&render_stats_request(StatsFormat::Prometheus))
                .unwrap()
                .unwrap(),
            AdminCmd::Stats { format: StatsFormat::Prometheus }
        );
        // Explicit format names.
        let cmd = parse_admin(r#"{"cmd":"stats","format":"prometheus"}"#)
            .unwrap()
            .unwrap();
        assert_eq!(cmd, AdminCmd::Stats { format: StatsFormat::Prometheus });
        // Non-admin lines fall through (None), malformed admin errors.
        assert!(parse_admin(r#"{"prompt":"x"}"#).is_none());
        assert!(parse_admin("not json").is_none());
        assert!(parse_admin(r#"{"cmd":"reboot"}"#).unwrap().is_err());
        assert!(parse_admin(r#"{"cmd":7}"#).unwrap().is_err());
        assert!(parse_admin(r#"{"cmd":"stats","format":"xml"}"#).unwrap().is_err());
    }

    #[test]
    fn stats_replies_roundtrip() {
        let mut snap = Json::obj();
        snap.set("ts_us", 42usize.into());
        let line = render_stats_response(snap.clone());
        match parse_stats_response(&line).unwrap() {
            StatsReply::Json(v) => assert_eq!(v.req_usize("ts_us").unwrap(), 42),
            other => panic!("expected json reply, got {other:?}"),
        }
        let text = "# TYPE hsr_generated_tokens counter\nhsr_generated_tokens 7\n";
        let line = render_stats_text_response(text);
        match parse_stats_response(&line).unwrap() {
            StatsReply::Text(t) => assert_eq!(t, text),
            other => panic!("expected text reply, got {other:?}"),
        }
        assert!(parse_stats_response(r#"{"event":"token"}"#).is_err());
        assert!(parse_stats_response(r#"{"event":"stats"}"#).is_err());
        assert!(parse_stats_response("not json").is_err());
    }

    #[test]
    fn error_lines_follow_schema() {
        let line = render_error("overloaded", "server overloaded", Some(50));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req_str("code").unwrap(), "overloaded");
        assert_eq!(v.req_str("error").unwrap(), "server overloaded");
        assert_eq!(v.req_usize("retry_after_ms").unwrap(), 50);
        let v = Json::parse(&render_error("bad_request", "nope", None)).unwrap();
        assert!(v.get("retry_after_ms").is_none());
    }
}
