//! Approximation-error analysis for Softmax attention with an index set.
//!
//! * [`general_error_bound`] — Lemma G.1: ‖Attn − Âttn‖∞ ≤ 2(ᾱ/α)‖V‖∞,
//!   where ᾱ is the exp-mass excluded by the index set and α the total.
//! * [`massive_activation_bound`] — Theorem 4.3 / G.2: with the
//!   (γ, β₁, β₂) massive-activation property the bound specializes to
//!   2‖V‖∞ / n^{γ + (β₁−β₂)·‖q‖₂ − 1}.
//! * [`MassiveActivation`] — a measurement of Definition B.3's property on
//!   concrete (q, K): the largest (β₁, β₂) pair the data satisfies at a
//!   given γ.
//!
//! These are used by `benches/error_topr.rs` to show measured ℓ∞ errors
//! sit *under* the theoretical curve, mirroring the paper's Section 7
//! conclusion ("error using a few top entries is already insignificant").

use super::topk::top_r_indices;
use crate::hsr::{dot, norm};

/// Exp-mass split of Definition B.2: α̂ = Σ_{i∈R} exp(s_i),
/// ᾱ = Σ_{i∉R} exp(s_i), computed stably relative to the global max.
/// Returns (kept_frac, excluded_frac) = (α̂/α, ᾱ/α).
pub fn mass_split(scores: &[f32], selected: &[u32]) -> (f64, f64) {
    if scores.is_empty() {
        return (0.0, 0.0);
    }
    let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut in_set = vec![false; scores.len()];
    for &i in selected {
        in_set[i as usize] = true;
    }
    let mut kept = 0f64;
    let mut excluded = 0f64;
    for (i, &s) in scores.iter().enumerate() {
        let e = ((s as f64) - max).exp();
        if in_set[i] {
            kept += e;
        } else {
            excluded += e;
        }
    }
    let total = kept + excluded;
    (kept / total, excluded / total)
}

/// Lemma G.1: 2·(ᾱ/α)·‖V‖∞ for a concrete score row and index set.
pub fn general_error_bound(scores: &[f32], selected: &[u32], v_inf: f32) -> f64 {
    let (_, excluded) = mass_split(scores, selected);
    2.0 * excluded * v_inf as f64
}

/// Theorem 4.3's closed form: 2‖V‖∞ / n^{γ + (β₁−β₂)‖q‖₂ − 1}.
pub fn massive_activation_bound(
    n: usize,
    gamma: f64,
    beta1: f64,
    beta2: f64,
    q_norm: f64,
    v_inf: f64,
) -> f64 {
    let exponent = gamma + (beta1 - beta2) * q_norm - 1.0;
    2.0 * v_inf / (n as f64).powf(exponent)
}

/// Measured massive-activation parameters of a concrete (q, K) pair at a
/// given γ (Definition B.3):
///   β₁ = (mean of top-n^γ scores) / (‖q‖₂ ln n)
///   β₂ = (max of remaining scores) / (‖q‖₂ ln n)
/// The data satisfies the (γ, β₁, β₂) property for any β₁' ≤ β₁, β₂' ≥ β₂.
#[derive(Debug, Clone, Copy)]
pub struct MassiveActivation {
    pub gamma: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub q_norm: f64,
    /// Size of the top set n^γ (rounded).
    pub top: usize,
}

impl MassiveActivation {
    /// Measure on raw inner products <q, K_i> (Definition B.3 uses
    /// unscaled inner products).
    pub fn measure(q: &[f32], keys: &[f32], d: usize, gamma: f64) -> MassiveActivation {
        let n = keys.len() / d;
        assert!(n >= 2);
        let qn = norm(q) as f64;
        let scores: Vec<f32> = (0..n)
            .map(|i| dot(q, &keys[i * d..(i + 1) * d]))
            .collect();
        let top = ((n as f64).powf(gamma).round() as usize).clamp(1, n);
        let idx = top_r_indices(&scores, top);
        let mut in_top = vec![false; n];
        let mut top_sum = 0f64;
        for &i in &idx {
            in_top[i as usize] = true;
            top_sum += scores[i as usize] as f64;
        }
        let top_mean = top_sum / top as f64;
        let mut rest_max = f64::NEG_INFINITY;
        for (i, &s) in scores.iter().enumerate() {
            if !in_top[i] {
                rest_max = rest_max.max(s as f64);
            }
        }
        if !rest_max.is_finite() {
            rest_max = 0.0; // top covers everything
        }
        let ln_n = (n as f64).ln();
        let denom = qn * ln_n;
        MassiveActivation {
            gamma,
            beta1: if denom > 0.0 { top_mean / denom } else { 0.0 },
            beta2: if denom > 0.0 { rest_max / denom } else { 0.0 },
            q_norm: qn,
            top,
        }
    }

    /// Theorem 4.3 bound instantiated with the measured parameters.
    pub fn bound(&self, n: usize, v_inf: f64) -> f64 {
        massive_activation_bound(n, self.gamma, self.beta1, self.beta2, self.q_norm, v_inf)
    }
}

/// ℓ∞ norm of a value matrix — the ‖V‖∞ of every bound.
pub fn v_inf_norm(values: &[f32]) -> f32 {
    values.iter().map(|v| v.abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::softmax::{softmax_attention_row, softmax_attention_row_subset};
    use crate::attention::{linf, scores_into};
    use crate::util::rng::Rng;

    #[test]
    fn mass_split_sums_to_one() {
        let scores = [1.0f32, 2.0, 3.0, 4.0];
        let (kept, excl) = mass_split(&scores, &[2, 3]);
        assert!((kept + excl - 1.0).abs() < 1e-12);
        assert!(kept > excl); // top-2 hold most of the exp mass
    }

    #[test]
    fn full_set_has_zero_excluded_mass() {
        let scores = [0.5f32, -1.0, 2.0];
        let (kept, excl) = mass_split(&scores, &[0, 1, 2]);
        assert!((kept - 1.0).abs() < 1e-12);
        assert_eq!(excl, 0.0);
        assert_eq!(general_error_bound(&scores, &[0, 1, 2], 10.0), 0.0);
    }

    /// Lemma G.1 is a *sound* bound: measured ℓ∞ error ≤ bound on random
    /// instances, for every subset size.
    #[test]
    fn lemma_g1_bound_is_sound() {
        let mut rng = Rng::new(71);
        let (n, d) = (300usize, 16usize);
        for trial in 0..10 {
            let q = rng.gaussian_vec_f32(d, 1.0);
            let k = rng.gaussian_vec_f32(n * d, 1.0);
            let v = rng.gaussian_vec_f32(n * d, 1.0);
            let mut scores = vec![0f32; n];
            scores_into(&q, &k, d, &mut scores);
            let mut buf = Vec::new();
            let mut dense = vec![0f32; d];
            softmax_attention_row(&q, &k, &v, d, &mut buf, &mut dense);
            for r in [1usize, 4, 16, 64, n] {
                let idx = top_r_indices(&scores, r);
                let mut approx = vec![0f32; d];
                softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut approx);
                let err = linf(&dense, &approx) as f64;
                let bound = general_error_bound(&scores, &idx, v_inf_norm(&v));
                assert!(
                    err <= bound + 1e-5,
                    "trial={trial} r={r}: err {err} > bound {bound}"
                );
            }
        }
    }

    /// Error decreases monotonically (up to noise) as r grows — the
    /// Figure 3 phenomenon in miniature.
    #[test]
    fn error_shrinks_with_r() {
        let mut rng = Rng::new(72);
        let (n, d) = (512usize, 8usize);
        let q = rng.gaussian_vec_f32(d, 1.5);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let mut scores = vec![0f32; n];
        scores_into(&q, &k, d, &mut scores);
        let mut buf = Vec::new();
        let mut dense = vec![0f32; d];
        softmax_attention_row(&q, &k, &v, d, &mut buf, &mut dense);
        let mut last = f64::INFINITY;
        for r in [4usize, 16, 64, 256, 512] {
            let idx = top_r_indices(&scores, r);
            let mut approx = vec![0f32; d];
            softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut approx);
            let err = linf(&dense, &approx) as f64;
            assert!(err <= last * 1.5 + 1e-6, "r={r} err={err} last={last}");
            last = err.min(last);
        }
        // Full set → exact.
        assert!(last < 1e-5 || {
            let idx = top_r_indices(&scores, n);
            let mut approx = vec![0f32; d];
            softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut approx);
            (linf(&dense, &approx) as f64) < 1e-5
        });
    }

    /// On data that *has* the massive-activation property (planted heavy
    /// directions), Theorem 4.3's bound holds for the measured (β₁, β₂).
    #[test]
    fn theorem_4_3_bound_on_planted_data() {
        let mut rng = Rng::new(73);
        let (n, d) = (1024usize, 16usize);
        let gamma = 0.4;
        // Plant: top n^γ keys strongly aligned with q, the rest near-orthogonal.
        let q: Vec<f32> = rng.gaussian_vec_f32(d, 1.0);
        let qn = norm(&q);
        let top = (n as f64).powf(gamma).round() as usize;
        let mut k = vec![0f32; n * d];
        for i in 0..n {
            if i < top {
                for j in 0..d {
                    k[i * d + j] = q[j] / qn * 3.0 + rng.normal(0.0, 0.05) as f32;
                }
            } else {
                loop {
                    let cand = rng.gaussian_vec_f32(d, 0.3);
                    // Keep keys whose alignment with q is small.
                    if dot(&cand, &q).abs() < 0.5 * qn {
                        k[i * d..(i + 1) * d].copy_from_slice(&cand);
                        break;
                    }
                }
            }
        }
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let ma = MassiveActivation::measure(&q, &k, d, gamma);
        assert!(ma.beta1 > ma.beta2, "planting failed: {ma:?}");

        // Compare measured error vs the Theorem 4.3 bound. NOTE:
        // Definition B.3 works on unscaled <q,K_i>; Âttn in Definition B.2
        // likewise. Use unscaled scores for consistency (d=16 scaling is a
        // monotone transform so the index set is identical).
        let scores: Vec<f32> = (0..n).map(|i| dot(&q, &k[i * d..(i + 1) * d])).collect();
        let idx = top_r_indices(&scores, ma.top);
        let bound_g1 = general_error_bound(&scores, &idx, v_inf_norm(&v));
        let bound_43 = ma.bound(n, v_inf_norm(&v) as f64);
        // Theorem 4.3 relaxes Lemma G.1, so G.1 ≤ 4.3 on valid data.
        assert!(
            bound_g1 <= bound_43 * (1.0 + 1e-6),
            "G.1 {bound_g1} should be tighter than 4.3 {bound_43}"
        );
    }

    #[test]
    fn v_inf_norm_is_max_abs() {
        assert_eq!(v_inf_norm(&[1.0, -7.5, 3.0]), 7.5);
        assert_eq!(v_inf_norm(&[]), 0.0);
    }
}
