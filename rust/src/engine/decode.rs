//! Algorithm 1 — Generation Decoding.
//!
//! The paper's `GenerationDecoding` data structure, verbatim:
//!
//! ```text
//! INIT({K_i}, V, n, d):   b ← σ_a √(0.4 log n);  HSR.INIT({K_i}, n, d)
//! INFERENCE(Q, m):        for i in 1..m:
//!                           S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                           A_{i,j} ← ReLU^α(⟨Q_i,K_j⟩/√d − b)  (or Softmax)
//!                         return D^{-1} A V
//! ```
//!
//! The KV cache (K, V) is fixed at INIT (generation-decoding scenario,
//! m = Θ(1) queries per step); the paper's Part-2 HSR (heavy
//! preprocessing, cheap queries) maps to whichever backend the caller
//! selects — see DESIGN.md §3 for the substitution. Support for appending
//! freshly generated keys (the auto-regressive loop of Theorem D.2) comes
//! from the dynamic logarithmic-method wrapper.

use crate::attention::relu::relu_weights_in_place;
use crate::attention::threshold::ThresholdParams;
use crate::attention::topk::top_r_select_into;
use crate::attention::AttentionKind;
use crate::hsr::dynamic::DynamicHsr;
use crate::hsr::{HalfSpaceReport, HsrBackend, QueryStats};
use crate::kernel::simd;
use crate::kernel::Scratch;

/// How many value rows one union bucket packs per gather pass of the
/// batched evaluation: small enough that the packed tile stays L1/L2
/// resident while every row of the batch consumes it.
const BUCKET_ROWS: usize = 256;

/// The paper's Algorithm 1 over raw K/V matrices.
pub struct GenerationDecoding {
    /// HSR structure over the keys (dynamic: supports appends).
    hsr: DynamicHsr,
    /// Keys, row-major [n, d] (grows on append).
    keys: Vec<f32>,
    /// Values, row-major [n, d].
    values: Vec<f32>,
    d: usize,
    /// Threshold b on the scaled score ⟨q,k⟩/√d (Lemma 6.1).
    pub bias: f32,
    /// Which attention to evaluate on the reported set.
    pub kind: AttentionKind,
    /// For softmax: restrict to top-r of the report (Theorem 4.2);
    /// None → use the whole reported set.
    pub top_r: Option<usize>,
    /// Key std σ_k for the per-query adaptive softmax threshold.
    pub sigma_k: f64,
    /// Worker threads for the batched query-row loop: 0 → one per
    /// available core, 1 → serial. Output is bit-identical either way.
    pub threads: usize,
    /// Accumulated query-work counters.
    pub stats: QueryStats,
    /// Reusable row buffers (no allocation in the decode inner loop).
    scratch: Scratch,
    /// Extra per-worker arenas for the parallel batched path (lazily
    /// grown, reused across calls).
    pool: Vec<Scratch>,
}

/// Copyable per-call snapshot of the row-evaluation configuration, so
/// worker threads never borrow the (mutably held) structure itself.
#[derive(Clone, Copy)]
struct RowCfg {
    d: usize,
    n: usize,
    bias: f32,
    kind: AttentionKind,
    top_r: Option<usize>,
    sigma_k: f64,
}

impl GenerationDecoding {
    /// INIT: build the HSR structure over the KV cache.
    /// `bias` is on the scaled score; pass
    /// `ThresholdParams::practical_bias` / `bias` / a calibrated value.
    pub fn init(
        keys: &[f32],
        values: &[f32],
        d: usize,
        bias: f32,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        assert_eq!(keys.len(), values.len());
        assert_eq!(keys.len() % d, 0);
        GenerationDecoding {
            hsr: DynamicHsr::from_points(backend, keys, d),
            keys: keys.to_vec(),
            values: values.to_vec(),
            d,
            bias,
            kind,
            top_r: None,
            sigma_k: 1.0,
            threads: 0,
            stats: QueryStats::default(),
            scratch: Scratch::new(),
            pool: Vec::new(),
        }
    }

    /// INIT with the paper's Lemma 6.1 threshold for Gaussian K/Q.
    pub fn init_gaussian(
        keys: &[f32],
        values: &[f32],
        d: usize,
        m: usize,
        kind: AttentionKind,
        backend: HsrBackend,
    ) -> GenerationDecoding {
        let n = keys.len() / d;
        let params = ThresholdParams::standard(d, m);
        let bias = params.practical_bias(n.max(2)) as f32;
        GenerationDecoding::init(keys, values, d, bias, kind, backend)
    }

    /// Number of cached (key, value) rows.
    pub fn len(&self) -> usize {
        self.keys.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Append a generated token's (k, v) — Theorem D.2's auto-regressive
    /// cache growth, amortized-logarithmic via the dynamic HSR.
    pub fn append(&mut self, key: &[f32], value: &[f32]) {
        assert_eq!(key.len(), self.d);
        assert_eq!(value.len(), self.d);
        self.hsr.insert(key);
        self.keys.extend_from_slice(key);
        self.values.extend_from_slice(value);
    }

    fn row_cfg(&self) -> RowCfg {
        RowCfg {
            d: self.d,
            n: self.len(),
            bias: self.bias,
            kind: self.kind,
            top_r: self.top_r,
            sigma_k: self.sigma_k,
        }
    }

    /// INFERENCE for a single query row; writes the attention output into
    /// `out` (length d) and returns the activated-set size k̃. This is
    /// exactly the B = 1 case of [`GenerationDecoding::inference_batch`],
    /// so serial and batched decode agree bit-for-bit.
    pub fn inference_row(&mut self, q: &[f32], out: &mut [f32]) -> usize {
        assert_eq!(q.len(), self.d);
        assert_eq!(out.len(), self.d);
        let cfg = self.row_cfg();
        let mut fired = [0usize; 1];
        run_shard(
            &self.hsr,
            &self.values,
            cfg,
            q,
            out,
            &mut fired,
            &mut self.scratch,
            &mut self.stats,
        );
        fired[0]
    }

    /// INFERENCE over B query rows at once (the batched decode engine).
    /// Per row the adaptive-threshold + top-r fallback semantics match
    /// [`GenerationDecoding::inference_row`] exactly; the value gathers
    /// are fused — each worker unions its rows' fired indices and streams
    /// the value matrix once per bucket instead of once per row — and the
    /// rows are sharded across scoped worker threads (`threads` knob,
    /// 0 = auto). Output is bit-identical to the serial row loop.
    /// Writes the [B, d] attention output into `out` and the per-row
    /// activated-set sizes k̃_i into `fired`.
    pub fn inference_batch_into(&mut self, q: &[f32], out: &mut [f32], fired: &mut [usize]) {
        assert_eq!(q.len() % self.d, 0);
        let b = q.len() / self.d;
        assert_eq!(out.len(), b * self.d);
        assert_eq!(fired.len(), b);
        if b == 0 {
            return;
        }
        let cfg = self.row_cfg();
        let workers = crate::kernel::effective_threads(self.threads, b);
        if workers <= 1 {
            run_shard(
                &self.hsr,
                &self.values,
                cfg,
                q,
                out,
                fired,
                &mut self.scratch,
                &mut self.stats,
            );
            return;
        }
        // Shard rows contiguously; each worker owns disjoint chunks of
        // `out`/`fired` and a private Scratch arena from the pool.
        let rows_per = (b + workers - 1) / workers;
        let shards = (b + rows_per - 1) / rows_per;
        while self.pool.len() < shards {
            self.pool.push(Scratch::new());
        }
        let hsr = &self.hsr;
        let values = &self.values[..];
        let d = self.d;
        let pool = &mut self.pool[..shards];
        let stats = &mut self.stats;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards);
            for (((q_c, out_c), fired_c), scratch) in q
                .chunks(rows_per * d)
                .zip(out.chunks_mut(rows_per * d))
                .zip(fired.chunks_mut(rows_per))
                .zip(pool.iter_mut())
            {
                handles.push(scope.spawn(move || {
                    let mut local = QueryStats::default();
                    run_shard(hsr, values, cfg, q_c, out_c, fired_c, scratch, &mut local);
                    local
                }));
            }
            // Merge in shard order so the aggregate is deterministic.
            for h in handles {
                stats.add(&h.join().expect("decode worker panicked"));
            }
        });
    }

    /// INFERENCE over B query rows, allocating the [B, d] output.
    pub fn inference_batch(&mut self, q: &[f32]) -> Vec<f32> {
        let b = q.len() / self.d;
        let mut out = vec![0f32; b * self.d];
        let mut fired = vec![0usize; b];
        self.inference_batch_into(q, &mut out, &mut fired);
        out
    }

    /// INFERENCE over a full Q (m × d): returns the m × d output.
    /// Delegates to [`GenerationDecoding::inference_batch`] — the serial
    /// path is just the B = 1 case of the batched one.
    pub fn inference(&mut self, q: &[f32]) -> Vec<f32> {
        self.inference_batch(q)
    }
}

/// Phase A of one row: score-carrying HSR query with the per-kind
/// threshold, the softmax top-r under-report fallback, canonical
/// ascending-index ordering, and the in-place weight transform. Leaves
/// the row's (index, weight) lists in `scratch.selected`/`scratch.exps`
/// and returns (k̃, 1/normalizer) — 0.0 marking a degenerate zero row.
fn row_phase_a(
    hsr: &DynamicHsr,
    cfg: RowCfg,
    qi: &[f32],
    scratch: &mut Scratch,
    stats: &mut QueryStats,
) -> (usize, f32) {
    let inv_sqrt_d = 1.0 / (cfg.d as f32).sqrt();
    // HSR threshold is on the raw inner product: ⟨q,k⟩ ≥ b·√d.
    // Softmax top-r uses a *per-query adaptive* threshold instead:
    // <q,k> | q ~ N(0, ‖q‖²σ_k²), so aiming the expected report at 2r
    // needs b_raw = ‖q‖σ_k√(2 ln(n/2r)) — a fixed b under-reports for
    // small-norm queries (and triggers costly full-scan fallbacks).
    let b_raw = match (cfg.kind, cfg.top_r) {
        (AttentionKind::Softmax, Some(r)) => {
            let n = cfg.n.max(2) as f64;
            let target = (2 * r).max(1) as f64;
            let t = (2.0 * (n / target).ln()).max(0.0).sqrt();
            (crate::hsr::norm(qi) as f64 * cfg.sigma_k * t) as f32
        }
        _ => cfg.bias * (cfg.d as f32).sqrt(),
    };
    // Score-carrying HSR query: the report arrives with the raw inner
    // products, so nothing below re-dots a key the traversal already
    // evaluated. All row buffers come from the reusable scratch.
    scratch.fire.clear();
    scratch.scores.clear();
    hsr.query_scored_into(qi, b_raw, &mut scratch.fire, &mut scratch.scores, stats);
    if let (AttentionKind::Softmax, Some(r)) = (cfg.kind, cfg.top_r) {
        // Theorem 4.2 needs R = NN(r, q, K): if the threshold
        // under-reported (|fire| < r), fall back to the full half-space
        // so the top-r below is exact.
        if scratch.fire.len() < r.min(cfg.n) {
            scratch.fire.clear();
            scratch.scores.clear();
            hsr.query_scored_into(
                qi,
                f32::NEG_INFINITY,
                &mut scratch.fire,
                &mut scratch.scores,
                stats,
            );
        }
    }
    // Canonicalize the report to ascending key order (selected/exps).
    // Evaluation order is then independent of the backend's traversal
    // order AND of how rows are grouped into batches — the property the
    // batched-vs-serial bit-identity rests on.
    match (cfg.kind, cfg.top_r) {
        (AttentionKind::Softmax, Some(r)) if r < scratch.fire.len() => {
            top_r_select_into(
                &scratch.fire,
                &scratch.scores,
                r,
                &mut scratch.selected,
                &mut scratch.exps,
            );
        }
        _ => {
            let Scratch { fire, scores, perm, selected, exps, .. } = scratch;
            perm.clear();
            perm.extend(0..fire.len() as u32);
            perm.sort_unstable_by_key(|&p| fire[p as usize]);
            selected.clear();
            exps.clear();
            for &p in perm.iter() {
                selected.push(fire[p as usize]);
                exps.push(scores[p as usize]);
            }
        }
    }
    for s in scratch.exps.iter_mut() {
        *s *= inv_sqrt_d;
    }
    let denom = match cfg.kind {
        AttentionKind::Relu { alpha, bias } => {
            debug_assert!(
                (bias - cfg.bias).abs() < 1e-6,
                "ReLU bias must equal the HSR threshold for exactness"
            );
            relu_weights_in_place(&mut scratch.exps, alpha, cfg.bias)
        }
        AttentionKind::Softmax => simd::softmax_exp_in_place(&mut scratch.exps),
    };
    let inv = if denom > 0.0 && denom.is_finite() { 1.0 / denom } else { 0.0 };
    (scratch.selected.len(), inv)
}

/// One worker's shard: phase A per row into a CSR (indices ascending per
/// row), then phase B — union the shard's fired indices and stream the
/// value matrix once per [`BUCKET_ROWS`]-row bucket, accumulating every
/// batch row's weighted sum out of the packed (cache-hot) bucket instead
/// of issuing B independent scattered passes over V.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    hsr: &DynamicHsr,
    values: &[f32],
    cfg: RowCfg,
    q_shard: &[f32],
    out_shard: &mut [f32],
    fired_shard: &mut [usize],
    scratch: &mut Scratch,
    stats: &mut QueryStats,
) {
    let d = cfg.d;
    let rows = fired_shard.len();
    debug_assert_eq!(q_shard.len(), rows * d);
    debug_assert_eq!(out_shard.len(), rows * d);
    out_shard.fill(0.0);
    scratch.idx.clear();
    scratch.w.clear();
    scratch.row_ptr.clear();
    scratch.row_ptr.push(0);
    scratch.inv.clear();
    for rw in 0..rows {
        let qi = &q_shard[rw * d..(rw + 1) * d];
        let (k, rinv) = row_phase_a(hsr, cfg, qi, scratch, stats);
        fired_shard[rw] = k;
        let Scratch { idx, w, row_ptr, inv, selected, exps, .. } = &mut *scratch;
        idx.extend_from_slice(selected);
        w.extend_from_slice(exps);
        row_ptr.push(idx.len());
        inv.push(rinv);
    }
    // Phase B: bucketed union gather + per-row accumulation. Each row's
    // contributions are applied in ascending key order regardless of how
    // the union is bucketed, so the result is independent of batching.
    let Scratch { idx, w, row_ptr, inv, union_idx, packed, cursor, .. } = &mut *scratch;
    union_idx.clear();
    union_idx.extend_from_slice(idx);
    union_idx.sort_unstable();
    union_idx.dedup();
    cursor.clear();
    cursor.extend_from_slice(&row_ptr[..rows]);
    for bucket in union_idx.chunks(BUCKET_ROWS) {
        // One gather pass per bucket: pack the bucket's value rows.
        packed.clear();
        for &j in bucket.iter() {
            let j = j as usize;
            packed.extend_from_slice(&values[j * d..(j + 1) * d]);
        }
        let hi = *bucket.last().expect("chunks are non-empty");
        for rw in 0..rows {
            let end = row_ptr[rw + 1];
            let mut c = cursor[rw];
            if inv[rw] == 0.0 {
                // Degenerate normalizer: leave the zero row, but keep
                // the cursor in step with the bucket sweep.
                while c < end && idx[c] <= hi {
                    c += 1;
                }
                cursor[rw] = c;
                continue;
            }
            let orow = &mut out_shard[rw * d..(rw + 1) * d];
            let scale = inv[rw];
            // Both the row's indices and the bucket are ascending, so the
            // bucket position advances monotonically: search only the
            // remaining suffix (O(1) amortized for dense rows, log for
            // sparse ones) instead of bisecting the whole bucket per hit.
            let mut bp = 0usize;
            while c < end && idx[c] <= hi {
                let a = w[c];
                if a != 0.0 {
                    let pos = bp
                        + bucket[bp..]
                            .binary_search(&idx[c])
                            .expect("every fired index is in the union");
                    simd::axpy(orow, &packed[pos * d..(pos + 1) * d], a * scale);
                    bp = pos + 1;
                }
                c += 1;
            }
            cursor[rw] = c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::softmax::softmax_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    /// Algorithm 1 with ReLU attention is *exact* vs the naive dense
    /// computation (the paper's "no error for ReLU" claim).
    #[test]
    fn relu_matches_dense_exactly() {
        let mut rng = Rng::new(101);
        for backend in [HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected] {
            let inst = AttentionInstance::gaussian(&mut rng, 4, 600, 8);
            let bias = inst.params.practical_bias(inst.n) as f32;
            for alpha in [1u32, 2] {
                let mut gd = GenerationDecoding::init(
                    &inst.k,
                    &inst.v,
                    inst.d,
                    bias,
                    AttentionKind::Relu { alpha, bias },
                    backend,
                );
                let got = gd.inference(&inst.q);
                let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, alpha, bias);
                assert!(
                    linf(&got, &want) < 1e-4,
                    "backend={backend:?} alpha={alpha}: {}",
                    linf(&got, &want)
                );
            }
        }
    }

    /// Softmax with top-r over the report is close to dense and the error
    /// shrinks as r grows (Theorem 4.3's shape).
    #[test]
    fn softmax_topr_error_shrinks() {
        let mut rng = Rng::new(102);
        let inst = AttentionInstance::gaussian(&mut rng, 2, 800, 8);
        let dense = softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        let mut last_err = f32::INFINITY;
        for r in [8usize, 64, 512, 800] {
            let mut gd = GenerationDecoding::init(
                &inst.k,
                &inst.v,
                inst.d,
                f32::NEG_INFINITY, // report everything; top-r selects
                AttentionKind::Softmax,
                HsrBackend::BallTree,
            );
            gd.top_r = Some(r);
            let got = gd.inference(&inst.q);
            let err = linf(&got, &dense);
            assert!(err <= last_err * 1.25 + 1e-6, "r={r} err={err} last={last_err}");
            last_err = last_err.min(err);
        }
        assert!(last_err < 1e-5, "full r must be exact: {last_err}");
    }

    /// Appending keys (auto-regressive growth) stays consistent with a
    /// from-scratch build.
    #[test]
    fn append_matches_rebuild() {
        let mut rng = Rng::new(103);
        let d = 6;
        let inst = AttentionInstance::gaussian(&mut rng, 1, 200, d);
        let bias = 0.2f32;
        let kind = AttentionKind::Relu { alpha: 1, bias };
        let mut grown = GenerationDecoding::init(
            &inst.k[..100 * d],
            &inst.v[..100 * d],
            d,
            bias,
            kind,
            HsrBackend::BallTree,
        );
        for j in 100..200 {
            grown.append(&inst.k[j * d..(j + 1) * d], &inst.v[j * d..(j + 1) * d]);
        }
        let mut fresh =
            GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
        let mut out_a = vec![0f32; d];
        let mut out_b = vec![0f32; d];
        let q: Vec<f32> = inst.q[..d].to_vec();
        grown.inference_row(&q, &mut out_a);
        fresh.inference_row(&q, &mut out_b);
        assert!(linf(&out_a, &out_b) < 1e-5);
    }

    /// Batched decode must be **bit-identical** to the serial row loop:
    /// same output floats, same fired counts, same merged work counters —
    /// across every HSR backend, both attention kinds, with and without
    /// top-r, and for every thread count. The serial reference is
    /// `inference_row` (the B = 1 case of the same canonical evaluation).
    #[test]
    fn batched_matches_serial_bitwise() {
        let mut rng = Rng::new(105);
        let cases: Vec<(HsrBackend, usize)> = vec![
            (HsrBackend::Brute, 8),
            (HsrBackend::BallTree, 8),
            (HsrBackend::Projected, 8),
            (HsrBackend::Layers2d, 2),
        ];
        for (backend, d) in cases {
            let inst = AttentionInstance::gaussian(&mut rng, 13, 400, d);
            let bias = inst.params.practical_bias(inst.n) as f32;
            type Setup = (&'static str, AttentionKind, Option<usize>, f32, f64);
            let setups: Vec<Setup> = vec![
                ("relu", AttentionKind::Relu { alpha: 2, bias }, None, bias, 1.0),
                ("softmax", AttentionKind::Softmax, None, bias, 1.0),
                ("softmax-topr", AttentionKind::Softmax, Some(24), 0.0, 1.0),
                // σ_k ≫ 1 inflates the adaptive threshold so the report
                // under-fills and every row takes the full-scan fallback.
                ("softmax-topr-fallback", AttentionKind::Softmax, Some(24), 0.0, 50.0),
            ];
            for (name, kind, top_r, b, sigma_k) in setups {
                let build = || {
                    let mut gd = GenerationDecoding::init(
                        &inst.k, &inst.v, inst.d, b, kind, backend,
                    );
                    gd.top_r = top_r;
                    gd.sigma_k = sigma_k;
                    gd
                };
                // Serial reference: one row at a time.
                let mut serial = build();
                let mut want = vec![0f32; inst.m * inst.d];
                let mut want_fired = vec![0usize; inst.m];
                for i in 0..inst.m {
                    let (s, e) = (i * inst.d, (i + 1) * inst.d);
                    want_fired[i] = serial.inference_row(&inst.q[s..e], &mut want[s..e]);
                }
                for threads in [1usize, 2, 3] {
                    let mut batched = build();
                    batched.threads = threads;
                    let mut got = vec![0f32; inst.m * inst.d];
                    let mut fired = vec![0usize; inst.m];
                    batched.inference_batch_into(&inst.q, &mut got, &mut fired);
                    assert_eq!(
                        want, got,
                        "{name} backend={backend:?} threads={threads}"
                    );
                    assert_eq!(want_fired, fired, "{name} backend={backend:?}");
                    assert_eq!(
                        serial.stats, batched.stats,
                        "{name} backend={backend:?} threads={threads}"
                    );
                }
            }
        }
    }

    /// `inference` is the batched path; it must agree with the serial row
    /// loop bit-for-bit (delegation sanity).
    #[test]
    fn inference_delegates_to_batch() {
        let mut rng = Rng::new(106);
        let inst = AttentionInstance::gaussian(&mut rng, 6, 300, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let kind = AttentionKind::Relu { alpha: 1, bias };
        let mut a = GenerationDecoding::init(&inst.k, &inst.v, inst.d, bias, kind, HsrBackend::BallTree);
        let mut b = GenerationDecoding::init(&inst.k, &inst.v, inst.d, bias, kind, HsrBackend::BallTree);
        let batched = a.inference(&inst.q);
        let mut serial = vec![0f32; inst.m * inst.d];
        for i in 0..inst.m {
            let (s, e) = (i * inst.d, (i + 1) * inst.d);
            b.inference_row(&inst.q[s..e], &mut serial[s..e]);
        }
        assert_eq!(batched, serial);
    }

    /// The activated-set size tracks Lemma 6.1: k̃ ≤ 2 n^{4/5}.
    #[test]
    fn activated_count_respects_lemma() {
        let mut rng = Rng::new(104);
        let inst = AttentionInstance::gaussian(&mut rng, 8, 4096, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let mut gd = GenerationDecoding::init(
            &inst.k,
            &inst.v,
            inst.d,
            bias,
            AttentionKind::Relu { alpha: 1, bias },
            HsrBackend::BallTree,
        );
        let bound = inst.params.row_bound(inst.n) as usize;
        let mut out = vec![0f32; inst.d];
        let mut any = 0usize;
        for i in 0..inst.m {
            let q: Vec<f32> = inst.query_row(i).to_vec();
            let fired = gd.inference_row(&q, &mut out);
            assert!(fired <= bound, "row {i}: fired {fired} > bound {bound}");
            any += fired;
        }
        assert!(any > 0, "nothing fired at the practical threshold");
    }
}
