//! Bench/reproduction: **headline claims** — end-to-end serving
//! throughput/latency with HSR-sparse attention vs the dense baseline,
//! plus the shared-prefix KV store on a common-prompt workload
//! (BENCH_serving.json: prefix-hit rate, prefill tokens skipped, and
//! steady-state tok/s shared vs unshared).
//!
//! The sparse-vs-dense section needs the trained artifacts (`make
//! artifacts`) and skips without them; the shared-prefix section falls
//! back to a deterministic synthetic model so the prefix-cache numbers
//! are always reproducible.
//!
//! Flags: --shared-only (skip the artifact section), --overload-only
//! (run just the admission-control section), --model NAME,
//! --shared-requests N, --shared-prompt N, --shared-gen N,
//! --overload-requests N, --overload-prompt N, --overload-gen N.

use hsr_attn::bench::banner;
use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{GenerationParams, Router, RouterConfig, SchedulerConfig};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::kvstore::PrefixCacheMode;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::util::cli::Args;
use hsr_attn::util::json::Json;
use hsr_attn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct RunResult {
    wall_s: f64,
    gen_tokens: u64,
    /// Decode throughput measured only over steps that started in steady
    /// state (all admitted prompts prefilled, nothing waiting) — the
    /// batching win, undiluted by prefill.
    steady_tok_per_s: f64,
    /// Time to first token, p50 across requests.
    ttft_p50_ns: u64,
    attended_frac: f64,
    p50_step_ns: u64,
    /// Shared-prefix counters (zero with the cache off).
    prefill_tokens_skipped: u64,
    prefill_tokens_demanded: u64,
    prefix_hit_rate: f64,
    grouped_decode_rows: u64,
    segments_evicted: u64,
}

/// Drive `prompts` to completion, timing steady-state decode separately.
fn drive(mut eng: Engine, prompts: Vec<Vec<u32>>, gen: usize) -> RunResult {
    for p in prompts {
        eng.submit(
            p,
            GenerationParams { max_new_tokens: gen, temperature: 0.0, stop_token: None, deadline: None },
        );
    }
    let requests = eng.metrics.requests_submitted;
    let t0 = Instant::now();
    let mut steady_ns: u128 = 0;
    let mut steady_tok: u64 = 0;
    while eng.has_work() {
        let was_steady = eng.steady_state();
        let g0 = eng.metrics.generated_tokens;
        let ts = Instant::now();
        let processed = eng.step();
        if was_steady {
            steady_ns += ts.elapsed().as_nanos();
            steady_tok += eng.metrics.generated_tokens - g0;
        }
        if processed == 0 {
            eng.run_to_completion(); // stuck-work fallback (aborts)
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    RunResult {
        wall_s,
        gen_tokens: eng.metrics.generated_tokens + requests, // + seeded
        steady_tok_per_s: if steady_ns > 0 {
            steady_tok as f64 / (steady_ns as f64 * 1e-9)
        } else {
            0.0
        },
        ttft_p50_ns: eng.metrics.ttft.percentile_ns(50.0),
        attended_frac: eng.metrics.attended_fraction(),
        p50_step_ns: eng.metrics.step_latency.percentile_ns(50.0),
        prefill_tokens_skipped: eng.metrics.prefill_tokens_skipped,
        prefill_tokens_demanded: eng.metrics.prefill_tokens_demanded,
        prefix_hit_rate: eng.metrics.prefix_hit_rate(),
        grouped_decode_rows: eng.metrics.grouped_decode_rows,
        segments_evicted: eng.metrics.prefix_segments_evicted,
    }
}

fn corpus() -> Vec<u32> {
    "the merchant carries copper coins by the river. \
     remember: alder keeps the amber token. the alder token is amber. "
        .bytes()
        .cycle()
        .take(8192)
        .map(|b| b as u32)
        .collect()
}

fn run(
    model: Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    requests: usize,
    prompt_len: usize,
    gen: usize,
    max_batch: usize,
) -> RunResult {
    let mut rng = Rng::new(11);
    let eng = Engine::new(
        model,
        EngineConfig {
            policy,
            hsr_backend: backend,
            // The sparse-vs-dense table is the PR 0-3 baseline: keep the
            // prefix cache out of it so the numbers stay comparable
            // (the shared_prefix_section measures the cache explicitly).
            prefix_cache: PrefixCacheMode::Off,
            scheduler: SchedulerConfig { max_batch, ..Default::default() },
            ..Default::default()
        },
    );
    let corpus = corpus();
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| {
            let s = rng.below(corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    drive(eng, prompts, gen)
}

/// The shared-prompt workload: every request carries the SAME prompt
/// (the multi-turn / common-system-prompt serving setting), run once
/// with the prefix cache off and once on.
fn shared_prefix_section(args: &Args) {
    let requests = args.usize_or("shared-requests", 32);
    let prompt_len = args.usize_or("shared-prompt", 256);
    let gen = args.usize_or("shared-gen", 32);
    let model_name = args.str_or("model", "small");
    let (model, model_desc) = if artifacts_dir().join("manifest.json").exists() {
        (
            Arc::new(Model::load_named(&artifacts_dir(), model_name).unwrap()),
            model_name.to_string(),
        )
    } else {
        // Deterministic fallback so this section always runs.
        (Arc::new(Model::synthetic(90, 2, 4, 8)), "synthetic-90".to_string())
    };
    println!(
        "\n== shared-prefix serving: {requests} requests x (identical prompt {prompt_len} + gen {gen}), model '{model_desc}' =="
    );
    let corpus = corpus();
    let prompt = corpus[..prompt_len].to_vec();
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let backend = Some(HsrBackend::BallTree);
    let mut results: Vec<(&str, PrefixCacheMode, RunResult)> = Vec::new();
    for (name, mode) in [
        ("prefix-cache off (unshared baseline)", PrefixCacheMode::Off),
        ("prefix-cache on (radix + grouped decode)", PrefixCacheMode::default()),
    ] {
        let eng = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                policy,
                hsr_backend: backend,
                prefix_cache: mode,
                scheduler: SchedulerConfig { max_batch: requests, ..Default::default() },
                ..Default::default()
            },
        );
        let prompts = vec![prompt.clone(); requests];
        let r = drive(eng, prompts, gen);
        results.push((name, mode, r));
    }
    println!(
        "{:<42} {:>8} {:>13} {:>10} {:>14} {:>12}",
        "configuration", "wall s", "steady tok/s", "ttft p50", "prefill skip", "grouped rows"
    );
    for (name, _, r) in &results {
        println!(
            "{:<42} {:>8.2} {:>13.1} {:>10} {:>13.1}% {:>12}",
            name,
            r.wall_s,
            r.steady_tok_per_s,
            hsr_attn::util::stats::fmt_ns(r.ttft_p50_ns as f64),
            100.0 * r.prefill_tokens_skipped as f64 / r.prefill_tokens_demanded.max(1) as f64,
            r.grouped_decode_rows,
        );
    }
    let off = &results[0].2;
    let on = &results[1].2;
    let skip_pct =
        100.0 * on.prefill_tokens_skipped as f64 / on.prefill_tokens_demanded.max(1) as f64;
    let steady_speedup = if off.steady_tok_per_s > 0.0 {
        on.steady_tok_per_s / off.steady_tok_per_s
    } else {
        0.0
    };
    println!(
        "\nprefill tokens skipped: {:.1}%  |  steady-state speedup: {:.2}x  |  hit rate {:.0}%",
        skip_pct,
        steady_speedup,
        100.0 * on.prefix_hit_rate
    );

    // Machine-readable report at the repo root.
    let mut root = Json::obj();
    root.set("model", model_desc.as_str().into())
        .set("requests", requests.into())
        .set("prompt_len", prompt_len.into())
        .set("gen", gen.into())
        .set("backend", "balltree".into())
        .set("prefill_tokens_skipped_pct", skip_pct.into())
        .set("prefix_hit_rate", on.prefix_hit_rate.into())
        .set("steady_speedup", steady_speedup.into());
    for (key, r) in [("unshared", off), ("shared", on)] {
        let mut o = Json::obj();
        o.set("wall_s", r.wall_s.into())
            .set("gen_tokens", r.gen_tokens.into())
            .set("steady_tok_per_s", r.steady_tok_per_s.into())
            .set("ttft_p50_ns", r.ttft_p50_ns.into())
            .set("p50_step_ns", r.p50_step_ns.into())
            .set("prefill_tokens_skipped", r.prefill_tokens_skipped.into())
            .set("prefill_tokens_demanded", r.prefill_tokens_demanded.into())
            .set("grouped_decode_rows", r.grouped_decode_rows.into())
            .set("segments_evicted", r.segments_evicted.into());
        root.set(key, o);
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// Overload section: calibrate the pool's sustainable completion rate
/// closed-loop, then offer 4x that rate through a tightly-capped router
/// and measure the shed rate plus the latency of the accepted requests
/// (BENCH_robustness.json). Always runs on the synthetic model, so the
/// admission-control numbers need no artifacts.
fn overload_section(args: &Args) {
    let requests = args.usize_or("overload-requests", 48);
    let gen = args.usize_or("overload-gen", 16);
    let prompt_len = args.usize_or("overload-prompt", 64);
    let model = Arc::new(Model::synthetic(90, 2, 4, 8));
    let corpus = corpus();
    let mut rng = Rng::new(23);
    let prompts: Vec<Vec<u32>> = (0..requests)
        .map(|_| {
            let s = rng.below(corpus.len() - prompt_len);
            corpus[s..s + prompt_len].to_vec()
        })
        .collect();
    let params = GenerationParams {
        max_new_tokens: gen,
        temperature: 0.0,
        stop_token: None,
        deadline: None,
    };
    println!("\n== overload: admission control at 4x the sustainable rate (2 workers) ==");

    // Calibrate closed-loop with the default (generous) caps.
    let cal_n = requests.min(24);
    let cal = Router::new(Arc::clone(&model), EngineConfig::default(), 2);
    let t0 = Instant::now();
    for p in prompts.iter().take(cal_n) {
        cal.submit(p.clone(), params).expect("calibration submit under default caps");
    }
    cal.wait_idle();
    let sustainable = cal_n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    cal.shutdown();

    // Offer 4x through tight queues; count sheds, time the accepted.
    let rcfg = RouterConfig {
        max_queue_per_worker: 6,
        max_in_flight: 16,
        ..Default::default()
    };
    let router = Router::with_config(Arc::clone(&model), EngineConfig::default(), 2, rcfg);
    let offered = sustainable * 4.0;
    let gap = std::time::Duration::from_secs_f64(1.0 / offered.max(1.0));
    let (mut accepted, mut shed) = (0usize, 0usize);
    for p in &prompts {
        match router.submit(p.clone(), params) {
            Ok(_) => accepted += 1,
            Err(_) => shed += 1,
        }
        std::thread::sleep(gap);
    }
    router.wait_idle();
    let responses = router.take_responses();
    let metrics = router.shutdown();
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let (p50, p99) = if latencies.is_empty() {
        (0.0, 0.0)
    } else {
        (
            hsr_attn::util::stats::percentile(&latencies, 50.0),
            hsr_attn::util::stats::percentile(&latencies, 99.0),
        )
    };
    let shed_rate = shed as f64 / requests.max(1) as f64;
    println!(
        "sustainable {sustainable:.1} req/s -> offered {offered:.1} req/s: \
         accepted {accepted} / shed {shed} ({:.0}% shed)",
        100.0 * shed_rate
    );
    println!("accepted-request latency: p50 {p50:.1} ms, p99 {p99:.1} ms");

    let mut root = Json::obj();
    root.set("requests_offered", requests.into())
        .set("sustainable_req_per_s", sustainable.into())
        .set("offered_req_per_s", offered.into())
        .set("accepted", accepted.into())
        .set("shed", shed.into())
        .set("shed_rate", shed_rate.into())
        .set("accepted_latency_p50_ms", p50.into())
        .set("accepted_latency_p99_ms", p99.into())
        .set("requests_rejected_metric", metrics.requests_rejected.into());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_robustness.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    banner("e2e_serving", "headline: sparse vs dense serving + shared-prefix KV store");
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));

    if args.flag("overload-only") {
        overload_section(&args);
        return;
    }
    shared_prefix_section(&args);
    if args.flag("shared-only") {
        return;
    }
    overload_section(&args);

    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("\nartifacts missing — run `make artifacts`; skipping sparse-vs-dense section");
        return;
    }
    let model_name = args.str_or("model", "small");
    let requests = args.usize_or("requests", 12);
    let prompt_len = args.usize_or("prompt", 384);
    let gen = args.usize_or("gen", 96);
    let model = Arc::new(Model::load_named(&artifacts_dir(), model_name).unwrap());
    println!(
        "\nmodel '{}', {} requests x (prompt {} + gen {})\n",
        model_name, requests, prompt_len, gen
    );

    println!(
        "{:<44} {:>9} {:>12} {:>13} {:>10} {:>11} {:>10}",
        "configuration", "wall s", "gen tok/s", "steady tok/s", "ttft p50", "p50 step", "attended"
    );
    let cases: Vec<(String, AttentionPolicy, Option<HsrBackend>, usize)> = vec![
        ("dense baseline (batch 8)".into(), AttentionPolicy::Dense, None, 8),
        (
            "sparse top-r=n^0.8, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, brute scan (ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            None,
            8,
        ),
        (
            "sparse top-r=64 fixed, balltree (batch 8)".into(),
            AttentionPolicy::TopR(RSpec::Fixed(64)),
            Some(HsrBackend::BallTree),
            8,
        ),
        (
            "sparse top-r=n^0.8, balltree (batch 1 ablation)".into(),
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            1,
        ),
    ];
    for (name, policy, backend, batch) in cases {
        let r = run(model.clone(), policy, backend, requests, prompt_len, gen, batch);
        println!(
            "{:<44} {:>9.2} {:>12.1} {:>13.1} {:>10} {:>11} {:>9.1}%",
            name,
            r.wall_s,
            r.gen_tokens as f64 / r.wall_s,
            r.steady_tok_per_s,
            hsr_attn::util::stats::fmt_ns(r.ttft_p50_ns as f64),
            hsr_attn::util::stats::fmt_ns(r.p50_step_ns as f64),
            r.attended_frac * 100.0
        );
    }
    println!("\nexpected: sparse attends a small fraction of entries; steady tok/s");
    println!("isolates the batched decode win from prefill (ttft reported apart);");
    println!("wall-clock gains grow with context (see decode_time for scaling).");
}
