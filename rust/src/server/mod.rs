//! TCP JSON-lines serving front-end.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0,
//!      "deadline_ms": 2000}
//!   ← {"id": 7, "text": "...", "latency_ms": 12.3, "ttft_ms": 4.5,
//!      "finish": "length", "prompt_len": 40}
//!   ← {"error": "server overloaded", "code": "overloaded",
//!      "retry_after_ms": 50}
//!
//! With `"stream": true` the reply is a frame sequence instead
//! (`protocol` module docs give the grammar):
//!   ← {"id": 7, "event": "token", "seq": 0, "token": 104, "text": "h"}
//!   ← {"id": 7, "event": "done", "tokens_streamed": 1, ...}
//! with exactly one terminal frame (`done`/`error`/`cancelled`) per
//! stream, contiguous `seq` numbers, and `keepalive` frames while
//! decode is busy. Grouped requests (`"n"`/`"best_of"`/`"beam_width"`
//! ≥ 2) interleave sibling-tagged token frames on the one connection
//! and end with exactly one terminal frame **per sibling**, each tagged
//! `sibling`/`siblings` (see the protocol module docs).
//!
//! Connections are handled by a thread each; generation runs on the
//! router's supervised engine workers (std::thread — the vendored
//! dependency set has no tokio; see DESIGN.md). The accept loop reaps
//! finished connection threads, caps live connections (shedding the
//! excess with an `overloaded` error line), and on stop drains
//! connections for a bounded window before shutting their sockets.
//! Request waits are Condvar-driven ([`Router::wait_for_outcome`]) with
//! a periodic disconnect probe: a client that goes away mid-generation
//! gets its request cancelled so it stops burning decode steps. Slow
//! stream consumers are bounded twice over: socket writes carry a write
//! timeout, and the engine-side send buffer severs the stream (terminal
//! `slow_consumer` error) if the client falls a full buffer behind —
//! decode never blocks on a reader.

pub mod protocol;

use crate::engine::{
    FinishReason, GenerationParams, Outcome, RequestId, Router, StreamRecv,
    StreamSink, SubmitError,
};
use crate::model::tokenizer::ByteTokenizer;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use protocol::{
    parse_admin, parse_frame, parse_request, parse_stats_response,
    render_cancelled_frame, render_cancelled_frame_sibling,
    render_choice_done_frame, render_done_frame, render_error,
    render_keepalive, render_request, render_response,
    render_stats_request, render_stats_response,
    render_stats_text_response, render_stream_error,
    render_stream_error_sibling, render_token_frame, AdminCmd,
    StatsFormat, StatsReply, StreamFrame, WireRequest,
};

/// Connection-handling knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Live connections beyond this are shed with an `overloaded` line.
    pub max_connections: usize,
    /// Per-connection socket read timeout; idle connections wake at
    /// this cadence to notice a server stop.
    pub read_timeout: Duration,
    /// Request lines longer than this draw a `line_too_long` error and
    /// close the connection (bounds per-connection memory).
    pub max_line_bytes: usize,
    /// Graceful-stop drain: in-flight connections get this long to
    /// finish before their sockets are shut down.
    pub drain: Duration,
    /// Server-side cap on one request's total wait (deadline of last
    /// resort when the client sets none).
    pub request_timeout: Duration,
    /// Idle gap on a live stream before a `keepalive` frame goes out
    /// (lets clients distinguish "decode busy" from "server wedged").
    pub keepalive: Duration,
    /// Socket write timeout: a frame write blocked this long (client
    /// stopped reading, TCP buffers full) counts the consumer as gone
    /// and the stream's request is cancelled — connection threads never
    /// hang on a dead reader.
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 64,
            read_timeout: Duration::from_millis(200),
            max_line_bytes: 64 * 1024,
            drain: Duration::from_secs(5),
            request_timeout: Duration::from_secs(120),
            keepalive: Duration::from_millis(500),
            write_timeout: Duration::from_secs(2),
        }
    }
}

/// Serving front-end over a [`Router`].
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) with
    /// default connection handling.
    pub fn bind(router: Arc<Router>, addr: &str) -> Result<Server> {
        Server::bind_with(router, addr, ServerConfig::default())
    }

    pub fn bind_with(router: Arc<Router>, addr: &str, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { router, listener, stop: Arc::new(AtomicBool::new(false)), cfg })
    }

    /// The bound address (for ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle that makes `serve` return after the current accept.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop; one thread per connection, reaped each iteration.
    /// Blocks until stopped, then drains connections for `cfg.drain`
    /// before forcing their sockets shut.
    pub fn serve(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        let live = Arc::new(AtomicUsize::new(0));
        // Socket registry for the forced phase of shutdown; each
        // connection removes its own entry on exit.
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::default();
        let mut next_token: u64 = 0;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    handles.retain(|h| !h.is_finished());
                    if live.load(Ordering::Relaxed) >= self.cfg.max_connections {
                        shed_connection(stream);
                        continue;
                    }
                    let token = next_token;
                    next_token += 1;
                    if let Ok(clone) = stream.try_clone() {
                        conns.lock().unwrap_or_else(|e| e.into_inner()).insert(token, clone);
                    }
                    let router = self.router.clone();
                    let cfg = self.cfg;
                    let stop = self.stop.clone();
                    let live2 = live.clone();
                    let conns2 = conns.clone();
                    live.fetch_add(1, Ordering::Relaxed);
                    handles.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, router, cfg, stop);
                        conns2.lock().unwrap_or_else(|e| e.into_inner()).remove(&token);
                        live2.fetch_sub(1, Ordering::Relaxed);
                    }));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    handles.retain(|h| !h.is_finished());
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        // Drain-then-abort: give in-flight connections a bounded window,
        // then shut their sockets so blocked reads/writes fail fast.
        let deadline = Instant::now() + self.cfg.drain;
        while live.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        for s in conns.lock().unwrap_or_else(|e| e.into_inner()).values() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Refuse a connection beyond the cap with a structured error line.
fn shed_connection(mut stream: TcpStream) {
    let line = render_error("overloaded", "connection limit reached", Some(100));
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
    let _ = stream.shutdown(Shutdown::Both);
}

enum LineRead {
    Line(String),
    /// Orderly EOF or server stop.
    Closed,
    TooLong,
    Err,
}

/// Read one `\n`-terminated line of at most `cap` bytes. Socket read
/// timeouts are idle polls (checking the stop flag), not errors.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    cap: usize,
    stop: &AtomicBool,
) -> LineRead {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (take, saw_newline, eof) = match reader.fill_buf() {
            Ok(chunk) if chunk.is_empty() => (0, false, true),
            Ok(chunk) => {
                let nl = chunk.iter().position(|&b| b == b'\n');
                let take = nl.map(|p| p + 1).unwrap_or(chunk.len());
                buf.extend_from_slice(&chunk[..take]);
                (take, nl.is_some(), false)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    return LineRead::Closed;
                }
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return LineRead::Err,
        };
        reader.consume(take);
        if eof {
            // A partial unterminated line at EOF is dropped.
            return LineRead::Closed;
        }
        if buf.len() > cap {
            return LineRead::TooLong;
        }
        if saw_newline {
            let mut s = String::from_utf8_lossy(&buf).into_owned();
            if s.ends_with('\n') {
                s.pop();
            }
            if s.ends_with('\r') {
                s.pop();
            }
            return LineRead::Line(s);
        }
    }
}

/// Nonblocking probe for a vanished client: orderly EOF or a socket
/// error while a request is in flight means nobody is listening.
fn client_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

enum Wait {
    Outcome(Outcome),
    ClientGone,
    TimedOut,
}

/// Condvar-signaled wait on the completion table, waking periodically
/// only to probe for a disconnected client.
fn await_outcome(router: &Router, stream: &TcpStream, id: RequestId, cap: Duration) -> Wait {
    let deadline = Instant::now() + cap;
    loop {
        if let Some(o) = router.wait_for_outcome(id, Duration::from_millis(50)) {
            return Wait::Outcome(o);
        }
        if client_gone(stream) {
            return Wait::ClientGone;
        }
        if Instant::now() >= deadline {
            return Wait::TimedOut;
        }
    }
}

/// Structured error line for a refused submission (shared by the
/// buffered and streaming paths — a stream that never started is
/// answered with a plain error line, not frames).
fn submit_error_line(e: SubmitError) -> String {
    match e {
        SubmitError::Overloaded { retry_after_ms } => {
            render_error("overloaded", "server overloaded", Some(retry_after_ms))
        }
        SubmitError::ShuttingDown => {
            render_error("shutting_down", "server is shutting down", None)
        }
        SubmitError::NoWorkers => render_error("unavailable", "no live workers", None),
    }
}

/// Wire reason closing a sibling whose choice carries `finish`.
fn cancel_reason(finish: FinishReason) -> &'static str {
    match finish {
        FinishReason::DeadlineExceeded => "deadline",
        FinishReason::Cancelled => "cancelled",
        _ => "aborted",
    }
}

/// Map a terminal [`Outcome`] to the stream's terminal frames — one per
/// sibling the client observed (streamed tokens or a surviving choice;
/// sibling 0 always counts). Plain single-sequence streams get the one
/// untagged frame of the pre-fork wire format. A severed sink takes
/// precedence: the engine sheds a slow consumer with `Cancelled`, but
/// on the wire that is a `slow_consumer` error (per sibling, so grouped
/// clients still see every stream closed).
fn terminal_frames_for(
    outcome: &Outcome,
    streamed_by: &HashMap<u32, u64>,
    severed: bool,
    tokenizer: &ByteTokenizer,
) -> Vec<String> {
    let mut observed: Vec<u32> = streamed_by.keys().copied().collect();
    if let Outcome::Done(resp) = outcome {
        observed.extend(resp.choices.iter().map(|c| c.index));
    }
    observed.push(0);
    observed.sort_unstable();
    observed.dedup();
    let streamed = |s: u32| streamed_by.get(&s).copied().unwrap_or(0);
    let grouped = observed.len() > 1
        || matches!(outcome, Outcome::Done(resp) if resp.choices.len() > 1);
    if !grouped {
        let frame = if severed {
            render_stream_error(
                outcome.id(),
                "slow_consumer",
                "client fell a full send-buffer behind; stream shed",
                streamed(0),
                None,
            )
        } else {
            match outcome {
                Outcome::Done(resp) => match resp.finish {
                    FinishReason::Length | FinishReason::StopToken => {
                        render_done_frame(resp, streamed(0), tokenizer)
                    }
                    finish => render_cancelled_frame(resp.id, cancel_reason(finish), streamed(0)),
                },
                Outcome::Failed(err) => render_stream_error(
                    err.id,
                    err.code,
                    &err.message,
                    streamed(0),
                    err.retry_after_ms,
                ),
            }
        };
        return vec![frame];
    }
    let siblings = observed.len() as u32;
    observed
        .iter()
        .map(|&s| {
            if severed {
                return render_stream_error_sibling(
                    outcome.id(),
                    "slow_consumer",
                    "client fell a full send-buffer behind; stream shed",
                    streamed(s),
                    None,
                    s,
                    siblings,
                );
            }
            match outcome {
                Outcome::Failed(err) => render_stream_error_sibling(
                    err.id,
                    err.code,
                    &err.message,
                    streamed(s),
                    err.retry_after_ms,
                    s,
                    siblings,
                ),
                Outcome::Done(resp) => {
                    match resp.choices.iter().find(|c| c.index == s) {
                        Some(choice) => match choice.finish {
                            FinishReason::Length | FinishReason::StopToken => {
                                render_choice_done_frame(
                                    resp,
                                    choice,
                                    siblings,
                                    streamed(s),
                                    tokenizer,
                                )
                            }
                            finish => render_cancelled_frame_sibling(
                                resp.id,
                                cancel_reason(finish),
                                streamed(s),
                                s,
                                siblings,
                            ),
                        },
                        // Streamed but no surviving choice: a pruned
                        // beam loser or a dropped best_of candidate.
                        None => render_cancelled_frame_sibling(
                            resp.id,
                            "pruned",
                            streamed(s),
                            s,
                            siblings,
                        ),
                    }
                }
            }
        })
        .collect()
}

/// Drive one accepted streaming request to its terminal frames. Writes
/// sibling-tagged `token` frames as the engine pushes them, `keepalive`
/// frames across idle gaps, and exactly one terminal frame per
/// observed sibling — unless the client goes away first (write failure
/// / disconnect probe), in which case the request is cancelled and
/// `Err` tells the caller to drop the connection (nobody is listening
/// for terminal frames).
#[allow(clippy::too_many_arguments)]
fn stream_request(
    writer: &mut TcpStream,
    stream: &TcpStream,
    router: &Router,
    cfg: &ServerConfig,
    stop: &AtomicBool,
    id: RequestId,
    sink: &StreamSink,
    tokenizer: &ByteTokenizer,
) -> Result<()> {
    let deadline = Instant::now() + cfg.request_timeout;
    // Per-sibling token counts: each sibling's terminal frame reports
    // its own `tokens_streamed` truncation point.
    let mut streamed_by: HashMap<u32, u64> = HashMap::new();
    let mut last_write = Instant::now();
    loop {
        match sink.recv_timeout(Duration::from_millis(50)) {
            StreamRecv::Event(ev) => {
                let frame = render_token_frame(id, ev.seq, ev.token, ev.sibling, tokenizer);
                if write_line(writer, &frame).is_err() {
                    router.cancel(id);
                    anyhow::bail!("client write failed mid-stream");
                }
                *streamed_by.entry(ev.sibling).or_insert(0) += 1;
                last_write = Instant::now();
            }
            StreamRecv::Closed => {
                // The router inserts the outcome before closing the
                // sink, so it is already present; the timeout is pure
                // defensiveness.
                let frames = match router.wait_for_outcome(id, Duration::from_secs(1)) {
                    Some(outcome) => {
                        terminal_frames_for(&outcome, &streamed_by, sink.is_severed(), tokenizer)
                    }
                    None => vec![render_cancelled_frame(
                        id,
                        "aborted",
                        streamed_by.values().sum(),
                    )],
                };
                for frame in &frames {
                    write_line(writer, frame)?;
                }
                return Ok(());
            }
            StreamRecv::Empty => {
                if client_gone(stream) {
                    router.cancel(id);
                    anyhow::bail!("client disconnected mid-stream");
                }
                let timed_out = Instant::now() >= deadline;
                if timed_out || stop.load(Ordering::Relaxed) {
                    // Server-side cut: cancel and emit the terminal
                    // frame ourselves (the engine's own outcome stays
                    // in the table). One untagged frame closes the
                    // whole stream — clients treat a server cut as
                    // stream-wide.
                    router.cancel(id);
                    let reason = if timed_out { "timeout" } else { "aborted" };
                    let total: u64 = streamed_by.values().sum();
                    write_line(writer, &render_cancelled_frame(id, reason, total))?;
                    return Ok(());
                }
                if last_write.elapsed() >= cfg.keepalive {
                    if write_line(writer, &render_keepalive(id)).is_err() {
                        router.cancel(id);
                        anyhow::bail!("client write failed on keepalive");
                    }
                    last_write = Instant::now();
                }
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    router: Arc<Router>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(cfg.read_timeout)).ok();
    stream.set_write_timeout(Some(cfg.write_timeout)).ok();
    let tokenizer = ByteTokenizer;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream.try_clone()?;
    loop {
        let line = match read_line_bounded(&mut reader, cfg.max_line_bytes, &stop) {
            LineRead::Line(l) => l,
            LineRead::Closed | LineRead::Err => break,
            LineRead::TooLong => {
                let msg = render_error(
                    "line_too_long",
                    &format!("request line exceeds {} bytes", cfg.max_line_bytes),
                    None,
                );
                let _ = write_line(&mut writer, &msg);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        // Admin frames ({"cmd":...}) carry no prompt and are answered
        // inline — dispatched before request parsing so a stats scrape
        // works on any connection, including mid-chaos ones.
        if let Some(admin) = parse_admin(&line) {
            match admin {
                Ok(AdminCmd::Stats { format }) => {
                    let snap =
                        crate::obs::Snapshot::of(&router.stats_snapshot());
                    let reply = match format {
                        StatsFormat::Json => render_stats_response(snap.to_json()),
                        StatsFormat::Prometheus => {
                            render_stats_text_response(&snap.to_prometheus())
                        }
                    };
                    write_line(&mut writer, &reply)?;
                }
                Err(e) => {
                    write_line(
                        &mut writer,
                        &render_error("bad_request", &e.to_string(), None),
                    )?;
                }
            }
            continue;
        }
        let req = match parse_request(&line) {
            Ok(req) => req,
            Err(e) => {
                write_line(&mut writer, &render_error("bad_request", &e.to_string(), None))?;
                continue;
            }
        };
        let prompt = tokenizer.encode(&req.prompt);
        let params = GenerationParams {
            max_new_tokens: req.max_new_tokens,
            temperature: req.temperature,
            stop_token: req.stop_token,
            deadline: req
                .deadline_ms
                .map(|ms| Instant::now() + Duration::from_millis(ms)),
            n: req.n,
            best_of: req.best_of,
            beam_width: req.beam_width,
        };
        if req.stream {
            match router.submit_streaming(prompt, params) {
                Ok((id, sink)) => {
                    if stream_request(
                        &mut writer,
                        &stream,
                        &router,
                        &cfg,
                        &stop,
                        id,
                        &sink,
                        &tokenizer,
                    )
                    .is_err()
                    {
                        break; // client gone mid-stream
                    }
                }
                Err(e) => write_line(&mut writer, &submit_error_line(e))?,
            }
            continue;
        }
        let resp_line = match router.submit(prompt, params) {
            Ok(id) => match await_outcome(&router, &stream, id, cfg.request_timeout) {
                Wait::Outcome(Outcome::Done(resp)) => render_response(&resp, &tokenizer),
                Wait::Outcome(Outcome::Failed(err)) => {
                    render_error(err.code, &err.message, err.retry_after_ms)
                }
                Wait::ClientGone => {
                    // Read EOF / reset with a request in flight:
                    // stop burning decode steps on it.
                    router.cancel(id);
                    break;
                }
                Wait::TimedOut => {
                    router.cancel(id);
                    render_error("timeout", "request timed out server-side", None)
                }
            },
            Err(e) => submit_error_line(e),
        };
        write_line(&mut writer, &resp_line)?;
    }
    Ok(())
}

fn write_line(writer: &mut TcpStream, line: &str) -> Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// Minimal blocking client for tests and examples.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and wait for the reply line.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
    ) -> Result<crate::util::json::Json> {
        self.request(&WireRequest {
            prompt: prompt.to_string(),
            max_new_tokens,
            temperature: 0.0,
            stop_token: None,
            deadline_ms: None,
            stream: false,
            n: 1,
            best_of: 0,
            beam_width: 0,
        })
    }

    /// Send a full request (deadline and all) and wait for the reply
    /// line — which may be a structured error object.
    pub fn request(&mut self, req: &WireRequest) -> Result<crate::util::json::Json> {
        self.send(req)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "connection closed by server");
        crate::util::json::Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Send a request line without waiting for the reply (streaming
    /// callers read frames themselves via [`Client::read_frame`]).
    pub fn send(&mut self, req: &WireRequest) -> Result<()> {
        self.stream.write_all(render_request(req).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        Ok(())
    }

    /// Scrape the server's live metrics snapshot (`{"cmd":"stats"}`).
    pub fn stats(&mut self) -> Result<crate::util::json::Json> {
        self.stream.write_all(render_stats_request(StatsFormat::Json).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "connection closed by server");
        match parse_stats_response(&line)? {
            StatsReply::Json(v) => Ok(v),
            StatsReply::Text(_) => anyhow::bail!("expected json stats reply"),
        }
    }

    /// Scrape the Prometheus text exposition
    /// (`{"cmd":"stats","format":"prometheus"}`).
    pub fn stats_prometheus(&mut self) -> Result<String> {
        self.stream
            .write_all(render_stats_request(StatsFormat::Prometheus).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "connection closed by server");
        match parse_stats_response(&line)? {
            StatsReply::Text(t) => Ok(t),
            StatsReply::Json(_) => anyhow::bail!("expected prometheus stats reply"),
        }
    }

    /// Read one streaming frame (blocks until a line arrives).
    pub fn read_frame(&mut self) -> Result<StreamFrame> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "connection closed by server");
        parse_frame(&line)
    }

    /// Send a streaming request and collect every frame through the
    /// last terminal one (inclusive): grouped streams carry one
    /// terminal frame per sibling, counted via the `siblings` tag. A
    /// plain error line (stream refused before it started — overload,
    /// bad request) becomes an `Err`.
    pub fn stream_generate(&mut self, req: &WireRequest) -> Result<Vec<StreamFrame>> {
        self.send(req)?;
        let mut frames = Vec::new();
        let mut terminals: u32 = 0;
        let mut expected: u32 = 1;
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            anyhow::ensure!(!line.is_empty(), "connection closed by server");
            let Ok(frame) = parse_frame(&line) else {
                anyhow::bail!("stream refused: {}", line.trim());
            };
            if let Some(n) = frame.siblings() {
                expected = expected.max(n);
            }
            if frame.is_terminal() {
                terminals += 1;
            }
            frames.push(frame);
            if terminals >= expected {
                return Ok(frames);
            }
        }
    }
}
