//! Extension: HSR-accelerated attention for the paper's §8 future-work
//! activations — SELU, CELU, PReLU.
//!
//! Section 8 of the paper lists these as open extensions:
//!   SELU(x)  = scale·(max(0,x) + min(0, α·(e^x − 1)))
//!   CELU(x)  = max(0,x) + min(0, α·(e^{x/α} − 1))
//!   PReLU(x) = max(0,x) + w·min(0,x)
//!
//! Unlike ReLU^α, the negative branch of each is *non-zero*, so skipping
//! non-reported entries is no longer error-free. The structure the paper
//! exploits still applies, split into two parts:
//!
//! 1. The positive branch is identical to ReLU: exactly the HSR-reported
//!    set {j : score_j > b} contributes it.
//! 2. The negative branch is **bounded**: |neg(x)| ≤ scale·α (SELU),
//!    ≤ α (CELU), ≤ |w·x| (PReLU). For SELU/CELU the tail contribution
//!    per excluded entry is at most the saturation constant, giving a
//!    computable ℓ∞ error bound analogous to Lemma G.1 — implemented in
//!    [`tail_bound`]. PReLU's negative branch is unbounded, so the sparse
//!    evaluator is exact only when w = 0 (≡ ReLU) and otherwise reports
//!    its bound as infinite (surfaced, not hidden).
//!
//! This makes the §8 program concrete: a saturating negative branch is
//! *sufficient* for HSR acceleration with provable error; an unbounded
//! one is not.

use super::{axpy_row, scores_into, scores_subset_into};

/// Generalized activation for attention scores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Activation {
    /// SELU with the canonical (scale, alpha).
    Selu { scale: f32, alpha: f32 },
    /// CELU(α).
    Celu { alpha: f32 },
    /// PReLU with negative-slope weight.
    Prelu { weight: f32 },
}

impl Activation {
    /// Canonical SELU constants (Klambauer et al. 2017).
    pub fn selu() -> Activation {
        Activation::Selu { scale: 1.0507, alpha: 1.67326 }
    }

    /// Apply the activation.
    #[inline]
    pub fn eval(&self, x: f32) -> f32 {
        match *self {
            Activation::Selu { scale, alpha } => {
                if x > 0.0 {
                    scale * x
                } else {
                    scale * alpha * (x.exp() - 1.0)
                }
            }
            Activation::Celu { alpha } => {
                if x > 0.0 {
                    x
                } else {
                    alpha * ((x / alpha).exp() - 1.0)
                }
            }
            Activation::Prelu { weight } => {
                if x > 0.0 {
                    x
                } else {
                    weight * x
                }
            }
        }
    }

    /// Supremum of |activation(x)| over x ≤ 0 (the saturation constant);
    /// infinite for PReLU with w ≠ 0.
    pub fn negative_saturation(&self) -> f32 {
        match *self {
            Activation::Selu { scale, alpha } => scale * alpha,
            Activation::Celu { alpha } => alpha.abs(),
            Activation::Prelu { weight } => {
                if weight == 0.0 {
                    0.0
                } else {
                    f32::INFINITY
                }
            }
        }
    }
}

/// Dense generalized-activation attention for one query row (oracle):
/// out = D^{-1} act(qK^T/√d − b) V with signed normalization
/// D = Σ_j act(s_j). Rows with D ≈ 0 produce zeros (same convention as
/// the ReLU path).
#[allow(clippy::too_many_arguments)]
pub fn general_attention_row(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    act: Activation,
    bias: f32,
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) {
    scores_into(q, keys, d, scores_buf);
    out.fill(0.0);
    let mut denom = 0f32;
    for s in scores_buf.iter_mut() {
        *s = act.eval(*s - bias);
        denom += *s;
    }
    if denom.abs() < 1e-12 {
        return;
    }
    let inv = 1.0 / denom;
    for (j, &a) in scores_buf.iter().enumerate() {
        if a != 0.0 {
            axpy_row(out, values, d, j, a * inv);
        }
    }
}

/// Result of a sparse generalized-activation evaluation.
pub struct SparseGeneralResult {
    /// ℓ∞ error bound vs the dense computation (0 for exact; inf when
    /// the activation's negative branch is unbounded).
    pub error_bound: f64,
    /// Entries actually evaluated.
    pub evaluated: usize,
}

/// Sparse evaluation on the HSR-reported set `idx` ⊇ {j : s_j − b > 0}:
/// positive branch exact; the excluded negative tail is approximated by
/// its saturation value −c per entry (SELU/CELU saturate within ~5
/// units below threshold, which the Lemma 6.1 b guarantees for most
/// excluded entries), yielding the returned error bound.
#[allow(clippy::too_many_arguments)]
pub fn general_attention_row_sparse(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    act: Activation,
    bias: f32,
    idx: &[u32],
    scores_buf: &mut Vec<f32>,
    out: &mut [f32],
) -> SparseGeneralResult {
    let n = keys.len() / d;
    let excluded = n - idx.len();
    let sat = act.negative_saturation();
    scores_subset_into(q, keys, d, idx, scores_buf);
    out.fill(0.0);
    // Positive + reported-negative contributions, exact.
    let mut denom = 0f32;
    for s in scores_buf.iter_mut() {
        *s = act.eval(*s - bias);
        denom += *s;
    }
    // Excluded tail: approximate each entry by the saturation value −sat,
    // and each excluded V row by the mean of V (cheap proxy; the bound
    // below does not rely on it being good).
    let v_mean: Vec<f32> = {
        let mut m = vec![0f32; d];
        for j in 0..n {
            for (mm, &x) in m.iter_mut().zip(&values[j * d..(j + 1) * d]) {
                *mm += x;
            }
        }
        for mm in m.iter_mut() {
            *mm /= n as f32;
        }
        m
    };
    let tail_weight = -(sat.min(1e30)) * excluded as f32;
    let denom_full = denom + tail_weight;
    if denom_full.abs() < 1e-12 {
        return SparseGeneralResult { error_bound: f64::INFINITY, evaluated: idx.len() };
    }
    let inv = 1.0 / denom_full;
    for (t, &a) in scores_buf.iter().enumerate() {
        if a != 0.0 {
            axpy_row(out, values, d, idx[t] as usize, a * inv);
        }
    }
    if sat > 0.0 && sat.is_finite() && excluded > 0 {
        for (o, &vm) in out.iter_mut().zip(&v_mean) {
            *o += tail_weight * inv * vm;
        }
    }
    let v_inf = super::error::v_inf_norm(values) as f64;
    let bound = tail_bound(sat, excluded, denom_full.abs() as f64, v_inf);
    SparseGeneralResult { error_bound: bound, evaluated: idx.len() }
}

/// ℓ∞ error bound of the saturated-tail approximation: each excluded
/// entry's activation lies in [−sat, 0], our proxy uses −sat exactly, so
/// the per-entry weight error is ≤ sat and (mirroring Lemma G.1's
/// telescoping) ‖err‖∞ ≤ 2·sat·excluded/|D|·‖V‖∞.
pub fn tail_bound(sat: f32, excluded: usize, denom_abs: f64, v_inf: f64) -> f64 {
    if excluded == 0 {
        return 0.0;
    }
    if !sat.is_finite() {
        return f64::INFINITY;
    }
    2.0 * sat as f64 * excluded as f64 / denom_abs.max(1e-12) * v_inf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::linf;
    use crate::hsr::dot;
    use crate::util::rng::Rng;

    #[test]
    fn activation_values() {
        let selu = Activation::selu();
        assert!((selu.eval(1.0) - 1.0507).abs() < 1e-4);
        assert!(selu.eval(-30.0) > -1.7582 && selu.eval(-30.0) < -1.7578);
        let celu = Activation::Celu { alpha: 2.0 };
        assert_eq!(celu.eval(3.0), 3.0);
        assert!((celu.eval(-2.0) - 2.0 * ((-1.0f32).exp() - 1.0)).abs() < 1e-6);
        let prelu = Activation::Prelu { weight: 0.1 };
        assert_eq!(prelu.eval(-5.0), -0.5);
        assert_eq!(prelu.eval(5.0), 5.0);
    }

    #[test]
    fn saturation_constants() {
        assert!((Activation::selu().negative_saturation() - 1.0507 * 1.67326).abs() < 1e-3);
        assert_eq!(Activation::Celu { alpha: 1.5 }.negative_saturation(), 1.5);
        assert_eq!(Activation::Prelu { weight: 0.0 }.negative_saturation(), 0.0);
        assert!(Activation::Prelu { weight: 0.2 }
            .negative_saturation()
            .is_infinite());
    }

    #[test]
    fn prelu_zero_weight_equals_relu() {
        let mut rng = Rng::new(201);
        let (n, d) = (50usize, 4usize);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let mut buf = Vec::new();
        let mut out_g = vec![0f32; d];
        general_attention_row(
            &q, &k, &v, d,
            Activation::Prelu { weight: 0.0 },
            0.2, &mut buf, &mut out_g,
        );
        let relu = crate::attention::relu::relu_attention(&q, &k, &v, d, 1, 0.2);
        assert!(linf(&out_g, &relu) < 1e-5);
    }

    /// The sparse evaluator's measured error stays under its own bound
    /// for the saturating activations.
    #[test]
    fn sparse_error_within_bound_selu_celu() {
        let mut rng = Rng::new(202);
        let (n, d) = (400usize, 8usize);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let bias = 0.8f32;
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let idx: Vec<u32> = (0..n)
            .filter(|&j| dot(&q, &k[j * d..(j + 1) * d]) * inv_sqrt_d - bias > 0.0)
            .map(|j| j as u32)
            .collect();
        assert!(!idx.is_empty() && idx.len() < n);
        for act in [Activation::selu(), Activation::Celu { alpha: 1.0 }] {
            let mut buf = Vec::new();
            let mut dense = vec![0f32; d];
            general_attention_row(&q, &k, &v, d, act, bias, &mut buf, &mut dense);
            let mut sparse = vec![0f32; d];
            let res = general_attention_row_sparse(
                &q, &k, &v, d, act, bias, &idx, &mut buf, &mut sparse,
            );
            let err = linf(&dense, &sparse) as f64;
            assert!(res.error_bound.is_finite());
            assert!(
                err <= res.error_bound + 1e-5,
                "{act:?}: err {err} > bound {}",
                res.error_bound
            );
            assert_eq!(res.evaluated, idx.len());
        }
    }

    #[test]
    fn prelu_nonzero_weight_reports_unbounded() {
        let mut rng = Rng::new(203);
        let (n, d) = (60usize, 4usize);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let k = rng.gaussian_vec_f32(n * d, 1.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let idx: Vec<u32> = (0..10).collect();
        let mut buf = Vec::new();
        let mut out = vec![0f32; d];
        let res = general_attention_row_sparse(
            &q, &k, &v, d,
            Activation::Prelu { weight: 0.25 },
            0.0, &idx, &mut buf, &mut out,
        );
        assert!(res.error_bound.is_infinite(), "PReLU tail must be flagged unbounded");
    }

    /// With a high threshold the excluded entries are deep in the
    /// saturated region, so the proxy is nearly exact for SELU.
    #[test]
    fn deep_saturation_is_accurate() {
        let mut rng = Rng::new(204);
        let (n, d) = (300usize, 8usize);
        let q: Vec<f32> = rng.gaussian_vec_f32(d, 2.0);
        let k = rng.gaussian_vec_f32(n * d, 2.0);
        let v = rng.gaussian_vec_f32(n * d, 1.0);
        let bias = 6.0f32; // scores − b mostly ≪ −5: fully saturated tail
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let idx: Vec<u32> = (0..n)
            .filter(|&j| dot(&q, &k[j * d..(j + 1) * d]) * inv_sqrt_d - bias > -1.0)
            .map(|j| j as u32)
            .collect();
        let act = Activation::selu();
        let mut buf = Vec::new();
        let mut dense = vec![0f32; d];
        general_attention_row(&q, &k, &v, d, act, bias, &mut buf, &mut dense);
        let mut sparse = vec![0f32; d];
        general_attention_row_sparse(&q, &k, &v, d, act, bias, &idx, &mut buf, &mut sparse);
        // The remaining error comes from V-row variation inside the tail,
        // not the activation value; it is small relative to ||dense||.
        let scale = dense.iter().map(|x| x.abs()).fold(0.0f32, f32::max).max(1e-3);
        assert!(
            linf(&dense, &sparse) / scale < 0.75,
            "relative err {}",
            linf(&dense, &sparse) / scale
        );
    }
}
