//! Observability: flight-recorder tracing, sparsity telemetry, and the
//! metrics export surface.
//!
//! Three pillars, one subsystem:
//!
//! * [`trace`] — a bounded per-worker ring buffer of timestamped span
//!   events ([`FlightRecorder`]) correlated by request id. Supervisors
//!   dump the ring as JSONL on worker panic; `--trace-dir` also writes
//!   per-request timelines at terminal outcomes.
//! * [`telemetry`] — per-context-length fired-fraction histograms
//!   ([`SparsityHist`]) checking empirical sparsity against the paper's
//!   `n^{4/5}` decode envelope, plus the shared [`ratio_or`] guard for
//!   every metrics ratio.
//! * [`export`] — a snapshot/delta registry ([`Snapshot`]) over the
//!   engine's merged `Metrics` with Prometheus-style text exposition
//!   and a JSON form, served by the `{"cmd":"stats"}` admin frame and
//!   the `--metrics-interval` stderr reporter.
//!
//! Everything stamps time with [`clock::now_us`] — one process-wide
//! monotonic clock — so `reqlog` lines, trace dumps, and snapshots
//! merge-sort into a single timeline.

pub mod clock;
pub mod export;
pub mod telemetry;
pub mod trace;

pub use export::{MetricKind, Snapshot};
pub use telemetry::{ratio_or, SparsityHist};
pub use trace::{FlightRecorder, SpanKind, TraceConfig, TraceEvent};
