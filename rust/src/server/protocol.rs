//! Wire protocol (JSON lines) for the serving front-end.

use crate::engine::{FinishReason, Response};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use anyhow::Result;

/// Parsed inbound request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub stop_token: Option<u32>,
}

/// Parse a request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = v.req_str("prompt")?.to_string();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(64)
        .clamp(1, 4096);
    let temperature = v
        .get("temperature")
        .and_then(|x| x.as_f64())
        .unwrap_or(0.0) as f32;
    let stop_token = v
        .get("stop_token")
        .and_then(|x| x.as_usize())
        .map(|t| t as u32);
    Ok(WireRequest { prompt, max_new_tokens, temperature, stop_token })
}

/// Render a response line.
pub fn render_response(resp: &Response, tokenizer: &ByteTokenizer) -> String {
    let mut o = Json::obj();
    o.set("id", resp.id.into())
        .set("text", tokenizer.decode(&resp.tokens).into())
        .set("latency_ms", resp.latency_ms.into())
        .set("ttft_ms", resp.ttft_ms.into())
        .set("prompt_len", resp.prompt_len.into())
        .set(
            "finish",
            match resp.finish {
                FinishReason::Length => "length",
                FinishReason::StopToken => "stop",
                FinishReason::Aborted => "aborted",
            }
            .into(),
        );
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let r = parse_request(
            r#"{"prompt":"hello","max_new_tokens":12,"temperature":0.5,"stop_token":46}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.max_new_tokens, 12);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.stop_token, Some(46));
    }

    #[test]
    fn defaults_and_validation() {
        let r = parse_request(r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.stop_token, None);
        assert!(parse_request(r#"{"prompt":""}"#).is_err());
        assert!(parse_request("not json").is_err());
        // max_new_tokens clamped.
        let r = parse_request(r#"{"prompt":"x","max_new_tokens":100000}"#).unwrap();
        assert_eq!(r.max_new_tokens, 4096);
    }

    #[test]
    fn render_roundtrips_through_json() {
        let resp = Response {
            id: 9,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            latency_ms: 1.5,
            ttft_ms: 0.5,
            prompt_len: 3,
        };
        let line = render_response(&resp, &ByteTokenizer);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req_str("text").unwrap(), "hi");
        assert_eq!(v.req_usize("id").unwrap(), 9);
        assert_eq!(v.req_str("finish").unwrap(), "length");
    }
}
