//! Ball-tree HSR: the Part-1 analogue of Corollary 3.1.
//!
//! Build: recursively split the point set on the dimension of largest
//! spread at the median — O(n log n). Each node stores the centroid c and
//! radius ρ of its point set. For a query half-space {x : <a,x> >= b}:
//!
//! * if  <a,c> − ρ‖a‖ ≥ b   the whole subtree satisfies the query →
//!   report its contiguous index range in O(k) without evaluating points;
//! * if  <a,c> + ρ‖a‖ < b   no point can satisfy it → prune;
//! * otherwise recurse; leaves are scanned point-by-point.
//!
//! On the paper's Gaussian workloads with the Lemma-6.1 threshold the
//! query touches a vanishing fraction of points (verified in tests below
//! and measured against n in `benches/hsr_structures.rs`). The worst case
//! is Θ(n) — the AEM92 guarantee is stronger — but the *shape* (output-
//! sensitive sublinear reporting) is what the paper's algorithms consume;
//! see DESIGN.md §3 for the substitution argument.

use super::{dot, HalfSpaceReport, QueryStats};

const LEAF_SIZE: usize = 48;

#[derive(Debug, Clone)]
struct Node {
    /// Range [start, end) into `order`.
    start: u32,
    end: u32,
    /// Children node ids; u32::MAX marks a leaf.
    left: u32,
    right: u32,
    /// Ball radius around the centroid.
    radius: f32,
    /// Centroid offset into `centroids` is the node id * d.
    _pad: u32,
}

const NONE: u32 = u32::MAX;

/// Static ball-tree over a point set.
#[derive(Debug, Clone)]
pub struct BallTreeHsr {
    points: Vec<f32>, // points permuted into `order` layout, row-major
    order: Vec<u32>,  // order[slot] = original index
    centroids: Vec<f32>,
    nodes: Vec<Node>,
    n: usize,
    d: usize,
}

impl BallTreeHsr {
    /// O(n log n) build.
    pub fn build(points: &[f32], d: usize) -> BallTreeHsr {
        assert!(d > 0);
        assert_eq!(points.len() % d, 0);
        let n = points.len() / d;
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut tree = BallTreeHsr {
            points: Vec::with_capacity(n * d),
            order: Vec::new(),
            centroids: Vec::new(),
            nodes: Vec::new(),
            n,
            d,
        };
        if n > 0 {
            tree.build_node(points, &mut order, 0, n);
        }
        // Lay points out in `order` order for cache-friendly leaf scans
        // and O(k) contiguous subtree reporting.
        for &idx in &order {
            let i = idx as usize;
            tree.points.extend_from_slice(&points[i * d..(i + 1) * d]);
        }
        tree.order = order;
        tree
    }

    /// Recursively build the node over order[start..end]; returns node id.
    fn build_node(
        &mut self,
        points: &[f32],
        order: &mut [u32],
        start: usize,
        end: usize,
    ) -> u32 {
        let d = self.d;
        let id = self.nodes.len() as u32;
        self.nodes.push(Node {
            start: start as u32,
            end: end as u32,
            left: NONE,
            right: NONE,
            radius: 0.0,
            _pad: 0,
        });
        // Centroid.
        let mut centroid = vec![0f32; d];
        for &idx in &order[start..end] {
            let p = &points[idx as usize * d..(idx as usize + 1) * d];
            for (c, &x) in centroid.iter_mut().zip(p) {
                *c += x;
            }
        }
        let count = (end - start) as f32;
        for c in centroid.iter_mut() {
            *c /= count;
        }
        // Radius.
        let mut r2max = 0f32;
        for &idx in &order[start..end] {
            let p = &points[idx as usize * d..(idx as usize + 1) * d];
            let mut r2 = 0f32;
            for (c, &x) in centroid.iter().zip(p) {
                let diff = x - c;
                r2 += diff * diff;
            }
            r2max = r2max.max(r2);
        }
        self.nodes[id as usize].radius = r2max.sqrt();
        self.centroids.extend_from_slice(&centroid);

        if end - start > LEAF_SIZE {
            // Split dimension: largest variance.
            let mut best_dim = 0;
            let mut best_var = -1f32;
            for j in 0..d {
                let mut sum = 0f32;
                let mut sumsq = 0f32;
                for &idx in &order[start..end] {
                    let x = points[idx as usize * d + j];
                    sum += x;
                    sumsq += x * x;
                }
                let mean = sum / count;
                let var = sumsq / count - mean * mean;
                if var > best_var {
                    best_var = var;
                    best_dim = j;
                }
            }
            let mid = start + (end - start) / 2;
            order[start..end].select_nth_unstable_by(mid - start, |&a, &b| {
                let xa = points[a as usize * d + best_dim];
                let xb = points[b as usize * d + best_dim];
                xa.partial_cmp(&xb).unwrap_or(std::cmp::Ordering::Equal)
            });
            let left = self.build_node(points, order, start, mid);
            let right = self.build_node(points, order, mid, end);
            self.nodes[id as usize].left = left;
            self.nodes[id as usize].right = right;
        }
        id
    }

    #[inline]
    fn centroid(&self, id: u32) -> &[f32] {
        let o = id as usize * self.d;
        &self.centroids[o..o + self.d]
    }

    /// Iterative traversal with an explicit stack (the recursive version
    /// cost ~15% in call overhead on deep trees — see EXPERIMENTS.md §Perf).
    /// With `scores: Some(_)` every reported index also gets its raw
    /// inner product pushed: leaf scans reuse the dot the membership test
    /// already computed, and bulk-reported subtrees are scored with a
    /// contiguous SIMD sweep over the permuted point layout.
    fn query_iter(
        &self,
        a: &[f32],
        a_norm: f32,
        b: f32,
        out: &mut Vec<u32>,
        mut scores: Option<&mut Vec<f32>>,
        stats: &mut QueryStats,
    ) {
        let mut stack: Vec<u32> = Vec::with_capacity(64);
        stack.push(0);
        while let Some(id) = stack.pop() {
            let node = &self.nodes[id as usize];
            stats.nodes_visited += 1;
            let proj = dot(self.centroid(id), a);
            let margin = node.radius * a_norm;
            if proj + margin < b {
                continue; // prune: no point in this ball reaches b
            }
            let (s, e) = (node.start as usize, node.end as usize);
            if proj - margin >= b {
                // Whole subtree satisfies the half-space: bulk report.
                out.extend_from_slice(&self.order[s..e]);
                if let Some(sc) = scores.as_mut() {
                    // Contiguous rows: dense blocked scoring, scale 1.
                    let start = sc.len();
                    sc.resize(start + (e - s), 0.0);
                    crate::kernel::simd::scaled_dots_into(
                        a,
                        &self.points[s * self.d..e * self.d],
                        self.d,
                        1.0,
                        &mut sc[start..],
                    );
                }
                stats.bulk_reported += e - s;
                stats.reported += e - s;
                continue;
            }
            if node.left == NONE {
                // Leaf: contiguous scan over the permuted point layout.
                stats.points_scanned += e - s;
                for slot in s..e {
                    let p = &self.points[slot * self.d..(slot + 1) * self.d];
                    let sdot = dot(p, a);
                    if sdot >= b {
                        out.push(self.order[slot]);
                        if let Some(sc) = scores.as_mut() {
                            sc.push(sdot);
                        }
                        stats.reported += 1;
                    }
                }
                continue;
            }
            stack.push(node.right);
            stack.push(node.left);
        }
    }

    /// Shared-traversal multi-query engine behind
    /// [`HalfSpaceReport::query_many_scored_into`]: one DFS answers every
    /// query in the block. `arena[lo..hi]` holds the query ids still
    /// *active* at this node (neither pruned nor bulk-reported by an
    /// ancestor); the node is visited — and counted — **once** for the
    /// whole block, while prune / bulk / leaf-scan decisions (and their
    /// per-point counters) stay per query, reproducing the single-query
    /// results element-for-element. Queries that recurse are appended to
    /// the arena tail, so the recursion allocates nothing per node.
    ///
    /// `scores` is optional so exact-filter callers ([`ProjectedHsr`])
    /// can share the traversal without paying for candidate scores.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn query_many_impl(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        mut scores: Option<&mut [Vec<f32>]>,
        stats: &mut QueryStats,
    ) {
        let d = self.d;
        let q = bs.len();
        assert_eq!(queries.len(), q * d);
        assert_eq!(outs.len(), q);
        if let Some(sc) = scores.as_ref() {
            assert_eq!(sc.len(), q);
        }
        if self.n == 0 || q == 0 {
            return;
        }
        let norms: Vec<f32> = (0..q)
            .map(|i| super::norm(&queries[i * d..(i + 1) * d]))
            .collect();
        let mut arena: Vec<u32> = (0..q as u32).collect();
        let hi = arena.len();
        self.query_many_rec(0, queries, &norms, bs, &mut arena, 0, hi, outs, &mut scores, stats);
    }

    #[allow(clippy::too_many_arguments)]
    fn query_many_rec(
        &self,
        id: u32,
        queries: &[f32],
        norms: &[f32],
        bs: &[f32],
        arena: &mut Vec<u32>,
        lo: usize,
        hi: usize,
        outs: &mut [Vec<u32>],
        scores: &mut Option<&mut [Vec<f32>]>,
        stats: &mut QueryStats,
    ) {
        let d = self.d;
        let node = &self.nodes[id as usize];
        stats.nodes_visited += 1;
        let c = self.centroid(id);
        let (s, e) = (node.start as usize, node.end as usize);
        let is_leaf = node.left == NONE;
        let start = arena.len();
        for t in lo..hi {
            let qi = arena[t] as usize;
            let a = &queries[qi * d..(qi + 1) * d];
            let proj = dot(c, a);
            let margin = node.radius * norms[qi];
            let b = bs[qi];
            if proj + margin < b {
                continue; // pruned for this query only
            }
            if proj - margin >= b {
                // Whole subtree satisfies this query: bulk report.
                outs[qi].extend_from_slice(&self.order[s..e]);
                if let Some(sc) = scores.as_mut() {
                    let sc = &mut sc[qi];
                    let st = sc.len();
                    sc.resize(st + (e - s), 0.0);
                    crate::kernel::simd::scaled_dots_into(
                        a,
                        &self.points[s * d..e * d],
                        d,
                        1.0,
                        &mut sc[st..],
                    );
                }
                stats.bulk_reported += e - s;
                stats.reported += e - s;
                continue;
            }
            if is_leaf {
                // Leaf scan for this query: per-(query, point) counting.
                stats.points_scanned += e - s;
                for slot in s..e {
                    let p = &self.points[slot * d..(slot + 1) * d];
                    let sdot = dot(p, a);
                    if sdot >= b {
                        outs[qi].push(self.order[slot]);
                        if let Some(sc) = scores.as_mut() {
                            sc[qi].push(sdot);
                        }
                        stats.reported += 1;
                    }
                }
            } else {
                let keep = arena[t];
                arena.push(keep);
            }
        }
        let end = arena.len();
        if !is_leaf && end > start {
            self.query_many_rec(
                node.left, queries, norms, bs, arena, start, end, outs, scores, stats,
            );
            arena.truncate(end);
            self.query_many_rec(
                node.right, queries, norms, bs, arena, start, end, outs, scores, stats,
            );
        }
        arena.truncate(start);
    }
}

impl HalfSpaceReport for BallTreeHsr {
    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        assert_eq!(a.len(), self.d);
        if self.n == 0 {
            return;
        }
        let a_norm = super::norm(a);
        self.query_iter(a, a_norm, b, out, None, stats);
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        assert_eq!(a.len(), self.d);
        if self.n == 0 {
            return;
        }
        let a_norm = super::norm(a);
        self.query_iter(a, a_norm, b, out, Some(scores), stats);
    }

    /// Native shared traversal: the whole query block walks the tree
    /// once; see [`BallTreeHsr::query_many_impl`] for the counting rules.
    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        assert_eq!(scores.len(), bs.len());
        self.query_many_impl(queries, bs, outs, Some(scores), stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::{gaussian_points, reference_query};
    use crate::util::rng::Rng;

    #[test]
    fn matches_reference_many_random() {
        let mut rng = Rng::new(7);
        for _ in 0..40 {
            let d = rng.range(1, 12);
            let n = rng.range(0, 600);
            let pts = gaussian_points(&mut rng, n, d, 1.0);
            let tree = BallTreeHsr::build(&pts, d);
            for _ in 0..4 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.0) as f32;
                assert_eq!(tree.query(&a, b), reference_query(&pts, d, &a, b));
            }
        }
    }

    #[test]
    fn duplicate_points_ok() {
        let mut pts = Vec::new();
        for _ in 0..100 {
            pts.extend_from_slice(&[1.0f32, 2.0]);
        }
        let tree = BallTreeHsr::build(&pts, 2);
        assert_eq!(tree.query(&[1.0, 0.0], 0.5).len(), 100);
        assert_eq!(tree.query(&[1.0, 0.0], 1.5).len(), 0);
    }

    /// Pruning effectiveness tracks the AEM92 d-dependence
    /// (O(n^{1-1/⌊d/2⌋}) per query): strong at low d, vanishing at high d
    /// on *isotropic* Gaussians. Measured on this workload (n = 20k):
    /// d=2 scans ~1.5% of points, d=4 ~11%, d=8 ~47%, d>=16 ~100%.
    /// The engine uses [`super::projected::ProjectedHsr`] for the
    /// anisotropic keys of trained models; see DESIGN.md §3.
    #[test]
    fn query_is_sublinear_on_low_d_gaussian_workload() {
        let mut rng = Rng::new(11);
        let (n, d) = (20_000usize, 4usize);
        let pts = gaussian_points(&mut rng, n, d, 1.0);
        let tree = BallTreeHsr::build(&pts, d);
        let q = rng.gaussian_vec_f32(d, 1.0);
        // b chosen per Lemma 6.1 at sigma_a = ||q|| * sigma_k / sqrt(d).
        let sigma_a = crate::hsr::norm(&q) as f64 / (d as f64).sqrt();
        let b = (sigma_a * (0.4 * (n as f64).ln()).sqrt()) as f32;
        // The half-space test is on <q, K_i>/sqrt(d) >= b, i.e. <q,K_i> >= b*sqrt(d).
        let bs = b * (d as f32).sqrt();
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        tree.query_into(&q, bs, &mut out, &mut stats);
        out.sort_unstable();
        assert_eq!(out, reference_query(&pts, d, &q, bs));
        assert!(
            stats.points_scanned < n / 3,
            "scanned {} of {} points — pruning ineffective",
            stats.points_scanned,
            n
        );
    }

    #[test]
    fn bulk_report_fires_for_deep_halfspace() {
        // A threshold below every projection must bulk-report the root.
        let mut rng = Rng::new(3);
        let pts = gaussian_points(&mut rng, 5_000, 8, 1.0);
        let tree = BallTreeHsr::build(&pts, 8);
        let a = rng.gaussian_vec_f32(8, 1.0);
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        tree.query_into(&a, -1e9, &mut out, &mut stats);
        assert_eq!(out.len(), 5_000);
        assert_eq!(stats.points_scanned, 0, "everything should bulk-report");
        assert_eq!(stats.bulk_reported, 5_000);
    }

    #[test]
    fn single_point_and_leaf_sizes() {
        for n in [1usize, 2, LEAF_SIZE, LEAF_SIZE + 1, 3 * LEAF_SIZE + 5] {
            let mut rng = Rng::new(n as u64);
            let pts = gaussian_points(&mut rng, n, 3, 1.0);
            let tree = BallTreeHsr::build(&pts, 3);
            let a = rng.gaussian_vec_f32(3, 1.0);
            assert_eq!(tree.query(&a, 0.0), reference_query(&pts, 3, &a, 0.0));
        }
    }
}
