//! Brute-force HSR: the naive O(n·d) scan.
//!
//! This is both the correctness oracle for the other backends and the
//! "naive O(mn)" baseline that every running-time theorem in the paper
//! compares against (Theorems 4.1, 4.2, 5.1, 5.2).

use super::{dot, HalfSpaceReport, QueryStats};

/// A flat copy of the points; every query scans all of them.
#[derive(Debug, Clone)]
pub struct BruteHsr {
    points: Vec<f32>,
    n: usize,
    d: usize,
}

impl BruteHsr {
    /// O(n) build: copy the points.
    pub fn build(points: &[f32], d: usize) -> BruteHsr {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(points.len() % d, 0, "points length must be a multiple of d");
        BruteHsr { points: points.to_vec(), n: points.len() / d, d }
    }

    /// Raw point row.
    pub fn point(&self, i: usize) -> &[f32] {
        &self.points[i * self.d..(i + 1) * self.d]
    }
}

impl HalfSpaceReport for BruteHsr {
    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        assert_eq!(a.len(), self.d);
        stats.points_scanned += self.n;
        for i in 0..self.n {
            if dot(self.point(i), a) >= b {
                out.push(i as u32);
                stats.reported += 1;
            }
        }
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        assert_eq!(a.len(), self.d);
        stats.points_scanned += self.n;
        for i in 0..self.n {
            let s = dot(self.point(i), a);
            if s >= b {
                out.push(i as u32);
                scores.push(s);
                stats.reported += 1;
            }
        }
    }

    /// Shared point stream: each key row is loaded once and dotted
    /// against the whole query block (better cache behaviour at fan-out;
    /// a scan has no nodes to amortize, so `QueryStats` totals are
    /// identical to the per-query loop). Output order per query is the
    /// same ascending index order as the single-query scan.
    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        let d = self.d;
        let q = bs.len();
        assert_eq!(queries.len(), q * d);
        assert_eq!(outs.len(), q);
        assert_eq!(scores.len(), q);
        stats.points_scanned += self.n * q;
        for i in 0..self.n {
            let p = self.point(i);
            for qi in 0..q {
                let s = dot(p, &queries[qi * d..(qi + 1) * d]);
                if s >= bs[qi] {
                    outs[qi].push(i as u32);
                    scores[qi].push(s);
                    stats.reported += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::reference_query;
    use crate::util::rng::Rng;

    #[test]
    fn simple_halfplane() {
        // Points on the x-axis: query "x >= 1.5" reports indices 2, 3.
        let pts = vec![0.0f32, 0.0, 1.0, 0.0, 2.0, 0.0, 3.0, 0.0];
        let h = BruteHsr::build(&pts, 2);
        assert_eq!(h.query(&[1.0, 0.0], 1.5), vec![2, 3]);
        assert_eq!(h.query(&[1.0, 0.0], -1.0), vec![0, 1, 2, 3]);
        assert_eq!(h.query(&[1.0, 0.0], 100.0), Vec::<u32>::new());
    }

    #[test]
    fn boundary_is_inclusive() {
        // sgn(<a,x> - b) >= 0 includes equality (paper Algorithm 3).
        let pts = vec![2.0f32, 0.0];
        let h = BruteHsr::build(&pts, 2);
        assert_eq!(h.query(&[1.0, 0.0], 2.0), vec![0]);
    }

    #[test]
    fn matches_reference_and_counts_work() {
        let mut r = Rng::new(5);
        let d = 6;
        let n = 500;
        let pts = r.gaussian_vec_f32(n * d, 1.0);
        let h = BruteHsr::build(&pts, d);
        let a = r.gaussian_vec_f32(d, 1.0);
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        h.query_into(&a, 0.5, &mut out, &mut stats);
        out.sort_unstable();
        assert_eq!(out, reference_query(&pts, d, &a, 0.5));
        assert_eq!(stats.points_scanned, n);
        assert_eq!(stats.reported, out.len());
    }

    #[test]
    #[should_panic]
    fn bad_length_panics() {
        let _ = BruteHsr::build(&[1.0, 2.0, 3.0], 2);
    }
}
