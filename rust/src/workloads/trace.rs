//! Serving-trace generation for the end-to-end benches: Poisson arrivals
//! with log-normal-ish prompt lengths and geometric output lengths,
//! loosely shaped after public LLM serving traces.
//!
//! Besides the plain open-loop trace ([`generate`]), two structured
//! workloads exercise the engine's prefix-sharing paths:
//!
//! * [`generate_multi_turn`] — chat sessions whose turns re-arrive with
//!   the full previous context as a shared prefix (radix-cache hits);
//! * [`generate_fork_join`] — agentic DAGs: a root request forks into
//!   `branches` siblings off the same context (sometimes as one grouped
//!   `"n"`-request), whose results a join request then extends.
//!
//! # Determinism
//!
//! Every request's content is drawn from a child [`Rng`] forked off the
//! trace stream (one `fork()` draw per request/session), so request *i*
//! depends only on the seed and its index — never on how many samples
//! earlier requests happened to consume. Changing output-length
//! parameters therefore cannot shift arrival times or prompt lengths,
//! and extending a trace keeps its existing prefix bit-identical.

use crate::util::rng::Rng;

/// One request in a serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length in tokens (including any shared prefix).
    pub prompt_len: usize,
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
    /// Leading prompt tokens shared verbatim with an earlier request of
    /// the same session (0 → fresh prompt). An engine with prefix
    /// caching skips their prefill.
    pub shared_prefix_len: usize,
    /// Session / DAG this request belongs to (plain traces: one
    /// session per request).
    pub session: usize,
    /// Parallel samples to request (the wire `"n"`); 1 → plain.
    pub n: u32,
    /// Beam width (the wire `"beam_width"`); 0 → off.
    pub beam_width: u32,
}

/// Trace generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    /// Mean arrival rate (requests/second). `f64::INFINITY` → all at t=0
    /// (closed-loop / offline batch workload).
    pub rate: f64,
    /// Log-space mean and std of prompt lengths.
    pub prompt_log_mean: f64,
    pub prompt_log_std: f64,
    /// Clamp for prompt lengths.
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// Mean output length (geometric).
    pub mean_new_tokens: f64,
    pub max_new_tokens: usize,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            rate: 4.0,
            prompt_log_mean: 5.0, // e^5 ≈ 148 tokens
            prompt_log_std: 0.8,
            prompt_min: 8,
            prompt_max: 4096,
            mean_new_tokens: 32.0,
            max_new_tokens: 128,
        }
    }
}

/// Draw one (prompt length, output length) pair.
fn sample_lengths(r: &mut Rng, params: &TraceParams) -> (usize, usize) {
    let prompt = (r.normal(params.prompt_log_mean, params.prompt_log_std))
        .exp()
        .round() as usize;
    let prompt_len = prompt.clamp(params.prompt_min, params.prompt_max);
    // Geometric with the given mean: p = 1/mean.
    let p = (1.0 / params.mean_new_tokens).clamp(1e-6, 1.0);
    let mut new_tokens = 1usize;
    while new_tokens < params.max_new_tokens && !r.bool(p) {
        new_tokens += 1;
    }
    (prompt_len, new_tokens)
}

/// Geometric draw with the given mean (≥ 1, capped).
fn sample_count(r: &mut Rng, mean: f64, cap: usize) -> usize {
    let p = (1.0 / mean.max(1.0)).clamp(1e-6, 1.0);
    let mut k = 1usize;
    while k < cap && !r.bool(p) {
        k += 1;
    }
    k
}

/// Generate `count` independent requests.
pub fn generate(rng: &mut Rng, params: &TraceParams, count: usize) -> Vec<TraceRequest> {
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        if params.rate.is_finite() {
            t += rng.exponential(params.rate);
        }
        let mut r = rng.fork();
        let (prompt_len, new_tokens) = sample_lengths(&mut r, params);
        out.push(TraceRequest {
            arrival_s: t,
            prompt_len,
            max_new_tokens: new_tokens,
            shared_prefix_len: 0,
            session: i,
            n: 1,
            beam_width: 0,
        });
    }
    out
}

/// Multi-turn (chat) workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct MultiTurnParams {
    pub base: TraceParams,
    /// Mean turns per session (geometric, ≥ 1, capped at 32).
    pub mean_turns: f64,
    /// Mean client think time between a reply and the next turn (s).
    pub think_s: f64,
}

impl Default for MultiTurnParams {
    fn default() -> Self {
        MultiTurnParams { base: TraceParams::default(), mean_turns: 3.0, think_s: 2.0 }
    }
}

/// Generate `sessions` chat sessions. Turn `k+1` of a session re-arrives
/// with the whole of turn `k`'s context (prompt + generated reply) as
/// its shared prefix, plus a fresh user message; the result is sorted by
/// arrival time (sessions interleave).
pub fn generate_multi_turn(
    rng: &mut Rng,
    params: &MultiTurnParams,
    sessions: usize,
) -> Vec<TraceRequest> {
    let mut t = 0.0f64;
    let mut out = Vec::new();
    for sid in 0..sessions {
        if params.base.rate.is_finite() {
            t += rng.exponential(params.base.rate);
        }
        let mut r = rng.fork();
        let turns = sample_count(&mut r, params.mean_turns, 32);
        let mut arrival = t;
        let mut context = 0usize;
        for _ in 0..turns {
            let (user_len, new_tokens) = sample_lengths(&mut r, &params.base);
            out.push(TraceRequest {
                arrival_s: arrival,
                prompt_len: context + user_len,
                max_new_tokens: new_tokens,
                shared_prefix_len: context,
                session: sid,
                n: 1,
                beam_width: 0,
            });
            context += user_len + new_tokens;
            arrival += r.exponential(1.0 / params.think_s.max(1e-9));
        }
    }
    out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    out
}

/// Agentic fork/join DAG workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct ForkJoinParams {
    pub base: TraceParams,
    /// Sibling branches per fork point (≥ 1).
    pub branches: usize,
    /// Fork/join rounds per DAG.
    pub rounds: usize,
    /// Mean gap between a round's replies and the next round (s).
    pub think_s: f64,
    /// Probability a fork round arrives as ONE grouped request
    /// (`n = branches`, decoded as COW-forked siblings in-engine)
    /// instead of `branches` separate sharing arrivals.
    pub grouped_prob: f64,
}

impl Default for ForkJoinParams {
    fn default() -> Self {
        ForkJoinParams {
            base: TraceParams::default(),
            branches: 4,
            rounds: 2,
            think_s: 1.0,
            grouped_prob: 0.5,
        }
    }
}

/// Generate `dags` fork/join DAGs. Each DAG: a root request, then per
/// round either `branches` sibling requests sharing the root's full
/// context (prefix-cache fan-out) or one grouped `n = branches`
/// request (in-engine COW fork), followed by a join request that
/// extends the shared context with a digest of the branch outputs.
/// Sorted by arrival time.
pub fn generate_fork_join(
    rng: &mut Rng,
    params: &ForkJoinParams,
    dags: usize,
) -> Vec<TraceRequest> {
    let branches = params.branches.max(1);
    let mut t = 0.0f64;
    let mut out = Vec::new();
    for did in 0..dags {
        if params.base.rate.is_finite() {
            t += rng.exponential(params.base.rate);
        }
        let mut r = rng.fork();
        let think = |r: &mut Rng| r.exponential(1.0 / params.think_s.max(1e-9));
        let (root_prompt, root_out) = sample_lengths(&mut r, &params.base);
        out.push(TraceRequest {
            arrival_s: t,
            prompt_len: root_prompt,
            max_new_tokens: root_out,
            shared_prefix_len: 0,
            session: did,
            n: 1,
            beam_width: 0,
        });
        let mut context = root_prompt + root_out;
        let mut arrival = t + think(&mut r);
        for _ in 0..params.rounds {
            let mut digest = 0usize;
            if r.bool(params.grouped_prob) {
                // The whole fork round as one grouped request; the
                // engine forks the siblings off a shared KV chain.
                let (instr, branch_out) = sample_lengths(&mut r, &params.base);
                out.push(TraceRequest {
                    arrival_s: arrival,
                    prompt_len: context + instr,
                    max_new_tokens: branch_out,
                    shared_prefix_len: context,
                    session: did,
                    n: branches as u32,
                    beam_width: 0,
                });
                digest = branch_out.min(32);
            } else {
                for _ in 0..branches {
                    let (instr, branch_out) = sample_lengths(&mut r, &params.base);
                    out.push(TraceRequest {
                        arrival_s: arrival,
                        prompt_len: context + instr,
                        max_new_tokens: branch_out,
                        shared_prefix_len: context,
                        session: did,
                        n: 1,
                        beam_width: 0,
                    });
                    digest += branch_out.min(32);
                }
            }
            // Join: re-arrives on the shared context with the branch
            // digests appended, after the branches had time to finish.
            arrival += think(&mut r);
            let (join_instr, join_out) = sample_lengths(&mut r, &params.base);
            out.push(TraceRequest {
                arrival_s: arrival,
                prompt_len: context + digest + join_instr,
                max_new_tokens: join_out,
                shared_prefix_len: context,
                session: did,
                n: 1,
                beam_width: 0,
            });
            context += digest + join_instr + join_out;
            arrival += think(&mut r);
        }
    }
    out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_rate_matches() {
        let mut rng = Rng::new(91);
        let params = TraceParams { rate: 10.0, ..Default::default() };
        let trace = generate(&mut rng, &params, 2000);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let total = trace.last().unwrap().arrival_s;
        let rate = trace.len() as f64 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate={rate}");
    }

    #[test]
    fn offline_trace_has_zero_arrivals() {
        let mut rng = Rng::new(92);
        let params = TraceParams { rate: f64::INFINITY, ..Default::default() };
        let trace = generate(&mut rng, &params, 10);
        assert!(trace.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn lengths_respect_bounds() {
        let mut rng = Rng::new(93);
        let params = TraceParams {
            prompt_min: 16,
            prompt_max: 256,
            max_new_tokens: 64,
            ..Default::default()
        };
        for r in generate(&mut rng, &params, 500) {
            assert!((16..=256).contains(&r.prompt_len));
            assert!((1..=64).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn mean_output_length_approximates_target() {
        let mut rng = Rng::new(94);
        let params = TraceParams {
            mean_new_tokens: 20.0,
            max_new_tokens: 1000,
            ..Default::default()
        };
        let trace = generate(&mut rng, &params, 3000);
        let mean: f64 =
            trace.iter().map(|r| r.max_new_tokens as f64).sum::<f64>() / trace.len() as f64;
        assert!((mean - 20.0).abs() < 2.0, "mean={mean}");
    }

    /// Golden determinism: the same seed yields the same trace, for all
    /// three generators.
    #[test]
    fn same_seed_same_trace() {
        let params = TraceParams::default();
        let a = generate(&mut Rng::new(7), &params, 100);
        let b = generate(&mut Rng::new(7), &params, 100);
        assert_eq!(a, b);
        let mt = MultiTurnParams::default();
        let a = generate_multi_turn(&mut Rng::new(7), &mt, 20);
        let b = generate_multi_turn(&mut Rng::new(7), &mt, 20);
        assert_eq!(a, b);
        let fj = ForkJoinParams::default();
        let a = generate_fork_join(&mut Rng::new(7), &fj, 10);
        let b = generate_fork_join(&mut Rng::new(7), &fj, 10);
        assert_eq!(a, b);
    }

    /// Extending a trace must not perturb its existing prefix: request
    /// `i` draws from its own forked stream, so it only depends on the
    /// seed and `i`.
    #[test]
    fn longer_trace_keeps_its_prefix() {
        let params = TraceParams::default();
        let short = generate(&mut Rng::new(11), &params, 10);
        let long = generate(&mut Rng::new(11), &params, 40);
        assert_eq!(&long[..10], &short[..]);
    }

    /// Output-length knobs must not shift arrivals or prompt lengths —
    /// the variable-draw geometric loop runs on the per-request fork,
    /// not on the shared trace stream.
    #[test]
    fn output_length_params_do_not_shift_arrivals() {
        let a_params = TraceParams { mean_new_tokens: 4.0, max_new_tokens: 8, ..Default::default() };
        let b_params =
            TraceParams { mean_new_tokens: 64.0, max_new_tokens: 256, ..Default::default() };
        let a = generate(&mut Rng::new(13), &a_params, 200);
        let b = generate(&mut Rng::new(13), &b_params, 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
    }

    #[test]
    fn multi_turn_prefixes_grow_within_sessions() {
        let mut rng = Rng::new(17);
        let trace = generate_multi_turn(&mut rng, &MultiTurnParams::default(), 40);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s, "sorted by arrival");
        }
        let sessions = trace.iter().map(|r| r.session).max().unwrap() + 1;
        let mut saw_multi = false;
        for sid in 0..sessions {
            // Per session (already arrival-ordered), the shared prefix
            // is exactly the previous turn's full context.
            let mut context = 0usize;
            let mut turns = 0;
            for r in trace.iter().filter(|r| r.session == sid) {
                assert_eq!(r.shared_prefix_len, context);
                assert!(r.prompt_len > r.shared_prefix_len);
                context = r.prompt_len + r.max_new_tokens;
                turns += 1;
            }
            saw_multi |= turns > 1;
        }
        assert!(saw_multi, "mean_turns=3 over 40 sessions must yield a multi-turn one");
    }

    #[test]
    fn fork_join_rounds_share_the_dag_context() {
        let mut rng = Rng::new(19);
        let params = ForkJoinParams { grouped_prob: 0.5, ..Default::default() };
        let trace = generate_fork_join(&mut rng, &params, 30);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s, "sorted by arrival");
        }
        let dags = trace.iter().map(|r| r.session).max().unwrap() + 1;
        let (mut saw_grouped, mut saw_fanout) = (false, false);
        for did in 0..dags {
            let reqs: Vec<&TraceRequest> =
                trace.iter().filter(|r| r.session == did).collect();
            // Exactly one root; everything after shares a prefix.
            assert_eq!(reqs.iter().filter(|r| r.shared_prefix_len == 0).count(), 1);
            for r in &reqs {
                assert!(r.prompt_len > r.shared_prefix_len);
                if r.n > 1 {
                    assert_eq!(r.n as usize, params.branches);
                    saw_grouped = true;
                }
            }
            // Sibling fan-out: several requests sharing one identical
            // prefix length (a fork round that wasn't grouped).
            for i in 0..reqs.len() {
                let twins = reqs
                    .iter()
                    .filter(|r| {
                        r.shared_prefix_len == reqs[i].shared_prefix_len
                            && r.shared_prefix_len > 0
                    })
                    .count();
                saw_fanout |= twins >= params.branches;
            }
        }
        assert!(saw_grouped, "grouped_prob=0.5 over 30 DAGs must yield a grouped round");
        assert!(saw_fanout, "must yield an un-grouped fan-out round too");
    }
}
