//! [`AttentionPlan`] — the *report* half of the plan→execute contract.
//!
//! A plan is the materialized outcome of the HSR phase of Algorithm 1/2
//! for a batch of query rows: per row, the fired (or top-r-selected)
//! key indices in canonical ascending order, the activation weights the
//! HSR-carried scores were transformed into (exp or ReLU^α — already
//! *unnormalized*), the row's `1/normalizer`, the activated-set size
//! k̃_i, and the accumulated [`QueryStats`]. Executing a plan (see
//! [`crate::attention::session`]) is a pure bucketed gather over the
//! value matrix — no inner product is ever recomputed.
//!
//! Plans are reusable arenas: every buffer is cleared (capacity kept) by
//! the next `plan_into`, so steady-state planning performs no heap
//! allocation — the same discipline as [`Scratch`], which a plan embeds.

use crate::hsr::QueryStats;
use crate::kernel::Scratch;

/// The planned sparse evaluation for a batch of query rows.
///
/// Layout is CSR over the batch: row r's entries live at
/// `buf.idx[buf.row_ptr[r]..buf.row_ptr[r + 1]]` (ascending key order)
/// with parallel weights in `buf.w`; `buf.inv[r]` is the row's
/// `1/normalizer` (0.0 marks a degenerate all-zero row).
#[derive(Default)]
pub struct AttentionPlan {
    /// Working buffers: the CSR arrays plus per-row scratch. Crate-level
    /// visibility so the session executor and the transformer's per-head
    /// path can reuse it without re-exporting every internal vector.
    pub(crate) buf: Scratch,
    /// Activated entries per row — the k̃_i of Lemma 6.1.
    pub fired: Vec<usize>,
    /// HSR work counters accumulated while planning this batch.
    pub stats: QueryStats,
    /// Rows that fell back to a full half-space re-query (softmax top-r
    /// under-report, Theorem 4.2's exactness guard).
    pub fallbacks: usize,
}

impl AttentionPlan {
    pub fn new() -> AttentionPlan {
        AttentionPlan::default()
    }

    /// Number of planned query rows.
    pub fn rows(&self) -> usize {
        self.buf.row_ptr.len().saturating_sub(1)
    }

    /// Row r's selected key indices, ascending.
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.buf.idx[self.buf.row_ptr[r]..self.buf.row_ptr[r + 1]]
    }

    /// Row r's unnormalized activation weights, parallel to
    /// [`AttentionPlan::row_indices`]; multiply by
    /// [`AttentionPlan::row_inv`] for the convex-combination weights.
    pub fn row_weights(&self, r: usize) -> &[f32] {
        &self.buf.w[self.buf.row_ptr[r]..self.buf.row_ptr[r + 1]]
    }

    /// Row r's `1/normalizer` (0.0 for a degenerate all-zero row).
    pub fn row_inv(&self, r: usize) -> f32 {
        self.buf.inv[r]
    }

    /// Reset for a fresh batch, keeping every buffer's capacity.
    pub(crate) fn reset(&mut self) {
        self.buf.idx.clear();
        self.buf.w.clear();
        self.buf.row_ptr.clear();
        self.buf.row_ptr.push(0);
        self.buf.inv.clear();
        self.fired.clear();
        self.stats = QueryStats::default();
        self.fallbacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_has_no_rows() {
        let mut p = AttentionPlan::new();
        assert_eq!(p.rows(), 0);
        p.reset();
        assert_eq!(p.rows(), 0);
        assert_eq!(p.fired.len(), 0);
    }
}
