//! Hardware-efficient kernel layer.
//!
//! The paper's speedup story is "evaluate attention only on the
//! HSR-reported set" — which only pays off if the per-entry evaluation is
//! itself hardware-efficient (the lesson of the SparseAccelerate /
//! SampleAttention line of work). This module is that layer:
//!
//! * [`simd`] — runtime-dispatched 8-lane f32 micro-kernels (dot,
//!   blocked dense scoring, gathered subset scoring, axpy, fused
//!   max/sum-exp) with an AVX2+FMA path on x86_64 and a portable
//!   unrolled fallback. Dispatch is detected once and cached; scalar
//!   twins are exported for property tests and before/after benches.
//! * [`scratch`] — the reusable per-thread [`Scratch`] arena (fire /
//!   scores / selected / exp buffers) threaded through decode, prefill
//!   and serving so the per-row inner loops perform no heap allocation.
//!
//! Layering: `hsr`, `attention`, `engine` and `model` all call down into
//! this module; nothing here calls up. Every inner product in the crate
//! (HSR pruning tests, leaf scans, score gathers, value accumulations,
//! softmax rows) routes through these entry points, so a new ISA path
//! added here accelerates every layer at once.

pub mod scratch;
pub mod simd;

pub use scratch::Scratch;

/// Shared worker-count policy for every scoped-thread fan-out in the
/// crate (prefill rows, batched decode rows, the per-(layer, head)
/// serving sweep): `requested` = the caller's knob (0 → one worker per
/// available core, 1 → serial), `jobs` = parallel units on offer. Tiny
/// grids stay serial — they are not worth a thread spawn.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        requested
    };
    if jobs < 4 {
        1
    } else {
        t.clamp(1, jobs)
    }
}
