//! Deterministic pseudo-random number generation.
//!
//! Everything in this crate that needs randomness (workload generators,
//! property tests, benches) goes through [`Rng`], a SplitMix64/xoshiro256++
//! generator seeded explicitly, so every experiment is reproducible from its
//! seed. We deliberately do not depend on the `rand` crate: the vendored
//! dependency set is minimal and the paper's workloads only need uniform,
//! Gaussian and exponential draws.

/// A small, fast, deterministic RNG (xoshiro256++ seeded via SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free enough for test workloads.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal draw (Box-Muller with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal draw with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gaussian()
    }

    /// Exponential draw with the given rate (mean 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], sigma: f64) {
        for v in out.iter_mut() {
            *v = (self.gaussian() * sigma) as f32;
        }
    }

    /// A fresh vector of N(0, sigma^2) f32 samples.
    pub fn gaussian_vec_f32(&mut self, n: usize, sigma: f64) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.fill_gaussian_f32(&mut v, sigma);
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w as f64;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a child generator (stable: derived from the next output).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut r = Rng::new(13);
        let w = [0.0f32, 0.0, 10.0, 0.1];
        let mut counts = [0usize; 4];
        for _ in 0..1000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!(counts[2] > 900);
        assert_eq!(counts[0], 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
