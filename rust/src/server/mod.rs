//! TCP JSON-lines serving front-end.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0}
//!   ← {"id": 7, "text": "...", "latency_ms": 12.3, "ttft_ms": 4.5,
//!      "finish": "length", "prompt_len": 40}
//!
//! Connections are handled by a thread each; generation runs on the
//! router's engine workers (std::thread + mpsc — the vendored dependency
//! set has no tokio; see DESIGN.md).

pub mod protocol;

use crate::engine::{GenerationParams, Response, Router};
use crate::model::tokenizer::ByteTokenizer;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub use protocol::{parse_request, render_response, WireRequest};

/// Serving front-end over a [`Router`].
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(router: Arc<Router>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { router, listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (for ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle that makes `serve` return after the current accept.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop; one thread per connection. Blocks until stopped.
    pub fn serve(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut handles = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let router = self.router.clone();
                    handles.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, router);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let tokenizer = ByteTokenizer;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp_line = match parse_request(&line) {
            Ok(req) => {
                let prompt = tokenizer.encode(&req.prompt);
                let id = router.submit(
                    prompt,
                    GenerationParams {
                        max_new_tokens: req.max_new_tokens,
                        temperature: req.temperature,
                        stop_token: req.stop_token,
                    },
                );
                // Block until *this* request's response arrives.
                let resp = wait_for(&router, id);
                render_response(&resp, &tokenizer)
            }
            Err(e) => {
                format!("{{\"error\":{}}}", crate::util::json::Json::from(e.to_string()))
            }
        };
        writer.write_all(resp_line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

fn wait_for(router: &Router, id: crate::engine::RequestId) -> Response {
    loop {
        if let Some(r) = router.take_response_by_id(id) {
            return r;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Minimal blocking client for tests and examples.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and wait for the reply line.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
    ) -> Result<crate::util::json::Json> {
        let mut req = crate::util::json::Json::obj();
        req.set("prompt", prompt.into())
            .set("max_new_tokens", max_new_tokens.into());
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        crate::util::json::Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
    }
}
