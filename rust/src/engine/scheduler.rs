//! Scheduling policy for the continuous-batching engine: admission order,
//! per-step token budget, and preemption victim selection.

/// Preemption victim policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptPolicy {
    /// Evict the most recently admitted sequence (vLLM default: oldest
    /// requests finish first, recomputation cost is smallest for young
    /// sequences).
    Youngest,
    /// Evict the sequence holding the most cache (frees the most room).
    Largest,
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max sequences decoding concurrently.
    pub max_batch: usize,
    /// Max prompt tokens prefixed per sequence per step (chunked prefill).
    pub prefill_chunk: usize,
    /// Max total tokens (prefill + decode) processed per step.
    pub step_token_budget: usize,
    pub preempt: PreemptPolicy,
    /// Free blocks a shared-prefix publish must leave behind: admission
    /// and decode draw from the same pool as the prefix cache, so
    /// publishing is only allowed when it keeps at least this much
    /// immediate headroom (it never blocks serving — a publish that
    /// would eat the last pages is simply skipped; the prefix can be
    /// republished by a later sequence once pressure eases).
    pub prefix_headroom_blocks: usize,
    /// Bound on the engine's private waiting queue:
    /// `Engine::submit_request` rejects (returning the request) once
    /// this many sequences are queued. Defense in depth behind the
    /// router's admission control; the default is effectively unbounded
    /// so direct `Engine::submit` users keep the old semantics.
    pub max_waiting: usize,
    /// Max tokens one admission-path prefix lookup may *refault* —
    /// promote back from the compressed cold tier (decompress +
    /// re-reserve blocks + reattach HSR). Bounds the latency a single
    /// admission can spend on promotion; a matched chain is truncated
    /// at the first cold node past the budget and the rest stays cold
    /// for a later lookup. Effectively unbounded by default.
    pub refault_token_budget: usize,
    /// Cap on sibling sequences one request may fan out to (parallel
    /// sampling `n`/`best_of` and beam width are clamped to this at
    /// admission). Bounds how much of the pool and batch a single
    /// grouped request can claim.
    pub max_group_width: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            prefill_chunk: 64,
            step_token_budget: 256,
            preempt: PreemptPolicy::Youngest,
            prefix_headroom_blocks: 1,
            max_waiting: usize::MAX,
            refault_token_budget: 1 << 20,
            max_group_width: 16,
        }
    }
}

impl SchedulerConfig {
    /// Pick a preemption victim among eligible sequences, given
    /// (index, cached_tokens, priority) triples (the caller pre-filters
    /// to strictly-younger sequences). Returns the index.
    pub fn pick_victim(&self, seqs: &[(usize, usize, u64)]) -> Option<usize> {
        if seqs.is_empty() {
            return None;
        }
        let chosen = match self.preempt {
            PreemptPolicy::Youngest => seqs.iter().max_by_key(|&&(_, _, prio)| prio),
            PreemptPolicy::Largest => seqs.iter().max_by_key(|&&(_, cached, _)| cached),
        };
        chosen.map(|&(idx, _, _)| idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn youngest_picks_latest_admission() {
        let cfg = SchedulerConfig { preempt: PreemptPolicy::Youngest, ..Default::default() };
        let seqs = vec![(0, 100, 5), (1, 900, 2), (2, 50, 9)];
        assert_eq!(cfg.pick_victim(&seqs), Some(2));
    }

    #[test]
    fn largest_picks_biggest_cache() {
        let cfg = SchedulerConfig { preempt: PreemptPolicy::Largest, ..Default::default() };
        let seqs = vec![(0, 100, 5), (1, 900, 2), (2, 50, 9)];
        assert_eq!(cfg.pick_victim(&seqs), Some(1));
    }

    #[test]
    fn empty_has_no_victim() {
        let cfg = SchedulerConfig::default();
        assert_eq!(cfg.pick_victim(&[]), None);
    }
}
