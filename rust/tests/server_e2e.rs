//! TCP server end-to-end: bind an ephemeral port, serve generation
//! requests over JSON lines, check responses and concurrent clients.

use hsr_attn::engine::{EngineConfig, Router};
use hsr_attn::model::Model;
use hsr_attn::server::{Client, Server};
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn serve_and_generate_over_tcp() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let model = Arc::new(Model::load_named(&artifacts_dir(), "mini").unwrap());
    let router = Arc::new(Router::new(model, EngineConfig::default(), 2));
    let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.serve());

    // Two sequential requests over one connection.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let r1 = client.generate("the merchant carries ", 12).unwrap();
    assert_eq!(r1.req_usize("prompt_len").unwrap(), 21);
    assert_eq!(r1.req_str("finish").unwrap(), "length");
    let text = r1.req_str("text").unwrap();
    assert_eq!(text.len(), 12);
    let r2 = client.generate("a courier guards ", 8).unwrap();
    assert_eq!(r2.req_str("text").unwrap().len(), 8);

    // Concurrent clients.
    let mut joins = Vec::new();
    for i in 0..4 {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let r = c.generate(&format!("concurrent client {i} says "), 6).unwrap();
            r.req_str("text").unwrap().len()
        }));
    }
    for j in joins {
        assert_eq!(j.join().unwrap(), 6);
    }

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    drop(client);
    handle.join().unwrap().unwrap();
}

#[test]
fn malformed_request_gets_error_line() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let model = Arc::new(Model::load_named(&artifacts_dir(), "mini").unwrap());
    let router = Arc::new(Router::new(model, EngineConfig::default(), 1));
    let server = Server::bind(router, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.serve());

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(b"this is not json\n").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got: {line}");

    // The connection stays usable afterwards.
    w.write_all(br#"{"prompt":"ok ","max_new_tokens":4}"#).unwrap();
    w.write_all(b"\n").unwrap();
    w.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("text"), "got: {line}");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    // Close *both* duplicated fds so the connection thread sees EOF.
    drop(w);
    drop(reader);
    handle.join().unwrap().unwrap();
}
