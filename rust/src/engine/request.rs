//! Request/response types of the serving engine.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// Sampling / generation parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenerationParams {
    pub max_new_tokens: usize,
    /// 0.0 → greedy.
    pub temperature: f32,
    /// Stop at this token if produced (byte value); None → length only.
    pub stop_token: Option<u32>,
}

impl Default for GenerationParams {
    fn default() -> Self {
        GenerationParams { max_new_tokens: 64, temperature: 0.0, stop_token: None }
    }
}

/// An inbound generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    StopToken,
    /// Engine shut down before completion.
    Aborted,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Wall time from submission to completion.
    pub latency_ms: f64,
    /// Time to first generated token.
    pub ttft_ms: f64,
    pub prompt_len: usize,
}

/// Engine-internal sequence state.
pub(crate) struct Sequence {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    pub generated: Vec<u32>,
    pub kv: crate::model::kv::KvState,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    /// Blocks held in the cache pool.
    pub blocks: Vec<u32>,
    /// Number of prompt tokens already prefilled (chunked prefill cursor).
    pub prefilled: usize,
    /// Submission order; lower = older. Preemption only ever evicts
    /// strictly-younger sequences, which guarantees scheduler progress.
    pub priority: u64,
}

impl Sequence {
    /// Total tokens this sequence holds in cache.
    pub fn cached_tokens(&self) -> usize {
        self.kv.len()
    }

    /// Next token to feed: prompt remainder, else last generated.
    pub fn done(&self) -> bool {
        self.generated.len() >= self.params.max_new_tokens
    }
}
