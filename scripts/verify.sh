#!/usr/bin/env bash
# Repo verification: format, lint, build, docs, tests, and perf smokes.
#
# Usage: scripts/verify.sh [--no-bench]
#
# Bench steps (machine-readable perf trajectory across PRs):
#  * benches/hsr_structures.rs --kernels-only → BENCH_kernels.json
#    (before/after ns-per-row for dot, scores_into, softmax row, prefill)
#  * benches/decode_time.rs --batched-only    → BENCH_decode.json
#    (ns per decoded token at batch 1/8/32, serial vs batched, per
#    HSR backend — the continuous-batch decode engine's headline)
#  * benches/decode_time.rs --hsr-batch-only  → BENCH_hsr_batch.json
#    (multi-query shared-traversal HSR: per-backend ns/query and
#    work/query, batched vs looped, fan-out 1/4/16)
#  * benches/e2e_serving.rs --shared-only     → BENCH_serving.json
#    (shared-prompt workload: prefix-hit rate, prefill tokens skipped,
#    steady-state tok/s shared vs unshared; runs on a synthetic model
#    when artifacts are absent, so it always reports)
#  * benches/e2e_serving.rs --streaming-only  → BENCH_serving.json
#    ("streaming_affinity" key: wire TTFT p50, prefix-hit rate, and
#    affinity hit/fallback counters for a shared-prompt streaming
#    cohort over TCP, affinity on vs off; synthetic model)
#  * benches/e2e_serving.rs --overload-only   → BENCH_robustness.json
#    (admission control at 4x the sustainable rate: shed rate and the
#    p50/p99 latency of the accepted requests; synthetic model)
#  * benches/e2e_serving.rs --tiered-only     → BENCH_kv_tiers.json
#    (tiered KV: working set 2-4x the hot cap driven twice — phase-2
#    prefill skip with refault vs re-prefill — plus a 32-tenant
#    identical-doc dedup sweep, physical vs logical segment bytes;
#    synthetic model)
#  * benches/e2e_serving.rs --scenarios-only  → BENCH_scenarios.json
#    (fork/join decode scenarios: parallel sampling n=1/4/16 and
#    width-4 beam search on COW-forked chains — peak physical vs
#    logical KV bytes, prefill-skip %, steady tok/s; synthetic model)
#  * benches/e2e_serving.rs --obs-only        → BENCH_obs.json
#    (observability: flight-recorder on-vs-off steady tok/s against
#    the 3% overhead budget, empirical fired-fraction per context
#    length vs the n^{-1/5} envelope, and a live double {"cmd":"stats"}
#    scrape over TCP — required snapshot keys and counter monotonicity
#    are asserted inside the bench, so a bad export surface fails this
#    script; synthetic model)

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --release -q -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --release -q -- -D warnings
else
    echo "clippy not installed in this toolchain — skipping"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo doc --no-deps -q =="
cargo doc --no-deps -q

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== kernel perf smoke (BENCH_kernels.json) =="
    cargo bench --bench hsr_structures -- --kernels-only
    echo "report: $(cd .. && pwd)/BENCH_kernels.json"

    echo "== batched decode smoke (BENCH_decode.json) =="
    cargo bench --bench decode_time -- --batched-only
    echo "report: $(cd .. && pwd)/BENCH_decode.json"

    echo "== multi-query HSR smoke (BENCH_hsr_batch.json) =="
    cargo bench --bench decode_time -- --hsr-batch-only
    echo "report: $(cd .. && pwd)/BENCH_hsr_batch.json"

    echo "== shared-prefix serving smoke (BENCH_serving.json) =="
    cargo bench --bench e2e_serving -- --shared-only
    echo "report: $(cd .. && pwd)/BENCH_serving.json"

    echo "== streaming + affinity smoke (BENCH_serving.json: streaming_affinity) =="
    cargo bench --bench e2e_serving -- --streaming-only
    echo "report: $(cd .. && pwd)/BENCH_serving.json"

    echo "== overload admission-control smoke (BENCH_robustness.json) =="
    cargo bench --bench e2e_serving -- --overload-only
    echo "report: $(cd .. && pwd)/BENCH_robustness.json"

    echo "== tiered KV spill/dedup smoke (BENCH_kv_tiers.json) =="
    cargo bench --bench e2e_serving -- --tiered-only
    echo "report: $(cd .. && pwd)/BENCH_kv_tiers.json"

    echo "== fork/join scenarios smoke (BENCH_scenarios.json) =="
    cargo bench --bench e2e_serving -- --scenarios-only
    echo "report: $(cd .. && pwd)/BENCH_scenarios.json"

    echo "== observability smoke: tracing overhead + live stats scrapes (BENCH_obs.json) =="
    cargo bench --bench e2e_serving -- --obs-only
    echo "report: $(cd .. && pwd)/BENCH_obs.json"

    echo "== serving throughput smoke (skips without artifacts) =="
    cargo bench --bench e2e_serving
fi

echo "verify: OK"
