//! Request router: shards requests across engine worker threads
//! (vllm-project/router-shaped, scaled to this testbed). Each worker owns
//! one [`Engine`] replica; the router picks the least-loaded worker,
//! tracks in-flight counts, and merges metrics/responses.

use super::request::{GenerationParams, RequestId, Response};
use super::serving::{Engine, EngineConfig};
use crate::model::Model;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum WorkerMsg {
    Submit { prompt: Vec<u32>, params: GenerationParams, reply_id: Sender<RequestId> },
    Shutdown,
}

struct Worker {
    tx: Sender<WorkerMsg>,
    handle: Option<JoinHandle<super::metrics::Metrics>>,
    in_flight: Arc<AtomicUsize>,
}

/// Multi-worker router.
pub struct Router {
    workers: Vec<Worker>,
    responses: Arc<Mutex<Vec<Response>>>,
    completed: Arc<AtomicUsize>,
    submitted: AtomicUsize,
    stopping: Arc<AtomicBool>,
}

impl Router {
    /// Spawn `n_workers` engines over a shared model.
    pub fn new(model: Arc<Model>, cfg: EngineConfig, n_workers: usize) -> Router {
        assert!(n_workers >= 1);
        let responses: Arc<Mutex<Vec<Response>>> = Arc::default();
        let completed = Arc::new(AtomicUsize::new(0));
        let stopping = Arc::new(AtomicBool::new(false));
        let workers = (0..n_workers)
            .map(|w| {
                let (tx, rx) = channel::<WorkerMsg>();
                let in_flight = Arc::new(AtomicUsize::new(0));
                let handle = std::thread::Builder::new()
                    .name(format!("engine-{w}"))
                    .spawn({
                        let model = model.clone();
                        let mut wcfg = cfg;
                        wcfg.seed = cfg.seed.wrapping_add(w as u64);
                        wcfg.id_offset = (w as u64) << 40;
                        let responses = responses.clone();
                        let completed = completed.clone();
                        let in_flight = in_flight.clone();
                        let stopping = stopping.clone();
                        move || {
                            worker_loop(model, wcfg, rx, responses, completed, in_flight, stopping)
                        }
                    })
                    .expect("spawn engine worker");
                Worker { tx, handle: Some(handle), in_flight }
            })
            .collect();
        Router {
            workers,
            responses,
            completed,
            submitted: AtomicUsize::new(0),
            stopping,
        }
    }

    /// Submit to the least-loaded worker; blocks only for id assignment.
    pub fn submit(&self, prompt: Vec<u32>, params: GenerationParams) -> RequestId {
        let widx = self
            .workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| w.in_flight.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap();
        let w = &self.workers[widx];
        w.in_flight.fetch_add(1, Ordering::Relaxed);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        w.tx
            .send(WorkerMsg::Submit { prompt, params, reply_id: reply_tx })
            .expect("worker alive");
        // Ids are globally unique: each engine numbers from widx << 40.
        reply_rx.recv().expect("worker replies")
    }

    /// Completed / submitted counts.
    pub fn progress(&self) -> (usize, usize) {
        (
            self.completed.load(Ordering::Relaxed),
            self.submitted.load(Ordering::Relaxed),
        )
    }

    /// Drain all responses accumulated so far.
    pub fn take_responses(&self) -> Vec<Response> {
        std::mem::take(&mut *self.responses.lock().unwrap())
    }

    /// Remove and return the response with the given id, if present.
    pub fn take_response_by_id(&self, id: RequestId) -> Option<Response> {
        let mut guard = self.responses.lock().unwrap();
        let pos = guard.iter().position(|r| r.id == id)?;
        Some(guard.swap_remove(pos))
    }

    /// Block until every submitted request completes.
    pub fn wait_idle(&self) {
        loop {
            let (done, sub) = self.progress();
            if done >= sub {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    /// Stop workers and merge their metrics.
    pub fn shutdown(mut self) -> super::metrics::Metrics {
        self.stopping.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        let mut merged = super::metrics::Metrics::default();
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                if let Ok(m) = h.join() {
                    merged.merge(&m);
                }
            }
        }
        merged
    }
}

fn worker_loop(
    model: Arc<Model>,
    cfg: EngineConfig,
    rx: Receiver<WorkerMsg>,
    responses: Arc<Mutex<Vec<Response>>>,
    completed: Arc<AtomicUsize>,
    in_flight: Arc<AtomicUsize>,
    stopping: Arc<AtomicBool>,
) -> super::metrics::Metrics {
    let mut engine = Engine::new(model, cfg);
    let mut shutdown = false;
    loop {
        // Drain the inbox (non-blocking while busy; blocking when idle).
        loop {
            let msg = if engine.has_work() || shutdown {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match msg {
                WorkerMsg::Submit { prompt, params, reply_id } => {
                    let id = engine.submit(prompt, params);
                    let _ = reply_id.send(id);
                }
                WorkerMsg::Shutdown => shutdown = true,
            }
        }
        if engine.has_work() {
            engine.step();
            let done = engine.take_finished();
            if !done.is_empty() {
                completed.fetch_add(done.len(), Ordering::Relaxed);
                in_flight.fetch_sub(done.len(), Ordering::Relaxed);
                responses.lock().unwrap().extend(done);
            }
        } else if shutdown || stopping.load(Ordering::Relaxed) {
            break;
        }
    }
    engine.metrics.clone()
}
