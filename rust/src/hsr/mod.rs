//! Half-Space Reporting (HSR) data structures — the paper's core substrate.
//!
//! The half-space range reporting problem (Definition B.10 of the paper,
//! after Agarwal–Eppstein–Matoušek [AEM92]): preprocess a set S of n points
//! in R^d so that, given a query half-space H = {x : <a, x> >= b}, all
//! points of S ∩ H are reported quickly. The paper's Algorithm 3 interface:
//!
//! ```text
//! INIT(S, n, d)     — build over the key vectors
//! QUERY(a, b)       — report {x in S : sgn(<a,x> - b) >= 0}
//! ```
//!
//! The paper only *cites* the AEM92 asymptotics (Corollary 3.1) and notes
//! (Appendix A) that no implementation of the original structure exists.
//! This module provides working structures spanning the same design space:
//!
//! * [`brute::BruteHsr`] — the naive O(n) scan, the comparator every
//!   theorem's "naive O(mn)" baseline refers to.
//! * [`balltree::BallTreeHsr`] — Part-1 analogue: O(n log n) build,
//!   output-sensitive queries via ball pruning and whole-subtree reporting.
//! * [`layers2d::ConvexLayers2d`] — Part-2 analogue, exact for d = 2:
//!   O(n log n) build, O((1 + k_layers) log n + k) query via convex-layer
//!   peeling — genuinely O(log n + k)-shaped where it is computable.
//! * [`dynamic::DynamicHsr`] — the logarithmic method over any static
//!   backend, giving amortized-logarithmic inserts (Theorem B.11's update
//!   clause); this is what the decode engine uses as keys are appended.
//!
//! All queries are **exact** (no approximate nearest-neighbour relaxation —
//! the paper contrasts itself with [FA23] on precisely this point).

pub mod balltree;
pub mod brute;
pub mod dynamic;
pub mod layers2d;
pub mod projected;

use crate::util::rng::Rng;

/// Instrumentation counters filled in by `query_into`, used by tests and
/// benches to verify output-sensitivity (e.g. that a ball-tree query
/// touches o(n) points on the paper's Gaussian workloads).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Internal nodes / layers visited.
    pub nodes_visited: usize,
    /// Points whose inner product was explicitly evaluated.
    pub points_scanned: usize,
    /// Points reported without evaluation (whole-subtree reports).
    pub bulk_reported: usize,
    /// Total points reported.
    pub reported: usize,
}

impl QueryStats {
    /// Total work proxy: evaluated points + visited nodes.
    pub fn work(&self) -> usize {
        self.nodes_visited + self.points_scanned
    }

    pub fn add(&mut self, other: &QueryStats) {
        self.nodes_visited += other.nodes_visited;
        self.points_scanned += other.points_scanned;
        self.bulk_reported += other.bulk_reported;
        self.reported += other.reported;
    }
}

/// The HSR interface (paper Algorithm 3). Implementations are immutable
/// after construction; dynamic insertion is layered on via
/// [`dynamic::DynamicHsr`].
pub trait HalfSpaceReport: Send + Sync {
    /// Number of indexed points.
    fn len(&self) -> usize;

    /// True if no points are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point dimensionality d.
    fn dim(&self) -> usize;

    /// Report every index i with `<a, x_i> >= b`, appending to `out`
    /// (order unspecified). `stats` accumulates work counters.
    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats);

    /// Score-carrying report: append every qualifying index to `out` AND
    /// its raw inner product `<a, x_i>` to `scores` (parallel vectors,
    /// order unspecified). Downstream consumers (softmax top-r, ReLU
    /// evaluation) already need these inner products — reporting them
    /// here means the dot the query paid for is never recomputed.
    ///
    /// Work counters keep [`HalfSpaceReport::query_into`] semantics:
    /// `points_scanned` counts points evaluated *to decide membership*;
    /// scoring a bulk-reported subtree is attention-side work and is not
    /// counted as a scan.
    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    );

    /// Batched multi-query score-carrying report: answer `bs.len()`
    /// half-space queries against the same structure in one call.
    /// `queries` is row-major `[q, d]`, `bs[i]` is query i's raw-score
    /// threshold, and `outs[i]` / `scores[i]` receive query i's report
    /// (appended, parallel vectors, in the same order
    /// [`HalfSpaceReport::query_scored_into`] would produce).
    ///
    /// # `QueryStats` counting rules under a shared traversal
    ///
    /// Per-point counters are **per (query, point)** exactly as in the
    /// single-query entry point: `points_scanned`, `bulk_reported` and
    /// `reported` accumulate once per query that scans / bulk-reports /
    /// reports a point, so their totals always equal the totals of a
    /// per-query loop. `nodes_visited`, by contrast, is **per structure
    /// node the batch touches**: a tree node pruned against (or descended
    /// for) the whole query block costs one visit regardless of fan-out.
    /// A native shared-traversal override therefore shows strictly lower
    /// [`QueryStats::work`] per query than the looped default whenever
    /// fan-out > 1 and the traversal visits at least one node — this is
    /// the cross-sequence amortization the decode engine's multi-row
    /// plans rely on. The default implementation below is a plain loop
    /// and keeps fully per-query counting.
    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        let d = self.dim();
        let q = bs.len();
        assert_eq!(queries.len(), q * d);
        assert_eq!(outs.len(), q);
        assert_eq!(scores.len(), q);
        for i in 0..q {
            self.query_scored_into(
                &queries[i * d..(i + 1) * d],
                bs[i],
                &mut outs[i],
                &mut scores[i],
                stats,
            );
        }
    }

    /// Convenience wrapper returning a fresh, sorted index vector.
    fn query(&self, a: &[f32], b: f32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        self.query_into(a, b, &mut out, &mut stats);
        out.sort_unstable();
        out
    }

    /// Convenience wrapper returning (index, raw-dot) pairs sorted by
    /// index (tests / diagnostics; hot paths use `query_scored_into`).
    fn query_scored(&self, a: &[f32], b: f32) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        let mut scores = Vec::new();
        let mut stats = QueryStats::default();
        self.query_scored_into(a, b, &mut out, &mut scores, &mut stats);
        let mut pairs: Vec<(u32, f32)> = out.into_iter().zip(scores).collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs
    }
}

/// Which static HSR backend to use. The engine and every bench take this
/// as a config knob so backends can be ablated against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HsrBackend {
    /// Naive linear scan (the paper's O(mn) baseline).
    Brute,
    /// Ball-tree partition structure (Part-1 analogue, any d).
    BallTree,
    /// Convex-layers halfplane reporting (Part-2 analogue, d = 2 only).
    Layers2d,
    /// Projection-augmented ball tree (exact; prunes on anisotropic keys).
    Projected,
}

impl HsrBackend {
    /// Every canonical backend name, in CLI-help order.
    pub const NAMES: [&'static str; 4] = ["brute", "balltree", "layers2d", "projected"];

    /// Parse a backend name (case-insensitive, with aliases). The error
    /// message lists the valid names so CLI callers can surface it
    /// verbatim (`util::cli::Args::parse_or_exit` does exactly that).
    pub fn parse(s: &str) -> Result<HsrBackend, String> {
        match s.to_ascii_lowercase().as_str() {
            "brute" | "naive" => Ok(HsrBackend::Brute),
            "balltree" | "ball" | "tree" => Ok(HsrBackend::BallTree),
            "layers2d" | "layers" | "convex" => Ok(HsrBackend::Layers2d),
            "projected" | "proj" | "pca" => Ok(HsrBackend::Projected),
            other => Err(format!(
                "unknown HSR backend '{other}'; valid backends: {} \
                 (aliases: naive, ball, tree, layers, convex, proj, pca)",
                HsrBackend::NAMES.join("|")
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HsrBackend::Brute => "brute",
            HsrBackend::BallTree => "balltree",
            HsrBackend::Layers2d => "layers2d",
            HsrBackend::Projected => "projected",
        }
    }
}

/// Build a static HSR structure over `n` points stored row-major in
/// `points` (length n*d). Panics if `Layers2d` is requested with d != 2.
pub fn build_hsr(
    backend: HsrBackend,
    points: &[f32],
    d: usize,
) -> Box<dyn HalfSpaceReport> {
    match backend {
        HsrBackend::Brute => Box::new(brute::BruteHsr::build(points, d)),
        HsrBackend::BallTree => Box::new(balltree::BallTreeHsr::build(points, d)),
        HsrBackend::Layers2d => {
            assert_eq!(d, 2, "ConvexLayers2d requires d = 2 (got d = {d})");
            Box::new(layers2d::ConvexLayers2d::build(points))
        }
        HsrBackend::Projected => {
            // Default projection rank: enough for trained-key anisotropy.
            Box::new(projected::ProjectedHsr::build(points, d, 6.min(d)))
        }
    }
}

/// Inner product of two equal-length slices. Thin alias for the
/// runtime-dispatched SIMD kernel (kept here because every HSR backend
/// and half the crate imports `hsr::dot`).
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::kernel::simd::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Generate `n` Gaussian points N(0, sigma^2)^d, row-major — the workload
/// of Lemma 6.1. Shared helper for tests and benches.
pub fn gaussian_points(rng: &mut Rng, n: usize, d: usize, sigma: f64) -> Vec<f32> {
    rng.gaussian_vec_f32(n * d, sigma)
}

/// Reference implementation used to cross-check every backend in tests:
/// a straight scan over the raw points.
pub fn reference_query(points: &[f32], d: usize, a: &[f32], b: f32) -> Vec<u32> {
    let n = points.len() / d;
    let mut out = Vec::new();
    for i in 0..n {
        if dot(&points[i * d..(i + 1) * d], a) >= b {
            out.push(i as u32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(1);
        for len in [0usize, 1, 3, 4, 7, 16, 65] {
            let a = r.gaussian_vec_f32(len, 1.0);
            let b = r.gaussian_vec_f32(len, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn backend_parse() {
        assert_eq!(HsrBackend::parse("balltree"), Ok(HsrBackend::BallTree));
        assert_eq!(HsrBackend::parse("BRUTE"), Ok(HsrBackend::Brute));
        assert_eq!(HsrBackend::parse("convex"), Ok(HsrBackend::Layers2d));
        assert_eq!(HsrBackend::parse("projected"), Ok(HsrBackend::Projected));
        assert_eq!(HsrBackend::parse("proj"), Ok(HsrBackend::Projected));
        assert_eq!(HsrBackend::parse("PCA"), Ok(HsrBackend::Projected));
        let err = HsrBackend::parse("??").unwrap_err();
        for name in HsrBackend::NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        assert!(err.contains("??"));
    }

    /// Property test: every backend agrees with the reference scan on
    /// random Gaussian instances across dimensions and thresholds.
    #[test]
    fn backends_match_reference() {
        let mut rng = Rng::new(42);
        for trial in 0..30 {
            let d = [2usize, 3, 8, 16][trial % 4];
            let n = rng.range(1, 400);
            let points = gaussian_points(&mut rng, n, d, 1.0);
            let mut backends: Vec<Box<dyn HalfSpaceReport>> = vec![
                build_hsr(HsrBackend::Brute, &points, d),
                build_hsr(HsrBackend::BallTree, &points, d),
                build_hsr(HsrBackend::Projected, &points, d),
            ];
            if d == 2 {
                backends.push(build_hsr(HsrBackend::Layers2d, &points, d));
            }
            for _ in 0..5 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.5) as f32;
                let expect = reference_query(&points, d, &a, b);
                for be in &backends {
                    let got = be.query(&a, b);
                    assert_eq!(got, expect, "n={n} d={d} b={b}");
                }
            }
        }
    }

    /// Score-carrying queries report exactly the `query_into` set, with
    /// each score equal to the raw inner product — on every backend.
    #[test]
    fn scored_queries_match_plain_plus_dots() {
        let mut rng = Rng::new(43);
        for trial in 0..20 {
            let d = [2usize, 5, 8, 16][trial % 4];
            let n = rng.range(1, 500);
            let points = gaussian_points(&mut rng, n, d, 1.0);
            let mut backends: Vec<Box<dyn HalfSpaceReport>> = vec![
                build_hsr(HsrBackend::Brute, &points, d),
                build_hsr(HsrBackend::BallTree, &points, d),
                build_hsr(HsrBackend::Projected, &points, d),
            ];
            if d == 2 {
                backends.push(build_hsr(HsrBackend::Layers2d, &points, d));
            }
            for _ in 0..4 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.0) as f32;
                let expect_idx = reference_query(&points, d, &a, b);
                for be in &backends {
                    let pairs = be.query_scored(&a, b);
                    let idx: Vec<u32> = pairs.iter().map(|&(i, _)| i).collect();
                    assert_eq!(idx, expect_idx, "n={n} d={d}");
                    for &(i, s) in &pairs {
                        let want = dot(&points[i as usize * d..(i as usize + 1) * d], &a);
                        assert!(
                            (s - want).abs() < 1e-4 * (1.0 + want.abs()),
                            "n={n} d={d} i={i}: {s} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// The looped reference for the batched entry point: per-query calls
    /// into `query_scored_into`, exactly what the default impl does.
    fn looped_many(
        hsr: &dyn HalfSpaceReport,
        queries: &[f32],
        bs: &[f32],
    ) -> (Vec<Vec<u32>>, Vec<Vec<f32>>, QueryStats) {
        let d = hsr.dim();
        let q = bs.len();
        let mut outs = vec![Vec::new(); q];
        let mut scores = vec![Vec::new(); q];
        let mut stats = QueryStats::default();
        for i in 0..q {
            hsr.query_scored_into(
                &queries[i * d..(i + 1) * d],
                bs[i],
                &mut outs[i],
                &mut scores[i],
                &mut stats,
            );
        }
        (outs, scores, stats)
    }

    /// Property test: `query_many_scored_into` is **element-identical**
    /// (indices, order, and raw f32 scores) to the per-query loop on all
    /// five backends — including a `DynamicHsr` grown by inserts — and
    /// its per-point counters match while `nodes_visited` never exceeds
    /// the looped total (the shared-traversal counting rule).
    #[test]
    fn query_many_matches_looped_all_backends() {
        let mut rng = Rng::new(77);
        for trial in 0..12 {
            let d = [2usize, 4, 8, 16][trial % 4];
            let n = rng.range(2, 600);
            let points = gaussian_points(&mut rng, n, d, 1.0);
            let mut backends: Vec<Box<dyn HalfSpaceReport>> = vec![
                build_hsr(HsrBackend::Brute, &points, d),
                build_hsr(HsrBackend::BallTree, &points, d),
                build_hsr(HsrBackend::Projected, &points, d),
            ];
            if d == 2 {
                backends.push(build_hsr(HsrBackend::Layers2d, &points, d));
            }
            // Fifth backend: the dynamic wrapper, half batch-built and
            // half grown by inserts so tail + multiple buckets are live.
            let split = n / 2;
            let mut dyn_hsr = dynamic::DynamicHsr::from_points(
                HsrBackend::BallTree,
                &points[..split * d],
                d,
            );
            for j in split..n {
                dyn_hsr.insert(&points[j * d..(j + 1) * d]);
            }
            backends.push(Box::new(dyn_hsr));
            for fan in [1usize, 3, 8, 13] {
                let queries = rng.gaussian_vec_f32(fan * d, 1.0);
                let bs: Vec<f32> =
                    (0..fan).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                for be in &backends {
                    let (want_out, want_scores, want_stats) =
                        looped_many(be.as_ref(), &queries, &bs);
                    let mut outs = vec![Vec::new(); fan];
                    let mut scores = vec![Vec::new(); fan];
                    let mut stats = QueryStats::default();
                    be.query_many_scored_into(
                        &queries, &bs, &mut outs, &mut scores, &mut stats,
                    );
                    assert_eq!(outs, want_out, "n={n} d={d} fan={fan}");
                    assert_eq!(scores, want_scores, "n={n} d={d} fan={fan}");
                    assert_eq!(stats.points_scanned, want_stats.points_scanned);
                    assert_eq!(stats.bulk_reported, want_stats.bulk_reported);
                    assert_eq!(stats.reported, want_stats.reported);
                    assert!(
                        stats.nodes_visited <= want_stats.nodes_visited,
                        "n={n} d={d} fan={fan}: {} > {}",
                        stats.nodes_visited,
                        want_stats.nodes_visited
                    );
                }
            }
        }
    }

    /// Acceptance: at fan-out ≥ 4 on the Lemma 6.1 Gaussian workload the
    /// shared traversal does strictly less `work()` per query than the
    /// looped default on every tree-shaped backend (BallTree, Projected,
    /// Dynamic) — the cross-sequence amortization the session plans use.
    #[test]
    fn batched_queries_amortize_work_on_gaussian_workload() {
        let mut rng = Rng::new(78);
        let (n, d) = (8192usize, 8usize);
        let points = gaussian_points(&mut rng, n, d, 1.0);
        let grown = n - 500;
        let mut dyn_hsr =
            dynamic::DynamicHsr::from_points(HsrBackend::BallTree, &points[..grown * d], d);
        for j in grown..n {
            dyn_hsr.insert(&points[j * d..(j + 1) * d]);
        }
        let backends: Vec<(&str, Box<dyn HalfSpaceReport>)> = vec![
            ("balltree", build_hsr(HsrBackend::BallTree, &points, d)),
            ("projected", build_hsr(HsrBackend::Projected, &points, d)),
            ("dynamic", Box::new(dyn_hsr)),
        ];
        // Practical Lemma 6.1 bias on the scaled score, raw-score units.
        let b_raw = ((0.4 * (n as f64).ln()).sqrt() * (d as f64).sqrt()) as f32;
        for fan in [4usize, 16] {
            let queries = rng.gaussian_vec_f32(fan * d, 1.0);
            let bs = vec![b_raw; fan];
            for (name, be) in &backends {
                let (_, _, looped) = looped_many(be.as_ref(), &queries, &bs);
                let mut outs = vec![Vec::new(); fan];
                let mut scores = vec![Vec::new(); fan];
                let mut batched = QueryStats::default();
                be.query_many_scored_into(&queries, &bs, &mut outs, &mut scores, &mut batched);
                assert!(
                    batched.work() < looped.work(),
                    "{name} fan={fan}: batched work {} !< looped {}",
                    batched.work(),
                    looped.work()
                );
            }
        }
    }

    #[test]
    fn empty_input() {
        let points: Vec<f32> = vec![];
        for be in [HsrBackend::Brute, HsrBackend::BallTree] {
            let h = build_hsr(be, &points, 4);
            assert!(h.is_empty());
            assert!(h.query(&[1.0, 0.0, 0.0, 0.0], 0.0).is_empty());
        }
    }

    #[test]
    #[should_panic]
    fn layers2d_requires_d2() {
        let points = vec![0.0f32; 12];
        let _ = build_hsr(HsrBackend::Layers2d, &points, 3);
    }
}
