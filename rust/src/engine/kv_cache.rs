//! Paged KV-cache accounting: a block allocator in the vLLM style.
//!
//! Sequences allocate fixed-size token blocks as they grow; admission and
//! preemption decisions are driven by pool pressure. The float payload
//! itself lives in each sequence's [`crate::model::kv::KvState`] (the HSR
//! index needs contiguous per-head key rows); this allocator is the
//! capacity authority — a sequence may only hold tokens it has blocks
//! for, which tests enforce.

/// Fixed-size block allocator over an abstract pool of token slots.
#[derive(Debug)]
pub struct BlockAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    total_blocks: usize,
    /// Debug-build ledger: `allocated[b]` iff block `b` is currently
    /// held by some owner. Catches double frees, frees of never-issued
    /// ids, and (via [`BlockAllocator::debug_assert_all_free`]) leaks.
    /// Absent in release builds — zero cost on the serving hot path.
    #[cfg(debug_assertions)]
    allocated: Vec<bool>,
}

impl BlockAllocator {
    /// Pool sized for `capacity_tokens` tokens in `block_tokens`-sized
    /// blocks.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> BlockAllocator {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        BlockAllocator {
            block_tokens,
            free: (0..total_blocks as u32).rev().collect(),
            total_blocks,
            #[cfg(debug_assertions)]
            allocated: vec![false; total_blocks],
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Tokens currently allocatable without eviction.
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate `count` blocks; None if the pool cannot satisfy it.
    pub fn alloc(&mut self, count: usize) -> Option<Vec<u32>> {
        if self.free.len() < count {
            return None;
        }
        let out = self.free.split_off(self.free.len() - count);
        #[cfg(debug_assertions)]
        for &b in &out {
            debug_assert!(
                !self.allocated[b as usize],
                "block {b} handed out while already allocated"
            );
            self.allocated[b as usize] = true;
        }
        Some(out)
    }

    /// Grow a sequence's holding from `held` blocks to cover
    /// `needed_tokens`; appends new blocks to `blocks`.
    pub fn ensure(&mut self, blocks: &mut Vec<u32>, needed_tokens: usize) -> bool {
        let need = self.blocks_for(needed_tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc(need - blocks.len()) {
            Some(mut extra) => {
                blocks.append(&mut extra);
                true
            }
            None => false,
        }
    }

    /// Return blocks to the pool.
    ///
    /// Debug builds assert each id is in range and currently allocated:
    /// a block freed twice (or never issued) would silently get handed
    /// to two owners on the next `alloc`, corrupting capacity
    /// accounting — exactly the failure mode the ledger exists to catch.
    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        #[cfg(debug_assertions)]
        for &b in blocks.iter() {
            debug_assert!(
                (b as usize) < self.total_blocks,
                "released block {b} out of range (total {})",
                self.total_blocks
            );
            debug_assert!(
                self.allocated[b as usize],
                "double free of block {b}"
            );
            self.allocated[b as usize] = false;
        }
        self.free.append(blocks);
        debug_assert!(self.free.len() <= self.total_blocks);
    }

    /// Debug helper: assert every block has been returned (no leaks).
    /// Compiles to nothing in release builds.
    pub fn debug_assert_all_free(&self) {
        debug_assert!(
            self.free.len() == self.total_blocks,
            "leaked {} of {} blocks",
            self.total_blocks - self.free.len(),
            self.total_blocks
        );
        #[cfg(debug_assertions)]
        debug_assert!(
            self.allocated.iter().all(|&a| !a),
            "leaked blocks still marked allocated"
        );
    }

    /// Pool utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        1.0 - self.free.len() as f64 / self.total_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(1024, 16);
        assert_eq!(a.total_blocks(), 64);
        let mut b1 = a.alloc(10).unwrap();
        assert_eq!(a.free_blocks(), 54);
        a.release(&mut b1);
        assert_eq!(a.free_blocks(), 64);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = BlockAllocator::new(64, 16);
        assert!(a.alloc(4).is_some());
        assert!(a.alloc(1).is_none());
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut a = BlockAllocator::new(160, 16);
        let mut blocks = Vec::new();
        assert!(a.ensure(&mut blocks, 1)); // 1 block
        assert_eq!(blocks.len(), 1);
        assert!(a.ensure(&mut blocks, 16)); // still 1 block
        assert_eq!(blocks.len(), 1);
        assert!(a.ensure(&mut blocks, 17)); // 2 blocks
        assert_eq!(blocks.len(), 2);
        assert!(a.ensure(&mut blocks, 160));
        assert_eq!(blocks.len(), 10);
        assert!(!a.ensure(&mut blocks, 176)); // pool exhausted
        assert_eq!(blocks.len(), 10);
    }

    #[test]
    fn no_double_allocation() {
        let mut a = BlockAllocator::new(64, 8);
        let b1 = a.alloc(4).unwrap();
        let b2 = a.alloc(4).unwrap();
        for x in &b1 {
            assert!(!b2.contains(x), "block {x} double-allocated");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double free")]
    fn double_free_is_caught() {
        let mut a = BlockAllocator::new(64, 16);
        let blocks = a.alloc(2).unwrap();
        let mut once = blocks.clone();
        let mut twice = blocks;
        a.release(&mut once);
        a.release(&mut twice); // regression: used to silently corrupt the pool
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "out of range")]
    fn foreign_block_release_is_caught() {
        let mut a = BlockAllocator::new(64, 16);
        let mut bogus = vec![99u32];
        a.release(&mut bogus);
    }

    #[test]
    fn leak_assertion_tracks_outstanding_blocks() {
        let mut a = BlockAllocator::new(64, 16);
        let mut b = a.alloc(3).unwrap();
        a.release(&mut b);
        a.debug_assert_all_free();
    }

    #[test]
    fn utilization_tracks() {
        let mut a = BlockAllocator::new(100, 10);
        assert_eq!(a.utilization(), 0.0);
        let mut b = a.alloc(5).unwrap();
        assert!((a.utilization() - 0.5).abs() < 1e-12);
        a.release(&mut b);
        assert_eq!(a.utilization(), 0.0);
    }
}
