//! Long-context prompt prefilling (Algorithm 2) on the Gaussian workload:
//! HSR-sparse ReLU attention vs the naive dense computation across n,
//! with m = n (the paper's m = Θ(n) scenario).
//!
//! Run: cargo run --release --example longcontext_prefill [-- --ns 512,1024,2048,4096]

use hsr_attn::attention::relu::relu_attention;
use hsr_attn::attention::{linf, AttentionKind};
use hsr_attn::engine::PromptPrefilling;
use hsr_attn::hsr::HsrBackend;
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use hsr_attn::workloads::gaussian::AttentionInstance;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let ns = args.usize_list_or("ns", &[512, 1024, 2048, 4096]);
    let d = args.usize_or("d", 8);
    let alpha = args.usize_or("alpha", 2) as u32;
    println!("Algorithm 2 (prompt prefilling), ReLU^{alpha} attention, d = {d}, m = n");
    println!(
        "{:>7} | {:>11} {:>11} {:>8} | {:>10} {:>9}",
        "n", "dense", "hsr-sparse", "speedup", "fired/row", "max err"
    );
    println!("{}", "-".repeat(68));
    let mut rng = Rng::new(9);
    for &n in &ns {
        let inst = AttentionInstance::gaussian(&mut rng, n, n, d);
        let bias = inst.params.practical_bias(n) as f32;

        let t0 = Instant::now();
        let dense = relu_attention(&inst.q, &inst.k, &inst.v, d, alpha, bias);
        let t_dense = t0.elapsed();

        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha, bias },
            backend: HsrBackend::BallTree,
            top_r: None,
            bias_override: Some(bias),
            threads: args.usize_or("threads", 0),
        };
        let t0 = Instant::now();
        let res = pp.inference(&inst.q, &inst.k, &inst.v, n, n, d);
        let t_sparse = t0.elapsed();

        let avg_fired = res.fired.iter().sum::<usize>() / n;
        println!(
            "{:>7} | {:>11?} {:>11?} {:>7.2}x | {:>10} {:>9.1e}",
            n,
            t_dense,
            t_sparse,
            t_dense.as_secs_f64() / t_sparse.as_secs_f64(),
            avg_fired,
            linf(&res.out, &dense),
        );
    }
    println!("\nexpected shape (Theorem 5.1): sparse grows ~n^{{1+4/5}} vs dense n^2,");
    println!("so the speedup column should widen as n grows; error is exactly 0");
    println!("up to float associativity (ReLU sparsity is lossless).");
}
