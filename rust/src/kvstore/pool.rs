//! [`PagePool`] — the single owner of shared KV payload *and* capacity.
//!
//! Before the shared-prefix store existed, KV capacity accounting lived
//! in [`BlockAllocator`] while the float payload lived in each
//! sequence's private [`KvState`] — the "capacity authority vs payload
//! owner" split the old `kv_cache.rs` docs called out. The pool retires
//! that split for everything shared: it embeds the block allocator (so
//! sequence tails still allocate their pages here) and it owns every
//! prefix [`Segment`] outright — pages and floats together.
//!
//! # Segment invariants
//!
//! * A segment is **immutable** after [`PagePool::create_segment`]: its
//!   keys/values are frozen copies of a prefilled range, stored as one
//!   contiguous `[len, d_head]` buffer per (layer, head) so HSR gathers
//!   and value reads stay cache-friendly, and its per-(layer, head)
//!   [`crate::hsr::dynamic::DynamicHsr`] is batch-built once and then
//!   shared read-only by every sequence (and every worker thread — the
//!   index is only ever queried through `&self`).
//! * A segment holds `blocks_for(len)` pages from the same pool that
//!   sequence tails draw from, so admission, preemption and prefix-cache
//!   eviction all compete for one physical budget.
//! * Reference counts and LRU stamps live on the radix nodes
//!   ([`crate::kvstore::radix::RadixIndex`]), which own segment
//!   *lifecycle*; the pool only stores and destroys payload. A segment
//!   must be unreferenced when [`PagePool::destroy_segment`] runs —
//!   debug-asserted by the caller.

use crate::engine::kv_cache::BlockAllocator;
use crate::hsr::HsrBackend;
use crate::model::kv::KvState;

/// Identifier of a segment slot inside a [`PagePool`].
pub type SegmentId = u32;

/// One immutable shared-prefix segment: the KV payload for token
/// positions `[start, start + len)` of every sequence that holds it.
pub struct Segment {
    /// Frozen per-(layer, head) keys/values + one HSR index per head.
    pub kv: KvState,
    /// The token ids this segment covers (the radix edge label).
    pub tokens: Vec<u32>,
    /// Global position of the segment's first token within its chain.
    pub start: usize,
    /// Pages held from the pool's block allocator.
    blocks: Vec<u32>,
}

impl Segment {
    /// Tokens covered by this segment.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Global position one past the segment's last token.
    pub fn end(&self) -> usize {
        self.start + self.tokens.len()
    }
}

/// Block-paged pool owning the shared KV segments and the block
/// allocator that sizes both segments and private sequence tails.
pub struct PagePool {
    alloc: BlockAllocator,
    slots: Vec<Option<Segment>>,
    free_slots: Vec<u32>,
    hsr_backend: Option<HsrBackend>,
    /// Tokens currently held by live segments (diagnostics/metrics).
    segment_tokens: usize,
}

impl PagePool {
    pub fn new(
        capacity_tokens: usize,
        block_tokens: usize,
        hsr_backend: Option<HsrBackend>,
    ) -> PagePool {
        PagePool {
            alloc: BlockAllocator::new(capacity_tokens, block_tokens),
            slots: Vec::new(),
            free_slots: Vec::new(),
            hsr_backend,
            segment_tokens: 0,
        }
    }

    // --- block-allocator delegation (sequence tails allocate here) ---

    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.alloc.blocks_for(tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.alloc.total_blocks()
    }

    pub fn block_tokens(&self) -> usize {
        self.alloc.block_tokens()
    }

    pub fn utilization(&self) -> f64 {
        self.alloc.utilization()
    }

    pub fn alloc(&mut self, count: usize) -> Option<Vec<u32>> {
        self.alloc.alloc(count)
    }

    pub fn ensure(&mut self, blocks: &mut Vec<u32>, needed_tokens: usize) -> bool {
        self.alloc.ensure(blocks, needed_tokens)
    }

    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        self.alloc.release(blocks)
    }

    /// Debug-build cross-check: every block accounted free in the
    /// allocator's ledger (no-op in release builds).
    pub fn debug_assert_all_free(&self) {
        self.alloc.debug_assert_all_free()
    }

    // --- segment lifecycle ---

    /// Number of live segments.
    pub fn segment_count(&self) -> usize {
        self.slots.len() - self.free_slots.len()
    }

    /// Tokens held by live segments.
    pub fn cached_tokens(&self) -> usize {
        self.segment_tokens
    }

    /// Freeze rows `[src_offset, src_offset + tokens.len())` of `source`
    /// into a new refcount-managed segment covering global positions
    /// `[start, start + tokens.len())`. Allocates the segment's pages
    /// from the pool; returns `None` (allocating nothing) if the pool
    /// cannot hold it — prefix caching is strictly best-effort.
    pub fn create_segment(
        &mut self,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
    ) -> Option<SegmentId> {
        assert!(!tokens.is_empty(), "segments cover at least one token");
        let need = self.alloc.blocks_for(tokens.len());
        let blocks = self.alloc.alloc(need)?;
        let kv = source.snapshot_range(src_offset, tokens.len(), self.hsr_backend);
        let seg = Segment { kv, tokens: tokens.to_vec(), start, blocks };
        self.segment_tokens += seg.tokens.len();
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.slots[slot as usize] = Some(seg);
                slot
            }
            None => {
                self.slots.push(Some(seg));
                (self.slots.len() - 1) as u32
            }
        };
        Some(id)
    }

    /// Borrow a live segment.
    pub fn segment(&self, id: SegmentId) -> &Segment {
        self.slots[id as usize]
            .as_ref()
            .expect("segment id refers to a live segment")
    }

    /// Destroy a segment, returning its pages to the pool. The caller
    /// (the radix index) guarantees the segment is unreferenced.
    pub fn destroy_segment(&mut self, id: SegmentId) {
        let mut seg = self.slots[id as usize]
            .take()
            .expect("destroying a live segment");
        self.segment_tokens -= seg.tokens.len();
        self.alloc.release(&mut seg.blocks);
        self.free_slots.push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn filled_kv(rng: &mut Rng, n: usize, d: usize) -> KvState {
        let mut kv = KvState::new(1, 2, d, Some(HsrBackend::BallTree));
        for _ in 0..n {
            for h in 0..2 {
                let k = rng.gaussian_vec_f32(d, 1.0);
                let v = rng.gaussian_vec_f32(d, 1.0);
                kv.head_mut(0, h).append(&k, &v);
            }
        }
        kv
    }

    #[test]
    fn segment_blocks_are_accounted_and_released() {
        let mut rng = Rng::new(5);
        let kv = filled_kv(&mut rng, 40, 4);
        let mut pool = PagePool::new(256, 16, Some(HsrBackend::BallTree));
        let free0 = pool.free_blocks();
        let tokens: Vec<u32> = (0..40).collect();
        let id = pool.create_segment(&tokens, 0, &kv, 0).expect("fits");
        assert_eq!(pool.free_blocks(), free0 - pool.blocks_for(40));
        assert_eq!(pool.segment_count(), 1);
        assert_eq!(pool.cached_tokens(), 40);
        assert_eq!(pool.segment(id).len(), 40);
        assert_eq!(pool.segment(id).end(), 40);
        pool.destroy_segment(id);
        assert_eq!(pool.free_blocks(), free0);
        assert_eq!(pool.segment_count(), 0);
        assert_eq!(pool.cached_tokens(), 0);
    }

    #[test]
    fn create_segment_is_best_effort_under_pressure() {
        let mut rng = Rng::new(6);
        let kv = filled_kv(&mut rng, 64, 4);
        let mut pool = PagePool::new(32, 16, None);
        let tokens: Vec<u32> = (0..64).collect();
        let free0 = pool.free_blocks();
        assert!(pool.create_segment(&tokens, 0, &kv, 0).is_none());
        // A failed create must not leak blocks.
        assert_eq!(pool.free_blocks(), free0);
    }

    #[test]
    fn segment_payload_matches_source_rows() {
        let mut rng = Rng::new(7);
        let kv = filled_kv(&mut rng, 30, 8);
        let mut pool = PagePool::new(1024, 16, Some(HsrBackend::BallTree));
        let tokens: Vec<u32> = (10..30).collect();
        let id = pool.create_segment(&tokens, 10, &kv, 10).unwrap();
        let seg = pool.segment(id);
        assert_eq!(seg.start, 10);
        for h in 0..2 {
            let src = kv.head(0, h);
            let dst = seg.kv.head(0, h);
            assert_eq!(dst.len(), 20);
            for j in 0..20 {
                assert_eq!(dst.key_row(j), src.key_row(10 + j));
                assert_eq!(dst.value_row(j), src.value_row(10 + j));
            }
        }
        // Slot reuse after destroy.
        pool.destroy_segment(id);
        let id2 = pool.create_segment(&tokens, 10, &kv, 10).unwrap();
        assert_eq!(id, id2);
    }
}
