//! Engine metrics: counters + latency histograms, cheap enough for the
//! token hot loop, merged across workers by the router.

use crate::obs::telemetry::{ratio_or, SparsityHist};
use crate::util::stats::Histogram;

/// Aggregated serving metrics.
///
/// `merge` is associative and commutative (worker-order-independent):
/// every field is an integer sum, a max-merged gauge, an exact-merge
/// histogram, or — for the one f64 — an addition whose test inputs are
/// dyadic rationals. The live stats endpoint depends on this: snapshots
/// merge per-worker copies in whatever order the router walks them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_preempted: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    /// Per decode-step wall time across batches.
    pub step_latency: Histogram,
    /// End-to-end request latency.
    pub request_latency: Histogram,
    /// Time to first token.
    pub ttft: Histogram,
    /// HSR instrumentation totals.
    pub hsr_points_scanned: u64,
    pub hsr_nodes_visited: u64,
    pub hsr_reported: u64,
    pub attended_entries: u64,
    pub dense_equivalent_entries: u64,
    pub calibration_fallbacks: u64,
    // --- shared-prefix KV store counters ---
    /// Radix probes that could have changed coverage: one per admission
    /// attempt plus one per successful mid-prefill adoption. Per-chunk
    /// re-matches that merely confirm existing coverage are not counted
    /// (they would read as misses on a perfectly-covering cache).
    pub prefix_lookups: u64,
    /// Probes that adopted a non-empty chain.
    pub prefix_hits: u64,
    /// Prompt tokens never prefilled thanks to an adopted prefix.
    pub prefill_tokens_skipped: u64,
    /// Prompt tokens *demanded* of prefill: the prompt length of every
    /// admission, including re-admissions after preemption. This is the
    /// denominator of [`Metrics::prefix_skip_rate`] — a preempted
    /// sequence that re-adopts its prefix adds to both sides, so the
    /// rate stays a true fraction (`prompt_tokens` alone would let it
    /// exceed 100%).
    pub prefill_tokens_demanded: u64,
    /// Prompt tokens published into the radix cache as shared segments.
    pub prefix_tokens_inserted: u64,
    /// Cached segments LRU-evicted under pool pressure.
    pub prefix_segments_evicted: u64,
    /// Adopted chains shed by a wedged sequence (last-resort recompute
    /// so its self-referenced segments become evictable).
    pub prefix_sheds: u64,
    /// Decode rows answered inside a ≥ 2-member shared-prefix group
    /// (one multi-query traversal per chain segment).
    pub grouped_decode_rows: u64,
    // --- tiered KV (cold spill + content dedup) counters ---
    /// Segments demoted into the compressed cold tier under LRU
    /// pressure instead of being destroyed.
    pub segments_spilled: u64,
    /// Cold segments promoted back on a prefix match: decompressed,
    /// blocks re-reserved, HSR indices reattached.
    pub segments_refaulted: u64,
    /// Cumulative compressed bytes written to the spill store.
    pub spill_bytes: u64,
    /// Milliseconds spent decoding spill records and rebuilding /
    /// deserializing HSR indices during refaults.
    pub refault_rebuild_ms: f64,
    /// Publishes that resolved to an already-resident identical segment
    /// (content-hash dedup) instead of allocating a fresh one.
    pub dedup_hits: u64,
    /// Uncompressed payload bytes those dedup hits did not duplicate.
    pub dedup_bytes_saved: u64,
    // --- robustness counters ---
    /// Requests shed by admission control (queue/in-flight caps).
    pub requests_rejected: u64,
    /// Requests answered with a terminal structured error (worker died
    /// mid-generation, retry budget exhausted, ...).
    pub requests_failed: u64,
    /// Sequences aborted past their client-supplied deadline.
    pub deadline_aborts: u64,
    /// Sequences cancelled because the client went away.
    pub disconnect_aborts: u64,
    /// Worker threads that panicked (caught or detected at join).
    pub worker_panics: u64,
    /// Panicked workers restarted in place with a fresh engine.
    pub worker_restarts: u64,
    /// KV blocks still held after a full drain — 0 in a correct engine
    /// (checked against the allocator's debug ledger at worker exit).
    pub kv_blocks_leaked: u64,
    /// Gauge: peak queued+running requests across the pool (merged by
    /// max, not sum).
    pub queue_depth_peak: u64,
    // --- streaming + affinity counters ---
    /// Tokens accepted into per-request stream sinks (frames the
    /// consumer will see; refused pushes on a severed sink don't count).
    pub tokens_streamed: u64,
    /// Streams whose terminal outcome was not a clean finish after at
    /// least one token went out — the wire-visible truncations the
    /// terminal frame makes detectable.
    pub streams_severed: u64,
    /// Streaming sequences shed because the consumer fell a full
    /// send-buffer behind (sink overflow → sever → shed at next step).
    pub slow_consumer_sheds: u64,
    /// Router dispatches that followed the prefix-affinity sketch to a
    /// live, unsaturated worker.
    pub affinity_hits: u64,
    /// Dispatches where the sketch named a worker but the degradation
    /// ladder fell back to least-loaded (dead, saturated, or the sketch
    /// probe was contended).
    pub affinity_fallbacks: u64,
    /// Time-to-first-token as deliverable on the wire: router
    /// submission until the first token enters the stream channel
    /// (engine-side `ttft` starts later, at sequence admission; this
    /// includes router queueing).
    pub ttft_wire: Histogram,
    // --- fork/join (parallel sampling + beam) counters ---
    /// Grouped requests admitted (parallel-sampling n/best_of ≥ 2 or
    /// beam width ≥ 2); each emits exactly one multi-choice response.
    pub group_requests: u64,
    /// Mid-decode sequence forks (sampling fan-outs + beam expansions
    /// + explicit `fork_request` calls).
    pub sequence_forks: u64,
    /// KV tokens a freshly forked sibling shares via the chain instead
    /// of recomputing or copying (its `prefix_len` at fork time).
    pub fork_shared_tokens: u64,
    /// Forks that could not publish the parent tail (pool pressure) and
    /// fell back to recompute: the child re-prefills privately, still
    /// bit-identical, just without physical sharing.
    pub fork_recompute_fallbacks: u64,
    /// Beam hypotheses pruned (blocks and chain refs released without a
    /// response; the survivors carry the beam forward).
    pub beam_prunes: u64,
    // --- sparsity telemetry ---
    /// Empirical fired-entry fraction per context-length bucket,
    /// reported against the paper's `n^{4/5}` envelope.
    pub fired_fraction: SparsityHist,
}

impl Metrics {
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_submitted += other.requests_submitted;
        self.requests_completed += other.requests_completed;
        self.requests_preempted += other.requests_preempted;
        self.prompt_tokens += other.prompt_tokens;
        self.generated_tokens += other.generated_tokens;
        self.step_latency.merge(&other.step_latency);
        self.request_latency.merge(&other.request_latency);
        self.ttft.merge(&other.ttft);
        self.hsr_points_scanned += other.hsr_points_scanned;
        self.hsr_nodes_visited += other.hsr_nodes_visited;
        self.hsr_reported += other.hsr_reported;
        self.attended_entries += other.attended_entries;
        self.dense_equivalent_entries += other.dense_equivalent_entries;
        self.calibration_fallbacks += other.calibration_fallbacks;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefill_tokens_skipped += other.prefill_tokens_skipped;
        self.prefill_tokens_demanded += other.prefill_tokens_demanded;
        self.prefix_tokens_inserted += other.prefix_tokens_inserted;
        self.prefix_segments_evicted += other.prefix_segments_evicted;
        self.prefix_sheds += other.prefix_sheds;
        self.grouped_decode_rows += other.grouped_decode_rows;
        self.segments_spilled += other.segments_spilled;
        self.segments_refaulted += other.segments_refaulted;
        self.spill_bytes += other.spill_bytes;
        self.refault_rebuild_ms += other.refault_rebuild_ms;
        self.dedup_hits += other.dedup_hits;
        self.dedup_bytes_saved += other.dedup_bytes_saved;
        self.requests_rejected += other.requests_rejected;
        self.requests_failed += other.requests_failed;
        self.deadline_aborts += other.deadline_aborts;
        self.disconnect_aborts += other.disconnect_aborts;
        self.worker_panics += other.worker_panics;
        self.worker_restarts += other.worker_restarts;
        self.kv_blocks_leaked += other.kv_blocks_leaked;
        self.queue_depth_peak = self.queue_depth_peak.max(other.queue_depth_peak);
        self.tokens_streamed += other.tokens_streamed;
        self.streams_severed += other.streams_severed;
        self.slow_consumer_sheds += other.slow_consumer_sheds;
        self.affinity_hits += other.affinity_hits;
        self.affinity_fallbacks += other.affinity_fallbacks;
        self.ttft_wire.merge(&other.ttft_wire);
        self.group_requests += other.group_requests;
        self.sequence_forks += other.sequence_forks;
        self.fork_shared_tokens += other.fork_shared_tokens;
        self.fork_recompute_fallbacks += other.fork_recompute_fallbacks;
        self.beam_prunes += other.beam_prunes;
        self.fired_fraction.merge(&other.fired_fraction);
    }

    /// Fraction of demanded prefill tokens skipped via the shared-prefix
    /// cache (the bench's "prefill tokens skipped"); always in [0, 1].
    pub fn prefix_skip_rate(&self) -> f64 {
        ratio_or(
            self.prefill_tokens_skipped as f64,
            self.prefill_tokens_demanded as f64,
            0.0,
        )
    }

    /// Fraction of radix lookups that adopted a cached chain.
    pub fn prefix_hit_rate(&self) -> f64 {
        ratio_or(self.prefix_hits as f64, self.prefix_lookups as f64, 0.0)
    }

    pub fn record_step_stats(&mut self, s: &crate::model::transformer::StepStats) {
        self.hsr_points_scanned += s.hsr.points_scanned as u64;
        self.hsr_nodes_visited += s.hsr.nodes_visited as u64;
        self.hsr_reported += s.hsr.reported as u64;
        self.attended_entries += s.attended as u64;
        self.dense_equivalent_entries += s.dense_equivalent as u64;
        self.calibration_fallbacks += s.fallbacks as u64;
    }

    /// Fraction of attention entries actually computed vs dense
    /// (1 − this = the Table-1 "sparsity ratio" realized by the engine).
    pub fn attended_fraction(&self) -> f64 {
        ratio_or(
            self.attended_entries as f64,
            self.dense_equivalent_entries as f64,
            1.0,
        )
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "requests: {} submitted / {} completed / {} preempted\n\
             tokens:   {} prompt / {} generated\n\
             latency:  p50 {} p90 {} p99 {} (request)  ttft p50 {}\n\
             step:     p50 {} p99 {}\n\
             sparsity: attended {:.2}% of dense ({} fallbacks)\n\
             prefix:   {:.1}% prefill tokens skipped, {}/{} lookups hit, \
             {} inserted / {} evicted, {} grouped decode rows\n\
             tier:     {} spilled / {} refaulted, {} spill bytes, \
             {:.1} ms rebuild; dedup {} hits / {} bytes saved\n\
             robust:   {} rejected / {} failed / {} deadline / {} disconnect; \
             {} worker panics / {} restarts; peak queue {}; {} leaked blocks\n\
             stream:   {} tokens_streamed / {} streams_severed / \
             {} slow_consumer_sheds; ttft_ms p50 {} (wire); \
             affinity {} hits / {} fallbacks\n\
             fork:     {} groups / {} forks / {} shared tokens / \
             {} recompute fallbacks / {} beam prunes",
            self.requests_submitted,
            self.requests_completed,
            self.requests_preempted,
            self.prompt_tokens,
            self.generated_tokens,
            crate::util::stats::fmt_ns(self.request_latency.percentile_ns(50.0) as f64),
            crate::util::stats::fmt_ns(self.request_latency.percentile_ns(90.0) as f64),
            crate::util::stats::fmt_ns(self.request_latency.percentile_ns(99.0) as f64),
            crate::util::stats::fmt_ns(self.ttft.percentile_ns(50.0) as f64),
            crate::util::stats::fmt_ns(self.step_latency.percentile_ns(50.0) as f64),
            crate::util::stats::fmt_ns(self.step_latency.percentile_ns(99.0) as f64),
            100.0 * self.attended_fraction(),
            self.calibration_fallbacks,
            100.0 * self.prefix_skip_rate(),
            self.prefix_hits,
            self.prefix_lookups,
            self.prefix_tokens_inserted,
            self.prefix_segments_evicted,
            self.grouped_decode_rows,
            self.segments_spilled,
            self.segments_refaulted,
            self.spill_bytes,
            self.refault_rebuild_ms,
            self.dedup_hits,
            self.dedup_bytes_saved,
            self.requests_rejected,
            self.requests_failed,
            self.deadline_aborts,
            self.disconnect_aborts,
            self.worker_panics,
            self.worker_restarts,
            self.queue_depth_peak,
            self.kv_blocks_leaked,
            self.tokens_streamed,
            self.streams_severed,
            self.slow_consumer_sheds,
            crate::util::stats::fmt_ns(self.ttft_wire.percentile_ns(50.0) as f64),
            self.affinity_hits,
            self.affinity_fallbacks,
            self.group_requests,
            self.sequence_forks,
            self.fork_shared_tokens,
            self.fork_recompute_fallbacks,
            self.beam_prunes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.requests_completed = 3;
        b.requests_completed = 4;
        b.generated_tokens = 10;
        a.merge(&b);
        assert_eq!(a.requests_completed, 7);
        assert_eq!(a.generated_tokens, 10);
    }

    #[test]
    fn prefix_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.prefix_skip_rate(), 0.0);
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefill_tokens_demanded = 200;
        m.prefill_tokens_skipped = 150;
        m.prefix_lookups = 4;
        m.prefix_hits = 3;
        assert!((m.prefix_skip_rate() - 0.75).abs() < 1e-12);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.summary().contains("75.0% prefill tokens skipped"));
        let mut other = Metrics::default();
        other.prefix_hits = 1;
        other.grouped_decode_rows = 7;
        m.merge(&other);
        assert_eq!(m.prefix_hits, 4);
        assert_eq!(m.grouped_decode_rows, 7);
    }

    #[test]
    fn robustness_counters_merge_and_render() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.requests_rejected = 2;
        a.queue_depth_peak = 9;
        b.requests_rejected = 3;
        b.queue_depth_peak = 4;
        b.worker_panics = 1;
        b.worker_restarts = 1;
        b.deadline_aborts = 5;
        a.merge(&b);
        assert_eq!(a.requests_rejected, 5);
        assert_eq!(a.worker_panics, 1);
        assert_eq!(a.deadline_aborts, 5);
        // Gauge merges by max, not sum.
        assert_eq!(a.queue_depth_peak, 9);
        assert!(a.summary().contains("5 rejected"));
        assert!(a.summary().contains("peak queue 9"));
    }

    #[test]
    fn tier_counters_merge_and_render() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.segments_spilled = 3;
        a.refault_rebuild_ms = 1.5;
        b.segments_spilled = 4;
        b.segments_refaulted = 2;
        b.spill_bytes = 1024;
        b.refault_rebuild_ms = 0.5;
        b.dedup_hits = 7;
        b.dedup_bytes_saved = 4096;
        a.merge(&b);
        assert_eq!(a.segments_spilled, 7);
        assert_eq!(a.segments_refaulted, 2);
        assert_eq!(a.spill_bytes, 1024);
        assert!((a.refault_rebuild_ms - 2.0).abs() < 1e-12);
        assert_eq!(a.dedup_hits, 7);
        assert_eq!(a.dedup_bytes_saved, 4096);
        let s = a.summary();
        assert!(s.contains("7 spilled / 2 refaulted"), "{s}");
        assert!(s.contains("dedup 7 hits / 4096 bytes saved"), "{s}");
    }

    #[test]
    fn fork_counters_merge_and_render() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        a.group_requests = 2;
        a.sequence_forks = 5;
        b.sequence_forks = 3;
        b.fork_shared_tokens = 640;
        b.fork_recompute_fallbacks = 1;
        b.beam_prunes = 6;
        a.merge(&b);
        assert_eq!(a.group_requests, 2);
        assert_eq!(a.sequence_forks, 8);
        assert_eq!(a.fork_shared_tokens, 640);
        let s = a.summary();
        assert!(s.contains("2 groups / 8 forks / 640 shared tokens"), "{s}");
        assert!(s.contains("1 recompute fallbacks / 6 beam prunes"), "{s}");
    }

    /// Deterministic pseudo-random `Metrics` value touching every field
    /// class: integer counters, the max-merged gauge, both histograms,
    /// the f64 accumulator (dyadic rationals so f64 addition is exact),
    /// and the sparsity histogram.
    fn arb_metrics(seed: u64) -> Metrics {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut m = Metrics::default();
        m.requests_submitted = next() % 100;
        m.requests_completed = next() % 100;
        m.requests_preempted = next() % 10;
        m.prompt_tokens = next() % 10_000;
        m.generated_tokens = next() % 10_000;
        m.hsr_points_scanned = next() % 100_000;
        m.hsr_nodes_visited = next() % 100_000;
        m.hsr_reported = next() % 10_000;
        m.attended_entries = next() % 10_000;
        m.dense_equivalent_entries = next() % 100_000;
        m.calibration_fallbacks = next() % 10;
        m.prefix_lookups = next() % 100;
        m.prefix_hits = next() % 100;
        m.prefill_tokens_skipped = next() % 1000;
        m.prefill_tokens_demanded = next() % 1000;
        m.prefix_tokens_inserted = next() % 1000;
        m.prefix_segments_evicted = next() % 50;
        m.prefix_sheds = next() % 5;
        m.grouped_decode_rows = next() % 500;
        m.segments_spilled = next() % 50;
        m.segments_refaulted = next() % 50;
        m.spill_bytes = next() % 1_000_000;
        m.refault_rebuild_ms = (next() % 64) as f64 * 0.25;
        m.dedup_hits = next() % 50;
        m.dedup_bytes_saved = next() % 100_000;
        m.requests_rejected = next() % 20;
        m.requests_failed = next() % 20;
        m.deadline_aborts = next() % 10;
        m.disconnect_aborts = next() % 10;
        m.worker_panics = next() % 4;
        m.worker_restarts = next() % 4;
        m.kv_blocks_leaked = next() % 2;
        m.queue_depth_peak = next() % 64;
        m.tokens_streamed = next() % 10_000;
        m.streams_severed = next() % 10;
        m.slow_consumer_sheds = next() % 10;
        m.affinity_hits = next() % 100;
        m.affinity_fallbacks = next() % 100;
        m.group_requests = next() % 10;
        m.sequence_forks = next() % 20;
        m.fork_shared_tokens = next() % 5000;
        m.fork_recompute_fallbacks = next() % 5;
        m.beam_prunes = next() % 20;
        for _ in 0..(next() % 8) {
            m.step_latency.record_ns(1_000 + next() % 10_000_000);
            m.request_latency.record_ns(1_000 + next() % 100_000_000);
            m.ttft.record_ns(1_000 + next() % 50_000_000);
            m.ttft_wire.record_ns(1_000 + next() % 50_000_000);
        }
        for _ in 0..(next() % 6) {
            let ctx = 1 + (next() % 100_000) as usize;
            let dense = 1 + next() % 100_000;
            m.fired_fraction.record(ctx, next() % (dense + 1), dense);
        }
        m
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        // The stats endpoint merges per-worker metrics in whatever
        // order the router walks its slots; the result must not depend
        // on that order. Histogram and sparsity merges included.
        for seed in 0..32u64 {
            let a = arb_metrics(seed * 3 + 1);
            let b = arb_metrics(seed * 3 + 2);
            let c = arb_metrics(seed * 3 + 3);
            // (a ⊕ b) ⊕ c
            let mut ab_c = a.clone();
            ab_c.merge(&b);
            ab_c.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            assert_eq!(ab_c, a_bc, "associativity failed at seed {seed}");
            // a ⊕ b == b ⊕ a
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "commutativity failed at seed {seed}");
            // Identity: merging a default is a no-op.
            let mut id = a.clone();
            id.merge(&Metrics::default());
            assert_eq!(id, a, "identity failed at seed {seed}");
        }
    }

    #[test]
    fn empty_engine_ratios_are_guarded() {
        // Satellite: every ratio on a fresh engine goes through the
        // shared zero-denominator helper and stays finite.
        let m = Metrics::default();
        assert_eq!(m.prefix_skip_rate(), 0.0);
        assert_eq!(m.prefix_hit_rate(), 0.0);
        assert_eq!(m.attended_fraction(), 1.0);
        assert_eq!(m.fired_fraction.overall_fraction(), 1.0);
        assert!(m.summary().lines().count() >= 9, "summary renders empty");
    }

    #[test]
    fn attended_fraction_bounds() {
        let mut m = Metrics::default();
        assert_eq!(m.attended_fraction(), 1.0);
        m.dense_equivalent_entries = 100;
        m.attended_entries = 25;
        assert!((m.attended_fraction() - 0.25).abs() < 1e-12);
        assert!(m.summary().contains("25.00%"));
    }
}
