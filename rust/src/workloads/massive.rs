//! Workloads with the massive-activation property (Definition B.3).
//!
//! Remark B.4 notes two families that satisfy the property: subexponential
//! key distributions and mixtures of Gaussians with n^{1-γ} clusters. We
//! implement both, plus a "planted" construction where (γ, β₁, β₂) are
//! controlled directly — the latter is what `benches/error_topr.rs` sweeps
//! to trace Theorem 4.3's error curve.

use crate::hsr::{dot, norm};
use crate::util::rng::Rng;

/// A query/key pair engineered so that q, K satisfy the (γ, β₁, β₂)
/// massive-activation property by construction.
#[derive(Debug, Clone)]
pub struct PlantedInstance {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub n: usize,
    pub d: usize,
    /// Number of planted massive keys = n^gamma (rounded).
    pub top: usize,
    pub gamma: f64,
}

/// Plant `n^gamma` keys with <q, K_i> ≈ beta1·‖q‖·ln n and the remainder
/// with <q, K_i> ≤ beta2·‖q‖·ln n.
pub fn planted(
    rng: &mut Rng,
    n: usize,
    d: usize,
    gamma: f64,
    beta1: f64,
    beta2: f64,
) -> PlantedInstance {
    assert!(beta1 >= beta2 && beta2 >= 0.0);
    let q = rng.gaussian_vec_f32(d, 1.0);
    let qn = norm(&q) as f64;
    let ln_n = (n as f64).ln();
    let top = ((n as f64).powf(gamma).round() as usize).clamp(1, n);
    let mut k = vec![0f32; n * d];
    let unit: Vec<f32> = q.iter().map(|&x| x / qn as f32).collect();
    for i in 0..n {
        let target = if i < top {
            // Slightly above the β₁ mean so the *average* clears it.
            beta1 * qn * ln_n * 1.05
        } else {
            // Uniform in [0, β₂ ‖q‖ ln n): strictly below the cap.
            rng.uniform(0.0, (beta2 * qn * ln_n).max(1e-6) * 0.95)
        };
        // K_i = (target/‖q‖)·q̂ + orthogonal noise.
        let coeff = (target / qn) as f32;
        let noise = rng.gaussian_vec_f32(d, 0.05);
        // Project noise orthogonal to q so it cannot shift the score.
        let nq = dot(&noise, &unit);
        for j in 0..d {
            k[i * d + j] = coeff * unit[j] + (noise[j] - nq * unit[j]);
        }
    }
    let v = rng.gaussian_vec_f32(n * d, 1.0);
    PlantedInstance { q, k, v, n, d, top, gamma }
}

/// Mixture-of-Gaussians keys (Remark B.4 case 2): `clusters` centers drawn
/// at radius `radius`, keys scattered around them with std `spread`.
pub fn gaussian_mixture_keys(
    rng: &mut Rng,
    n: usize,
    d: usize,
    clusters: usize,
    radius: f64,
    spread: f64,
) -> Vec<f32> {
    assert!(clusters >= 1);
    let mut centers = vec![0f32; clusters * d];
    for c in 0..clusters {
        let dir = rng.gaussian_vec_f32(d, 1.0);
        let nrm = norm(&dir).max(1e-9);
        for j in 0..d {
            centers[c * d + j] = dir[j] / nrm * radius as f32;
        }
    }
    let mut k = vec![0f32; n * d];
    for i in 0..n {
        let c = rng.below(clusters);
        for j in 0..d {
            k[i * d + j] = centers[c * d + j] + rng.normal(0.0, spread) as f32;
        }
    }
    k
}

/// Multivariate-Laplace-ish keys (Remark B.4 case 1, subexponential):
/// Gaussian directions with Exp(1) radial lengths.
pub fn laplace_keys(rng: &mut Rng, n: usize, d: usize, scale: f64) -> Vec<f32> {
    let mut k = vec![0f32; n * d];
    for i in 0..n {
        let dir = rng.gaussian_vec_f32(d, 1.0);
        let nrm = norm(&dir).max(1e-9);
        let len = rng.exponential(1.0) * scale;
        for j in 0..d {
            k[i * d + j] = dir[j] / nrm * len as f32;
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::error::MassiveActivation;

    #[test]
    fn planted_satisfies_definition_b3() {
        let mut rng = Rng::new(81);
        let (n, d) = (2048usize, 16usize);
        let inst = planted(&mut rng, n, d, 0.4, 0.8, 0.2);
        let ma = MassiveActivation::measure(&inst.q, &inst.k, d, 0.4);
        assert!(
            ma.beta1 >= 0.8 * 0.95,
            "planted beta1 {} too small",
            ma.beta1
        );
        assert!(ma.beta2 <= 0.2, "planted beta2 {} too large", ma.beta2);
        assert_eq!(ma.top, inst.top);
    }

    #[test]
    fn mixture_keys_have_cluster_structure() {
        let mut rng = Rng::new(82);
        let (n, d) = (1000usize, 8usize);
        let k = gaussian_mixture_keys(&mut rng, n, d, 4, 5.0, 0.2);
        // Norms concentrate near the cluster radius.
        let mut near = 0;
        for i in 0..n {
            let nrm = norm(&k[i * d..(i + 1) * d]);
            if (nrm - 5.0).abs() < 1.5 {
                near += 1;
            }
        }
        assert!(near > n * 9 / 10, "only {near} near radius");
    }

    #[test]
    fn laplace_keys_are_heavy_tailed() {
        let mut rng = Rng::new(83);
        let (n, d) = (20_000usize, 4usize);
        let k = laplace_keys(&mut rng, n, d, 1.0);
        let norms: Vec<f64> = (0..n).map(|i| norm(&k[i * d..(i + 1) * d]) as f64).collect();
        let mean = norms.iter().sum::<f64>() / n as f64;
        let max = norms.iter().cloned().fold(0.0, f64::max);
        // Exponential radial: max/mean should be large (heavy tail).
        assert!(max / mean > 5.0, "max/mean = {}", max / mean);
    }
}
