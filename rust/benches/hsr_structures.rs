//! Bench/reproduction: **Corollary 3.1** — HSR init/query scaling across
//! backends, plus the dynamic-update amortization of Theorem B.11.
//!
//! Expected shapes:
//!  * init: brute O(n), ball-tree / layers2d O(n log n)-ish.
//!  * query: output-sensitive for ball-tree (low d) and layers2d (d = 2),
//!    degrading toward linear as d grows (the AEM n^{1-1/⌊d/2⌋} story).
//!  * dynamic inserts: amortized ~log² n.

use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::hsr::dynamic::DynamicHsr;
use hsr_attn::hsr::{build_hsr, gaussian_points, HsrBackend, QueryStats};
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::{fmt_ns, power_fit};

fn main() {
    banner("hsr_structures", "paper Corollary 3.1 / Theorem B.11 (HSR costs)");
    let bench = Bencher::quick();
    let ns = [4_096usize, 16_384, 65_536];

    // ---- init + query across backends ----
    for d in [2usize, 8, 16] {
        println!("\n== d = {d} ==");
        println!(
            "{:>9} {:>10} | {:>11} {:>11} | {:>10} {:>10}",
            "backend", "n", "init", "query", "scanned", "reported"
        );
        let backends: Vec<HsrBackend> = if d == 2 {
            vec![HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Layers2d]
        } else {
            vec![HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected]
        };
        for backend in backends {
            let mut q_times = Vec::new();
            let mut sizes = Vec::new();
            for &n in &ns {
                let mut rng = Rng::new(n as u64);
                let pts = gaussian_points(&mut rng, n, d, 1.0);
                let init = bench.run(&format!("{}/init/n={n}", backend.name()), || {
                    black_box(build_hsr(backend, &pts, d));
                });
                let index = build_hsr(backend, &pts, d);
                // Threshold reporting ~n^{4/5} entries (Lemma 6.1 regime).
                let q = rng.gaussian_vec_f32(d, 1.0);
                let qn = hsr_attn::hsr::norm(&q) as f64;
                let b = (qn / (d as f64).sqrt() * (0.4 * (n as f64).ln()).sqrt()
                    * (d as f64).sqrt()) as f32;
                let mut out = Vec::new();
                let mut stats = QueryStats::default();
                index.query_into(&q, b, &mut out, &mut stats);
                let query = bench.run(&format!("{}/query/n={n}", backend.name()), || {
                    let mut o = Vec::new();
                    let mut s = QueryStats::default();
                    index.query_into(&q, b, &mut o, &mut s);
                    black_box(o.len());
                });
                println!(
                    "{:>9} {:>10} | {:>11} {:>11} | {:>10} {:>10}",
                    backend.name(),
                    n,
                    fmt_ns(init.median_ns),
                    fmt_ns(query.median_ns),
                    stats.points_scanned,
                    stats.reported
                );
                q_times.push(query.median_ns);
                sizes.push(n as f64);
            }
            if let Some((e, r2)) = power_fit(&sizes, &q_times) {
                println!(
                    "{:>9}   query-time exponent fit: n^{e:.2} (r2={r2:.3})",
                    backend.name()
                );
            }
        }
    }

    // ---- dynamic updates (logarithmic method) ----
    println!("\n== dynamic inserts (Theorem B.11 amortized updates), d = 8 ==");
    println!("{:>9} | {:>12} {:>14} {:>10}", "n", "total", "per-insert", "rebuilds");
    for &n in &ns {
        let mut rng = Rng::new(n as u64 + 1);
        let points: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec_f32(8, 1.0)).collect();
        let r = bench.run(&format!("dynamic_insert/n={n}"), || {
            let mut dynamic = DynamicHsr::new(HsrBackend::BallTree, 8);
            for p in &points {
                dynamic.insert(p);
            }
            black_box(&dynamic);
        });
        let mut dynamic = DynamicHsr::new(HsrBackend::BallTree, 8);
        for p in &points {
            dynamic.insert(p);
        }
        println!(
            "{:>9} | {:>12} {:>14} {:>10}",
            n,
            fmt_ns(r.median_ns),
            fmt_ns(r.median_ns / n as f64),
            dynamic.rebuilds
        );
    }
    println!("\nexpected: per-insert cost grows ~log^2 n, not with n.");
}
