//! Tiny command-line argument parser (the vendored dependency set has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    opts: BTreeMap<String, String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (useful for tests).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: everything after is positional.
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: if the next token is not an option, treat it as
                    // this option's value; otherwise it is a boolean flag.
                    let is_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_value {
                        args.opts.insert(body.to_string(), it.next().unwrap());
                    } else {
                        args.opts.insert(body.to_string(), "true".to_string());
                    }
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.replace('_', "").parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.replace('_', "").parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--ns 1024,2048,4096`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().replace('_', "").parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Run `--key` (or `default`) through a fallible parser, prefixing
    /// any error with the flag name so it reads as CLI feedback — e.g.
    /// `HsrBackend::parse`'s valid-name list surfaces verbatim.
    pub fn try_parse<T>(
        &self,
        key: &str,
        default: &str,
        parse: impl FnOnce(&str) -> Result<T, String>,
    ) -> Result<T, String> {
        parse(self.str_or(key, default)).map_err(|e| format!("--{key}: {e}"))
    }

    /// Like [`Args::try_parse`] but terminal: on a parse error, print the
    /// message plus the caller's usage line to stderr and exit 2 (the
    /// same exit code the unknown-subcommand path uses).
    pub fn parse_or_exit<T>(
        &self,
        key: &str,
        default: &str,
        usage: &str,
        parse: impl FnOnce(&str) -> Result<T, String>,
    ) -> T {
        match self.try_parse(key, default, parse) {
            Ok(v) => v,
            Err(msg) => {
                eprintln!("{msg}");
                eprintln!("{usage}");
                std::process::exit(2);
            }
        }
    }

    /// First positional argument (typically a subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_styles() {
        let a = parse("serve --port 9000 --host=127.0.0.1 --verbose");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 9000);
        assert_eq!(a.str_or("host", "x"), "127.0.0.1");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 42), 42);
        assert_eq!(a.f64_or("sigma", 1.5), 1.5);
    }

    #[test]
    fn lists_and_underscores() {
        let a = parse("x --ns 1_024,2048 --big 65_536");
        assert_eq!(a.usize_list_or("ns", &[]), vec![1024, 2048]);
        assert_eq!(a.usize_or("big", 0), 65536);
    }

    #[test]
    fn try_parse_prefixes_flag_name() {
        let a = parse("serve --backend balltree");
        let ok = a.try_parse("backend", "brute", crate::hsr::HsrBackend::parse);
        assert_eq!(ok, Ok(crate::hsr::HsrBackend::BallTree));
        let b = parse("serve --backend nope");
        let err = b
            .try_parse("backend", "brute", crate::hsr::HsrBackend::parse)
            .unwrap_err();
        assert!(err.starts_with("--backend:"), "{err}");
        assert!(err.contains("balltree"), "valid names must be listed: {err}");
        // Absent flag parses the default.
        let c = parse("serve");
        assert_eq!(
            c.try_parse("backend", "projected", crate::hsr::HsrBackend::parse),
            Ok(crate::hsr::HsrBackend::Projected)
        );
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("cmd --opt v -- --not-an-opt pos");
        assert_eq!(a.get("opt"), Some("v"));
        assert_eq!(a.positional, vec!["cmd", "--not-an-opt", "pos"]);
    }
}
