//! [`PagePool`] — the single owner of shared KV payload *and* capacity,
//! now across **two tiers**.
//!
//! Before the shared-prefix store existed, KV capacity accounting lived
//! in [`BlockAllocator`] while the float payload lived in each
//! sequence's private [`KvState`] — the "capacity authority vs payload
//! owner" split the old `kv_cache.rs` docs called out. The pool retires
//! that split for everything shared: it embeds the block allocator (so
//! sequence tails still allocate their pages here) and it owns every
//! prefix segment outright — pages and floats together, hot or cold.
//!
//! # Tiers
//!
//! A segment slot is either **hot** — uncompressed payload in pool
//! blocks plus built per-(layer, head) HSR indices, servable — or
//! **cold** — its payload compressed into the [`SpillStore`] and its
//! blocks returned to the shared budget, while the radix node that owns
//! it stays in the tree so the prefix can still *match*. Transitions:
//!
//! * [`PagePool::release_segment`] with `spill = true` **demotes** a
//!   sole-owner hot segment in place ([`Demoted::Spilled`]);
//! * [`PagePool::refault_segment`] **promotes** a cold segment back —
//!   decompress, re-reserve blocks, reattach HSR per the
//!   [`SpillPolicy`] — before a sequence adopts the chain.
//!
//! # Dedup
//!
//! Publishes are content-addressed: [`segment_content_key`] digests the
//! token run, chain position, shape, and every K/V bit the segment
//! would freeze. A digest hit is confirmed by a **full bitwise payload
//! comparison** (a collision can cost a missed share, never a wrong
//! one), and then the existing physical segment simply gains an owner —
//! `owners` counts radix nodes per physical segment, so identical
//! chunks published under different radix parents share one payload
//! and one set of HSR indices fleet-wide. Payload is destroyed (or
//! demoted) only when the last owner lets go.
//!
//! # Segment invariants
//!
//! * A segment is **immutable** after [`PagePool::create_segment`]: its
//!   keys/values are frozen copies of a prefilled range, stored as one
//!   contiguous `[len, d_head]` buffer per (layer, head) so HSR gathers
//!   and value reads stay cache-friendly, and its per-(layer, head)
//!   [`crate::hsr::dynamic::DynamicHsr`] is batch-built once and then
//!   shared read-only by every sequence (and every worker thread — the
//!   index is only ever queried through `&self`). Demotion round-trips
//!   the payload bit-exactly and the index deterministically, so
//!   immutability spans the cold trip.
//! * A hot segment holds `blocks_for(len)` pages from the same pool
//!   that sequence tails draw from, so admission, preemption and
//!   prefix-cache eviction all compete for one physical budget. A cold
//!   segment holds **zero** pages — only a spill extent.
//! * Reference counts and LRU stamps live on the radix nodes
//!   ([`crate::kvstore::radix::RadixIndex`]), which own segment
//!   *lifecycle*; the pool owns payload, tiers and the owner count. A
//!   segment must be unreferenced when its owning node releases it —
//!   debug-asserted by the caller.

use super::tier::hash::segment_content_key;
use super::tier::{
    decode_segment, encode_segment, Extent, SpillPolicy, SpillStore, TierConfig, TierStats,
};
use crate::engine::kv_cache::BlockAllocator;
use crate::hsr::HsrBackend;
use crate::model::kv::KvState;
use std::collections::HashMap;

/// Identifier of a segment slot inside a [`PagePool`].
pub type SegmentId = u32;

/// One immutable shared-prefix segment: the KV payload for token
/// positions `[start, start + len)` of every sequence that holds it.
pub struct Segment {
    /// Frozen per-(layer, head) keys/values + one HSR index per head.
    pub kv: KvState,
    /// The token ids this segment covers (the radix edge label).
    pub tokens: Vec<u32>,
    /// Global position of the segment's first token within its chain.
    pub start: usize,
    /// Pages held from the pool's block allocator.
    blocks: Vec<u32>,
}

impl Segment {
    /// Tokens covered by this segment.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Global position one past the segment's last token.
    pub fn end(&self) -> usize {
        self.start + self.tokens.len()
    }
}

/// A demoted segment: tokens stay resident (the radix edge label must
/// remain matchable), payload lives in the spill store.
struct ColdSegment {
    tokens: Vec<u32>,
    start: usize,
    extent: Extent,
    /// Uncompressed payload bytes (for spill-ratio diagnostics).
    raw_bytes: usize,
    /// Set when a refault failed to decode: the record is lost, the
    /// node must never match again, and teardown reaps it.
    poisoned: bool,
}

enum State {
    Hot(Segment),
    Cold(ColdSegment),
}

struct Entry {
    /// Radix nodes owning this physical segment (content dedup).
    owners: u32,
    /// Content digest ([`segment_content_key`]) — the dedup map key.
    content: u64,
    state: State,
}

/// Outcome of [`PagePool::release_segment`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Demoted {
    /// Sole owner, spill requested: payload compressed into the cold
    /// tier, blocks freed, slot stays live (cold).
    Spilled,
    /// Other owners remain: this owner's claim dropped, payload stays
    /// hot, nothing freed.
    SharedKept,
    /// Sole owner, no spill (or spill declined): payload destroyed,
    /// blocks freed, slot retired.
    Dropped,
    /// Spill I/O failed and the caller forbade dropping: segment is
    /// still hot and untouched (spill has been disabled pool-wide so
    /// the caller's eviction loop cannot spin on this outcome).
    Kept,
}

/// Outcome of [`PagePool::refault_segment`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Refault {
    /// Segment is hot again; blocks re-reserved, HSR reattached.
    Refaulted,
    /// Not enough free blocks — caller should evict and retry (or give
    /// up and re-prefill).
    NoRoom,
    /// The cold record failed to read or decode; the segment is now
    /// poisoned (never matchable) and waits for teardown.
    Failed,
}

/// Block-paged pool owning the shared KV segments (hot and cold) and
/// the block allocator that sizes both segments and private tails.
pub struct PagePool {
    alloc: BlockAllocator,
    slots: Vec<Option<Entry>>,
    free_slots: Vec<u32>,
    hsr_backend: Option<HsrBackend>,
    /// Tokens currently held by hot segments.
    segment_tokens: usize,
    /// Tokens currently held by cold segments.
    cold_tokens: usize,
    /// The cold tier; `None` = spill off (eviction destroys).
    spill: Option<SpillStore>,
    policy: SpillPolicy,
    /// content digest → hot segment id (cold segments are not dedup
    /// targets — adopting one would force a refault mid-publish).
    dedup: HashMap<u64, SegmentId>,
    stats: TierStats,
}

impl PagePool {
    pub fn new(
        capacity_tokens: usize,
        block_tokens: usize,
        hsr_backend: Option<HsrBackend>,
    ) -> PagePool {
        PagePool::with_tier(capacity_tokens, block_tokens, hsr_backend, &TierConfig::default())
    }

    /// Pool with a cold tier per `tier`. If the spill backing fails to
    /// open (e.g. unwritable directory) the pool falls back to
    /// spill-off and keeps serving — the cold tier is an optimization,
    /// never a correctness dependency.
    pub fn with_tier(
        capacity_tokens: usize,
        block_tokens: usize,
        hsr_backend: Option<HsrBackend>,
        tier: &TierConfig,
    ) -> PagePool {
        let spill = match SpillStore::open(&tier.spill) {
            Ok(s) => s,
            Err(e) => {
                eprintln!(
                    "kvstore: spill backing {:?} unavailable ({e}); spill disabled",
                    tier.spill
                );
                None
            }
        };
        PagePool {
            alloc: BlockAllocator::new(capacity_tokens, block_tokens),
            slots: Vec::new(),
            free_slots: Vec::new(),
            hsr_backend,
            segment_tokens: 0,
            cold_tokens: 0,
            spill,
            policy: tier.policy,
            dedup: HashMap::new(),
            stats: TierStats::default(),
        }
    }

    // --- block-allocator delegation (sequence tails allocate here) ---

    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.alloc.blocks_for(tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.alloc.total_blocks()
    }

    pub fn block_tokens(&self) -> usize {
        self.alloc.block_tokens()
    }

    pub fn utilization(&self) -> f64 {
        self.alloc.utilization()
    }

    pub fn alloc(&mut self, count: usize) -> Option<Vec<u32>> {
        self.alloc.alloc(count)
    }

    pub fn ensure(&mut self, blocks: &mut Vec<u32>, needed_tokens: usize) -> bool {
        self.alloc.ensure(blocks, needed_tokens)
    }

    pub fn release(&mut self, blocks: &mut Vec<u32>) {
        self.alloc.release(blocks)
    }

    /// Debug-build cross-check: every block accounted free in the
    /// allocator's ledger (no-op in release builds).
    pub fn debug_assert_all_free(&self) {
        self.alloc.debug_assert_all_free()
    }

    // --- tier accessors ---

    /// Whether the cold tier is available.
    pub fn spill_enabled(&self) -> bool {
        self.spill.is_some()
    }

    /// Cumulative tier counters.
    pub fn tier_stats(&self) -> TierStats {
        self.stats
    }

    /// Compressed bytes currently live in the spill arena.
    pub fn spill_live_bytes(&self) -> u64 {
        self.spill.as_ref().map_or(0, |s| s.live_bytes())
    }

    // --- segment lifecycle ---

    /// Number of live segment slots (hot + cold).
    pub fn segment_count(&self) -> usize {
        self.slots.len() - self.free_slots.len()
    }

    /// Tokens held by hot segments.
    pub fn cached_tokens(&self) -> usize {
        self.segment_tokens
    }

    /// Tokens held by cold segments.
    pub fn cold_tokens(&self) -> usize {
        self.cold_tokens
    }

    /// Uncompressed payload bytes of hot segments, counted once per
    /// *physical* segment (the dedup denominator).
    pub fn physical_payload_bytes(&self) -> usize {
        self.live_entries()
            .filter_map(|e| match &e.state {
                State::Hot(seg) => Some(seg.kv.bytes()),
                State::Cold(_) => None,
            })
            .sum()
    }

    /// Uncompressed payload bytes as owners see them — each physical
    /// hot segment counted `owners` times (the dedup numerator).
    pub fn logical_payload_bytes(&self) -> usize {
        self.live_entries()
            .filter_map(|e| match &e.state {
                State::Hot(seg) => Some(seg.kv.bytes() * e.owners as usize),
                State::Cold(_) => None,
            })
            .sum()
    }

    fn live_entries(&self) -> impl Iterator<Item = &Entry> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    fn entry(&self, id: SegmentId) -> &Entry {
        self.slots[id as usize]
            .as_ref()
            .expect("segment id refers to a live segment")
    }

    fn entry_mut(&mut self, id: SegmentId) -> &mut Entry {
        self.slots[id as usize]
            .as_mut()
            .expect("segment id refers to a live segment")
    }

    /// Freeze rows `[src_offset, src_offset + tokens.len())` of `source`
    /// into a refcount-managed segment covering global positions
    /// `[start, start + tokens.len())` — or, when an identical segment
    /// is already resident, adopt it instead (one more owner, zero
    /// blocks). Returns `None` (allocating nothing) if the pool cannot
    /// hold a fresh copy — prefix caching is strictly best-effort.
    pub fn create_segment(
        &mut self,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
    ) -> Option<SegmentId> {
        if let Some(id) = self.adopt_identical(tokens, start, source, src_offset) {
            return Some(id);
        }
        self.create_segment_fresh(tokens, start, source, src_offset)
    }

    /// Content-dedup probe: if a *hot* segment with byte-identical
    /// content (tokens, chain position, every K/V bit) is resident,
    /// take one more owner claim on it and return its id. Costs one
    /// hash pass over the candidate rows and zero allocation.
    pub fn adopt_identical(
        &mut self,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
    ) -> Option<SegmentId> {
        assert!(!tokens.is_empty(), "segments cover at least one token");
        let key = segment_content_key(tokens, start, source, src_offset);
        let &id = self.dedup.get(&key)?;
        let entry = self.entry(id);
        let State::Hot(seg) = &entry.state else {
            return None; // dedup map only holds hot ids; stale = bug
        };
        if !payload_identical(seg, tokens, start, source, src_offset) {
            return None; // 64-bit collision: missed dedup, never a wrong share
        }
        let saved = seg.kv.bytes() as u64;
        self.entry_mut(id).owners += 1;
        self.stats.dedup_hits += 1;
        self.stats.dedup_bytes_saved += saved;
        Some(id)
    }

    /// Unconditionally freeze a fresh physical segment (no dedup probe).
    pub fn create_segment_fresh(
        &mut self,
        tokens: &[u32],
        start: usize,
        source: &KvState,
        src_offset: usize,
    ) -> Option<SegmentId> {
        assert!(!tokens.is_empty(), "segments cover at least one token");
        let need = self.alloc.blocks_for(tokens.len());
        let blocks = self.alloc.alloc(need)?;
        let key = segment_content_key(tokens, start, source, src_offset);
        let kv = source.snapshot_range(src_offset, tokens.len(), self.hsr_backend);
        let seg = Segment { kv, tokens: tokens.to_vec(), start, blocks };
        self.segment_tokens += seg.tokens.len();
        let entry = Entry { owners: 1, content: key, state: State::Hot(seg) };
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.slots[slot as usize] = Some(entry);
                slot
            }
            None => {
                self.slots.push(Some(entry));
                (self.slots.len() - 1) as u32
            }
        };
        // First publisher of a content key becomes the dedup target; a
        // key already present (hash-collision miss above) keeps its
        // original target.
        self.dedup.entry(key).or_insert(id);
        Some(id)
    }

    /// Borrow a live **hot** segment. Callers reach cold segments only
    /// through [`PagePool::refault_segment`] first; the radix layer
    /// guarantees adopted chains are fully hot.
    pub fn segment(&self, id: SegmentId) -> &Segment {
        match &self.entry(id).state {
            State::Hot(seg) => seg,
            State::Cold(_) => panic!("segment {id} is cold; refault before use"),
        }
    }

    /// The token run a segment covers — hot or cold (radix matching
    /// must see demoted edges).
    pub fn tokens_of(&self, id: SegmentId) -> &[u32] {
        match &self.entry(id).state {
            State::Hot(seg) => &seg.tokens,
            State::Cold(c) => &c.tokens,
        }
    }

    /// Global position of the segment's first token.
    pub fn start_of(&self, id: SegmentId) -> usize {
        match &self.entry(id).state {
            State::Hot(seg) => seg.start,
            State::Cold(c) => c.start,
        }
    }

    /// Tokens covered by the segment.
    pub fn len_of(&self, id: SegmentId) -> usize {
        self.tokens_of(id).len()
    }

    /// Whether the segment is in the cold tier.
    pub fn is_cold(&self, id: SegmentId) -> bool {
        matches!(self.entry(id).state, State::Cold(_))
    }

    /// Whether radix matching may traverse this segment: hot, or cold
    /// with an intact record. Poisoned cold segments (lost records)
    /// never match — the prompt re-prefills past them.
    pub fn is_matchable(&self, id: SegmentId) -> bool {
        match &self.entry(id).state {
            State::Hot(_) => true,
            State::Cold(c) => !c.poisoned,
        }
    }

    /// Whether the segment currently holds pool blocks (i.e. is hot).
    pub fn holds_blocks(&self, id: SegmentId) -> bool {
        matches!(self.entry(id).state, State::Hot(_))
    }

    /// Whether [`PagePool::release_segment`] with `spill = true` would
    /// demote this segment in place: cold tier available, segment hot,
    /// and this caller is the sole owner (another owner still needs the
    /// payload hot).
    pub fn can_demote(&self, id: SegmentId) -> bool {
        self.spill.is_some() && self.entry(id).owners == 1 && self.holds_blocks(id)
    }

    /// Radix-node owners of this physical segment.
    pub fn owners_of(&self, id: SegmentId) -> u32 {
        self.entry(id).owners
    }

    /// Release one owner claim on a hot segment. With other owners
    /// remaining this just drops the claim ([`Demoted::SharedKept`]).
    /// As the sole owner: `spill = true` demotes the payload into the
    /// cold tier in place ([`Demoted::Spilled`]) — the slot stays live
    /// and matchable; `spill = false` destroys it ([`Demoted::Dropped`]).
    /// If the spill write fails, spill is disabled pool-wide and the
    /// segment is dropped when `may_drop` (caller is unlinking the
    /// node) or kept hot otherwise ([`Demoted::Kept`], caller keeps the
    /// node).
    pub fn release_segment(&mut self, id: SegmentId, spill: bool, may_drop: bool) -> Demoted {
        let entry = self.entry_mut(id);
        if entry.owners > 1 {
            entry.owners -= 1;
            return Demoted::SharedKept;
        }
        if spill && self.spill.is_some() {
            match self.demote(id) {
                Ok(()) => return Demoted::Spilled,
                Err(e) => {
                    // One failed write means the backing is gone (disk
                    // full, arena unwritable) — stop spilling so the
                    // eviction loop cannot spin retrying this segment.
                    eprintln!("kvstore: spill write failed ({e}); spill disabled");
                    self.spill = None;
                    if !may_drop {
                        return Demoted::Kept;
                    }
                }
            }
        }
        self.drop_hot(id);
        Demoted::Dropped
    }

    /// Compress a sole-owner hot segment into the spill store and swap
    /// its slot to cold. Blocks return to the shared budget.
    fn demote(&mut self, id: SegmentId) -> std::io::Result<()> {
        let (record, raw_bytes) = {
            let entry = self.entry(id);
            debug_assert_eq!(entry.owners, 1, "demoting a shared segment");
            let State::Hot(seg) = &entry.state else {
                panic!("demoting a cold segment")
            };
            let mut rec = Vec::new();
            encode_segment(&seg.kv, self.policy, &mut rec);
            (rec, seg.kv.bytes())
        };
        let store = self.spill.as_mut().expect("demote requires a spill store");
        let extent = store.write(&record)?;
        // Write landed: commit the state swap.
        let entry = self.entry_mut(id);
        let key = entry.content;
        let State::Hot(seg) = std::mem::replace(
            &mut entry.state,
            State::Cold(ColdSegment {
                tokens: Vec::new(),
                start: 0,
                extent,
                raw_bytes,
                poisoned: false,
            }),
        ) else {
            unreachable!()
        };
        let Segment { tokens, start, mut blocks, .. } = seg;
        let n = tokens.len();
        let State::Cold(cold) = &mut entry.state else { unreachable!() };
        cold.tokens = tokens;
        cold.start = start;
        self.segment_tokens -= n;
        self.cold_tokens += n;
        self.alloc.release(&mut blocks);
        // Cold segments are not dedup targets.
        if self.dedup.get(&key) == Some(&id) {
            self.dedup.remove(&key);
        }
        self.stats.segments_spilled += 1;
        self.stats.spill_bytes += extent.len;
        Ok(())
    }

    /// Destroy a sole-owner hot segment outright.
    fn drop_hot(&mut self, id: SegmentId) {
        let entry = self.slots[id as usize].take().expect("dropping a live segment");
        debug_assert_eq!(entry.owners, 1);
        let State::Hot(mut seg) = entry.state else {
            panic!("drop_hot on a cold segment")
        };
        self.segment_tokens -= seg.tokens.len();
        self.alloc.release(&mut seg.blocks);
        if self.dedup.get(&entry.content) == Some(&id) {
            self.dedup.remove(&entry.content);
        }
        self.free_slots.push(id);
    }

    /// Destroy a cold segment (teardown, or reaping a poisoned record),
    /// returning its extent to the spill arena.
    pub fn release_cold(&mut self, id: SegmentId) {
        let entry = self.slots[id as usize].take().expect("releasing a live segment");
        debug_assert_eq!(entry.owners, 1, "cold segments have exactly one owner");
        let State::Cold(cold) = entry.state else {
            panic!("release_cold on a hot segment")
        };
        self.cold_tokens -= cold.tokens.len();
        if let Some(store) = &mut self.spill {
            store.release(cold.extent);
        }
        self.free_slots.push(id);
    }

    /// Promote a cold segment back to hot: re-reserve its blocks, read
    /// and decode the record, reattach HSR indices per the policy. On
    /// decode failure the segment is poisoned (record lost; the node
    /// stops matching and teardown reaps it) — callers fall back to
    /// re-prefill, never crash.
    pub fn refault_segment(&mut self, id: SegmentId) -> Refault {
        let (extent, len) = {
            let entry = self.entry(id);
            let State::Cold(cold) = &entry.state else {
                panic!("refaulting a hot segment")
            };
            if cold.poisoned {
                return Refault::Failed;
            }
            (cold.extent, cold.tokens.len())
        };
        let need = self.alloc.blocks_for(len);
        let Some(blocks) = self.alloc.alloc(need) else {
            return Refault::NoRoom;
        };
        let record = match self.spill.as_ref().expect("cold segment implies a store").read(extent)
        {
            Ok(r) => r,
            Err(e) => {
                eprintln!("kvstore: spill read failed ({e}); segment {id} lost");
                return self.poison(id, blocks);
            }
        };
        let t0 = std::time::Instant::now();
        let decoded = decode_segment(&record, self.hsr_backend);
        let rebuild_ns = t0.elapsed().as_nanos() as u64;
        // A record that fails to decode — or decodes to a different
        // length than the tokens it must back — is lost.
        let Some(kv) = decoded.filter(|kv| kv.len() == len) else {
            return self.poison(id, blocks);
        };
        let entry = self.entry_mut(id);
        let key = entry.content;
        let State::Cold(cold) = std::mem::replace(
            &mut entry.state,
            State::Hot(Segment { kv, tokens: Vec::new(), start: 0, blocks }),
        ) else {
            unreachable!()
        };
        let State::Hot(seg) = &mut entry.state else { unreachable!() };
        seg.tokens = cold.tokens;
        seg.start = cold.start;
        self.segment_tokens += len;
        self.cold_tokens -= len;
        if let Some(store) = &mut self.spill {
            store.release(cold.extent);
        }
        // Hot again: eligible as a dedup target (unless the key was
        // re-published while this segment was cold).
        self.dedup.entry(key).or_insert(id);
        self.stats.segments_refaulted += 1;
        self.stats.refault_rebuild_ns += rebuild_ns;
        Refault::Refaulted
    }

    fn poison(&mut self, id: SegmentId, mut blocks: Vec<u32>) -> Refault {
        self.alloc.release(&mut blocks);
        let entry = self.entry_mut(id);
        let State::Cold(cold) = &mut entry.state else { unreachable!() };
        cold.poisoned = true;
        Refault::Failed
    }
}

/// Full bitwise comparison between a resident segment and the rows a
/// publish would freeze — the collision-proof step behind every dedup
/// hit. Calibration snapshots must match too (they ride the segment).
fn payload_identical(
    seg: &Segment,
    tokens: &[u32],
    start: usize,
    source: &KvState,
    src_offset: usize,
) -> bool {
    if seg.start != start
        || seg.tokens != tokens
        || seg.kv.n_layers != source.n_layers
        || seg.kv.n_heads != source.n_heads
        || seg.kv.d_head != source.d_head
    {
        return false;
    }
    let d = source.d_head;
    let len = tokens.len();
    let (lo, hi) = (src_offset * d, (src_offset + len) * d);
    seg.kv.heads.iter().zip(source.heads.iter()).all(|(sh, src)| {
        sh.calib_threshold.map(f32::to_bits) == src.calib_threshold.map(f32::to_bits)
            && bits_eq(&sh.keys, &src.keys[lo..hi])
            && bits_eq(&sh.values, &src.values[lo..hi])
    })
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::tier::SpillConfig;
    use crate::util::rng::Rng;

    fn filled_kv(rng: &mut Rng, n: usize, d: usize) -> KvState {
        let mut kv = KvState::new(1, 2, d, Some(HsrBackend::BallTree));
        for _ in 0..n {
            for h in 0..2 {
                let k = rng.gaussian_vec_f32(d, 1.0);
                let v = rng.gaussian_vec_f32(d, 1.0);
                kv.head_mut(0, h).append(&k, &v);
            }
        }
        kv
    }

    fn tiered_pool(capacity: usize, policy: SpillPolicy) -> PagePool {
        PagePool::with_tier(
            capacity,
            16,
            Some(HsrBackend::BallTree),
            &TierConfig { spill: SpillConfig::Memory, policy },
        )
    }

    #[test]
    fn segment_blocks_are_accounted_and_released() {
        let mut rng = Rng::new(5);
        let kv = filled_kv(&mut rng, 40, 4);
        let mut pool = PagePool::new(256, 16, Some(HsrBackend::BallTree));
        let free0 = pool.free_blocks();
        let tokens: Vec<u32> = (0..40).collect();
        let id = pool.create_segment(&tokens, 0, &kv, 0).expect("fits");
        assert_eq!(pool.free_blocks(), free0 - pool.blocks_for(40));
        assert_eq!(pool.segment_count(), 1);
        assert_eq!(pool.cached_tokens(), 40);
        assert_eq!(pool.segment(id).len(), 40);
        assert_eq!(pool.segment(id).end(), 40);
        assert_eq!(pool.release_segment(id, false, true), Demoted::Dropped);
        assert_eq!(pool.free_blocks(), free0);
        assert_eq!(pool.segment_count(), 0);
        assert_eq!(pool.cached_tokens(), 0);
    }

    #[test]
    fn create_segment_is_best_effort_under_pressure() {
        let mut rng = Rng::new(6);
        let kv = filled_kv(&mut rng, 64, 4);
        let mut pool = PagePool::new(32, 16, None);
        let tokens: Vec<u32> = (0..64).collect();
        let free0 = pool.free_blocks();
        assert!(pool.create_segment(&tokens, 0, &kv, 0).is_none());
        // A failed create must not leak blocks.
        assert_eq!(pool.free_blocks(), free0);
    }

    #[test]
    fn segment_payload_matches_source_rows() {
        let mut rng = Rng::new(7);
        let kv = filled_kv(&mut rng, 30, 8);
        let mut pool = PagePool::new(1024, 16, Some(HsrBackend::BallTree));
        let tokens: Vec<u32> = (10..30).collect();
        let id = pool.create_segment(&tokens, 10, &kv, 10).unwrap();
        let seg = pool.segment(id);
        assert_eq!(seg.start, 10);
        for h in 0..2 {
            let src = kv.head(0, h);
            let dst = seg.kv.head(0, h);
            assert_eq!(dst.len(), 20);
            for j in 0..20 {
                assert_eq!(dst.key_row(j), src.key_row(10 + j));
                assert_eq!(dst.value_row(j), src.value_row(10 + j));
            }
        }
        // Slot reuse after drop.
        assert_eq!(pool.release_segment(id, false, true), Demoted::Dropped);
        let id2 = pool.create_segment(&tokens, 10, &kv, 10).unwrap();
        assert_eq!(id, id2);
    }

    #[test]
    fn demote_then_refault_restores_payload_and_blocks() {
        let mut rng = Rng::new(8);
        let kv = filled_kv(&mut rng, 48, 8);
        for policy in [SpillPolicy::RebuildOnRefault, SpillPolicy::SerializeHsr] {
            let mut pool = tiered_pool(1024, policy);
            let free0 = pool.free_blocks();
            let tokens: Vec<u32> = (0..48).collect();
            let id = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
            let blocks_used = free0 - pool.free_blocks();
            assert_eq!(pool.release_segment(id, true, true), Demoted::Spilled);
            // Demoted: blocks free, tokens still readable, payload cold.
            assert_eq!(pool.free_blocks(), free0);
            assert!(pool.is_cold(id));
            assert!(pool.is_matchable(id));
            assert_eq!(pool.tokens_of(id), &tokens[..]);
            assert_eq!(pool.start_of(id), 0);
            assert_eq!(pool.cached_tokens(), 0);
            assert_eq!(pool.cold_tokens(), 48);
            assert!(pool.spill_live_bytes() > 0);
            assert_eq!(pool.refault_segment(id), Refault::Refaulted);
            assert_eq!(pool.free_blocks(), free0 - blocks_used);
            assert!(!pool.is_cold(id));
            assert_eq!(pool.cold_tokens(), 0);
            assert_eq!(pool.spill_live_bytes(), 0, "refault frees the extent");
            // Bitwise-identical payload after the round trip.
            let seg = pool.segment(id);
            for h in 0..2 {
                let src = kv.head(0, h);
                let dst = seg.kv.head(0, h);
                for j in 0..48 {
                    assert!(bits_eq(dst.key_row(j), src.key_row(j)));
                    assert!(bits_eq(dst.value_row(j), src.value_row(j)));
                }
            }
            let stats = pool.tier_stats();
            assert_eq!(stats.segments_spilled, 1);
            assert_eq!(stats.segments_refaulted, 1);
            assert!(stats.spill_bytes > 0);
        }
    }

    #[test]
    fn refault_reports_no_room_and_retries() {
        let mut rng = Rng::new(9);
        let kv = filled_kv(&mut rng, 32, 4);
        // Pool fits exactly one 32-token segment (2 blocks).
        let mut pool = tiered_pool(32, SpillPolicy::RebuildOnRefault);
        let tokens: Vec<u32> = (0..32).collect();
        let id = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        assert_eq!(pool.release_segment(id, true, true), Demoted::Spilled);
        // Occupy the blocks with a tail allocation.
        let mut tail = pool.alloc(2).unwrap();
        assert_eq!(pool.refault_segment(id), Refault::NoRoom);
        assert!(pool.is_cold(id), "NoRoom leaves the segment cold and intact");
        pool.release(&mut tail);
        assert_eq!(pool.refault_segment(id), Refault::Refaulted);
    }

    #[test]
    fn dedup_shares_one_physical_segment() {
        let mut rng = Rng::new(10);
        let kv = filled_kv(&mut rng, 24, 4);
        let mut pool = tiered_pool(1024, SpillPolicy::RebuildOnRefault);
        let tokens: Vec<u32> = (0..24).collect();
        let free0 = pool.free_blocks();
        let a = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        let after_one = pool.free_blocks();
        let b = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        assert_eq!(a, b, "identical publish adopts the same physical segment");
        assert_eq!(pool.free_blocks(), after_one, "dedup hit allocates nothing");
        assert_eq!(pool.owners_of(a), 2);
        assert_eq!(pool.segment_count(), 1);
        assert_eq!(pool.logical_payload_bytes(), 2 * pool.physical_payload_bytes());
        let stats = pool.tier_stats();
        assert_eq!(stats.dedup_hits, 1);
        assert!(stats.dedup_bytes_saved > 0);
        // Different start position → different content → fresh segment.
        let c = pool.create_segment(&tokens, 24, &kv, 0).unwrap();
        assert_ne!(a, c);
        // Shared segment cannot demote; releases peel owners one at a
        // time and only the last one frees.
        assert!(!pool.can_demote(a));
        let before = pool.free_blocks();
        assert_eq!(pool.release_segment(a, true, true), Demoted::SharedKept);
        assert_eq!(pool.free_blocks(), before, "SharedKept frees nothing");
        assert_eq!(pool.owners_of(a), 1);
        assert!(pool.can_demote(a));
        assert_eq!(pool.release_segment(a, false, true), Demoted::Dropped);
        assert_eq!(pool.release_segment(c, false, true), Demoted::Dropped);
        assert_eq!(pool.free_blocks(), free0);
        pool.debug_assert_all_free();
    }

    #[test]
    fn cold_segment_is_not_a_dedup_target() {
        let mut rng = Rng::new(11);
        let kv = filled_kv(&mut rng, 16, 4);
        let mut pool = tiered_pool(1024, SpillPolicy::RebuildOnRefault);
        let tokens: Vec<u32> = (0..16).collect();
        let a = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        assert_eq!(pool.release_segment(a, true, true), Demoted::Spilled);
        // Same content published again while `a` is cold: fresh segment.
        let b = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        assert_ne!(a, b);
        assert_eq!(pool.owners_of(a), 1);
        // Refaulting `a` must not steal the dedup slot back.
        assert_eq!(pool.refault_segment(a), Refault::Refaulted);
        let c = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        assert_eq!(c, b, "key republished while cold keeps its target");
    }

    #[test]
    fn spill_off_release_drops() {
        let mut rng = Rng::new(12);
        let kv = filled_kv(&mut rng, 16, 4);
        let mut pool = PagePool::new(1024, 16, None);
        assert!(!pool.spill_enabled());
        let tokens: Vec<u32> = (0..16).collect();
        let id = pool.create_segment(&tokens, 0, &kv, 0).unwrap();
        // spill requested but no store → plain drop.
        assert_eq!(pool.release_segment(id, true, true), Demoted::Dropped);
        assert_eq!(pool.segment_count(), 0);
    }
}
