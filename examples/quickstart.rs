//! Quickstart: the paper's pipeline in 60 lines.
//!
//! 1. Draw the Gaussian attention workload of Lemma 6.1.
//! 2. Build the HSR structure over the keys (Algorithm 1 INIT).
//! 3. Run HSR-sparse ReLU^α attention and verify it is *exactly* the
//!    dense result, while touching only ~n^{4/5} entries.
//! 4. Run top-r Softmax attention and show the Lemma G.1 error bound.
//!
//! Run: cargo run --release --example quickstart

use hsr_attn::attention::error::{general_error_bound, v_inf_norm};
use hsr_attn::attention::relu::relu_attention;
use hsr_attn::attention::softmax::softmax_attention;
use hsr_attn::attention::topk::top_r_indices;
use hsr_attn::attention::{linf, scores_into, AttentionKind};
use hsr_attn::engine::GenerationDecoding;
use hsr_attn::hsr::HsrBackend;
use hsr_attn::util::rng::Rng;
use hsr_attn::workloads::gaussian::AttentionInstance;

fn main() {
    let mut rng = Rng::new(42);
    let (m, n, d) = (4usize, 8192usize, 16usize);
    println!("== HSR-enhanced sparse attention quickstart ==");
    println!("workload: Q[{m}x{d}], K/V[{n}x{d}] ~ N(0,1)  (Lemma 6.1 setting)\n");
    let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
    let bias = inst.params.practical_bias(n) as f32;
    println!("threshold b = sigma_a * sqrt(0.4 ln n) = {bias:.4}");
    println!("Lemma 6.1 row bound: 2n^(4/5) = {:.0}\n", inst.params.row_bound(n));

    // --- ReLU^2 attention via Algorithm 1: exact, sparse ---
    let kind = AttentionKind::Relu { alpha: 2, bias };
    let mut gd =
        GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
    let sparse = gd.inference(&inst.q);
    let dense = relu_attention(&inst.q, &inst.k, &inst.v, d, 2, bias);
    println!("ReLU^2 attention (Algorithm 1, ball-tree HSR):");
    println!(
        "  max |sparse - dense|      = {:.2e}  (exact by construction)",
        linf(&sparse, &dense)
    );
    println!(
        "  HSR work: scanned {} + bulk-reported {} of {} keys/query",
        gd.stats.points_scanned / m,
        gd.stats.bulk_reported / m,
        n
    );
    println!("  activated entries/query   = {}\n", gd.stats.reported / m);

    // --- Softmax attention with top-r indices (Definition B.2) ---
    let dense_s = softmax_attention(&inst.q, &inst.k, &inst.v, d);
    let r = (n as f64).powf(0.8) as usize;
    println!("Softmax attention with top-r indices (r = n^(4/5) = {r}):");
    let mut scores = vec![0f32; n];
    for i in 0..m {
        let q = inst.query_row(i);
        scores_into(q, &inst.k, d, &mut scores);
        let idx = top_r_indices(&scores, r);
        let mut out = vec![0f32; d];
        let mut buf = Vec::new();
        hsr_attn::attention::softmax::softmax_attention_row_subset(
            q, &inst.k, &inst.v, d, &idx, &mut buf, &mut out,
        );
        let err = linf(&out, &dense_s[i * d..(i + 1) * d]);
        let bound = general_error_bound(&scores, &idx, v_inf_norm(&inst.v));
        println!("  query {i}: linf err = {err:.3e}   Lemma G.1 bound = {bound:.3e}");
        assert!((err as f64) <= bound + 1e-5);
    }
    println!("\nOK — sparse ReLU is exact, softmax top-r error sits under the bound.");
}
